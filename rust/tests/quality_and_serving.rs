//! Integration: quality harness orderings + the router/batcher serving
//! path end-to-end (in-process, no TCP).

use std::rc::Rc;

use kvswap::baselines::{configure, Budget};
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::batcher::BatcherConfig;
use kvswap::coordinator::router::Router;
use kvswap::coordinator::{EngineConfig, Policy};
use kvswap::disk::DiskProfile;
use kvswap::quality::{evaluate_policy, niah_cell};
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};
use kvswap::workload::tracegen::Request;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(Manifest::load(dir).unwrap()).unwrap()))
}

fn cfg(policy: Policy, kv: KvSwapConfig) -> EngineConfig {
    EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(policy)
        .kv(kv)
        .disk(DiskProfile::nvme())
        .max_context(2048)
        .build()
        .expect("valid test config")
}

#[test]
fn kvswap_quality_beats_tight_baselines() {
    let Some(rt) = runtime() else { return };
    let context = 1792;
    let steps = 4;
    let fid = |policy: &Policy, budget: Budget| {
        let (p, kv) = configure(policy, budget, 4);
        evaluate_policy(rt.clone(), cfg(p, kv), context, steps, 77)
            .unwrap()
            .fidelity
    };
    let kvswap_t = fid(&Policy::KvSwap, Budget::Tight);
    let loki_t = fid(&Policy::Loki, Budget::Tight);
    let infinigen = fid(
        &Policy::InfiniGen {
            head_agg: false,
            reuse: false,
        },
        Budget::Tight,
    );
    eprintln!("fidelity: kvswap-t {kvswap_t:.3} loki-t {loki_t:.3} infinigen-t {infinigen:.3}");
    // paper Tab. 2 ordering under the tight budget. KNOWN DEVIATION
    // (EXPERIMENTS.md): our Loki variant shares KVSwap's SVD predictor
    // (the real Loki's weaker approximation is what collapses in the
    // paper), so its *quality* ties KVSwap here — its losses show up in
    // throughput/IO instead. Assert statistical parity, not dominance.
    assert!(
        kvswap_t >= loki_t - 0.02,
        "kvswap-t {kvswap_t:.3} well below loki-t {loki_t:.3}"
    );
    assert!(
        kvswap_t > infinigen,
        "kvswap-t {kvswap_t:.3} <= infinigen {infinigen:.3}"
    );
    assert!(kvswap_t > 0.5, "kvswap-t unusable: {kvswap_t:.3}");
}

#[test]
fn niah_kvswap_retrieves_needle() {
    let Some(rt) = runtime() else { return };
    let (p, kv) = configure(&Policy::KvSwap, Budget::Relaxed, 4);
    let score = niah_cell(rt.clone(), cfg(p, kv), 512, 0.4, 5, 10.0).unwrap();
    assert!(score > 0.8, "kvswap missed the needle: {score:.3}");

    // a needle-blind strawman: FlexGen truncated? use Loki-t which tends
    // to lose needles at depth on tight budgets — allow it to pass but
    // never beat kvswap by a margin
    let (p2, kv2) = configure(&Policy::Loki, Budget::Tight, 4);
    let s2 = niah_cell(rt.clone(), cfg(p2, kv2), 512, 0.4, 5, 10.0).unwrap();
    assert!(score >= s2 - 0.05, "kvswap {score:.3} vs loki-t {s2:.3}");
}

#[test]
fn router_serves_a_trace_in_process() {
    let Some(_) = runtime() else { return };
    let engine_cfg = EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .max_context(1024)
        .build()
        .expect("valid router config");
    let batcher_cfg = BatcherConfig {
        supported: vec![1, 2],
        linger_s: 0.01,
        max_context: 1024,
    };
    let router = Router::spawn(default_artifacts_dir(), engine_cfg, batcher_cfg);
    let n = 5;
    for i in 0..n {
        router.submit(Request {
            id: i,
            context: 256 + (i as usize % 2) * 128,
            decode: 4 + i as usize,
            arrival_s: 0.0,
            seed: i,
            tokens: None,
        });
    }
    router.flush();
    let mut got = Vec::new();
    for _ in 0..n {
        let c = router
            .recv_timeout(std::time::Duration::from_secs(300))
            .expect("completion");
        assert_eq!(c.tokens.len(), 4 + c.id as usize);
        assert!(c.latency_ms >= 0.0);
        got.push(c.id);
    }
    got.sort();
    assert_eq!(got, (0..n).collect::<Vec<_>>());
    router.stop().unwrap();
}

#[test]
fn shadowkv_reconstruction_stays_consistent_across_ranks() {
    // KNOWN DEVIATION (EXPERIMENTS.md): on trained models ShadowKV's
    // tight-budget rank squeeze collapses quality (paper Tab. 2,
    // -61.9% RULER); our synthetic K spectra put *noise* in the tail
    // dims, so the low-rank reconstruction acts as a denoiser and
    // ShadowKV-t stays usable. We assert the mechanism runs and both
    // ranks produce coherent output, and document the deviation.
    let Some(rt) = runtime() else { return };
    let (p16, kv16) = configure(&Policy::ShadowKv { chunk: 8, rank: 32 }, Budget::Relaxed, 4);
    let q16 = evaluate_policy(rt.clone(), cfg(p16, kv16), 768, 5, 55).unwrap();
    let (p4, kv4) = configure(&Policy::ShadowKv { chunk: 8, rank: 32 }, Budget::Tight, 4);
    let q4 = evaluate_policy(rt.clone(), cfg(p4, kv4), 768, 5, 55).unwrap();
    assert!(q16.fidelity > 0.85, "shadowkv r16 broken: {:.3}", q16.fidelity);
    assert!(q4.fidelity > 0.85, "shadowkv r4 broken: {:.3}", q4.fidelity);
}
