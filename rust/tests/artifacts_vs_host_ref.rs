//! Integration: PJRT-executed HLO artifacts vs the pure-Rust host oracle.
//!
//! This is the repo's cross-layer correctness keystone: the same math must
//! come out of (a) the Pallas-kernel-bearing HLO produced by the JAX
//! compile path and (b) `runtime::host_ref`. Requires `make artifacts`.

use std::rc::Rc;

use kvswap::runtime::{
    default_artifacts_dir, HostModel, KvLayer, Manifest, ModelRuntime, PjrtRuntime, Tensor,
    TensorI32,
};
use kvswap::util::mathx;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(Manifest::load(dir).unwrap()).unwrap()))
}

fn host_model(rt: &Rc<PjrtRuntime>, preset: &str) -> HostModel {
    let weights = rt.host_weights(preset).unwrap();
    let spec = rt.manifest.presets[preset].spec.clone();
    HostModel::new(spec, weights)
}

#[test]
fn embed_and_logits_match_host_ref() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::new(rt.clone(), "nano", 2).unwrap();
    let host = host_model(&rt, "nano");
    let tokens = [17i32, 401];
    let x = mr.embed(&tokens).unwrap();
    for (b, &tok) in tokens.iter().enumerate() {
        let want = host.embed(tok);
        assert!(
            mathx::rel_err(x.row(&[b]), &want) < 1e-5,
            "embed row {b} mismatch"
        );
    }
    let (toks, tops) = mr.logits_argmax(x).unwrap();
    for (b, &tok) in tokens.iter().enumerate() {
        let (want_tok, want_top) = host.logits_argmax(&host.embed(tok));
        assert_eq!(toks[b], want_tok);
        assert!((tops[b] - want_top).abs() < 1e-3);
    }
}

#[test]
fn decode_block_matches_host_ref_over_random_cache() {
    let Some(rt) = runtime() else { return };
    let batch = 2;
    let mr = ModelRuntime::new(rt.clone(), "nano", batch).unwrap();
    let host = host_model(&rt, "nano");
    let spec = host.spec.clone();
    let p = mr.p_sel;
    let (hkv, d) = (spec.n_kv_heads, spec.head_dim);
    let hd = spec.kv_flat_dim();

    let mut rng = kvswap::util::rng::Rng::new(7);
    // random activations + random KV rows; last 20 slots masked out
    let n_valid = p - 20;
    let x = Tensor::from_vec(
        &[batch, spec.d_model],
        (0..batch * spec.d_model).map(|_| rng.normal_f32(1.0)).collect(),
    );
    // host layout: token-major rows [Hkv*d]; artifact layout: [b,Hkv,P,d]
    let mut k_rows = vec![vec![0.0f32; hd]; batch * p];
    let mut v_rows = vec![vec![0.0f32; hd]; batch * p];
    for r in k_rows.iter_mut().chain(v_rows.iter_mut()) {
        for v in r.iter_mut() {
            *v = rng.normal_f32(0.5);
        }
    }
    let mut k_sel = Tensor::zeros(&[batch, hkv, p, d]);
    let mut v_sel = Tensor::zeros(&[batch, hkv, p, d]);
    for b in 0..batch {
        for g in 0..hkv {
            for s in 0..p {
                for dd in 0..d {
                    *k_sel.at_mut(&[b, g, s, dd]) = k_rows[b * p + s][g * d + dd];
                    *v_sel.at_mut(&[b, g, s, dd]) = v_rows[b * p + s][g * d + dd];
                }
            }
        }
    }
    let mut mask = Tensor::zeros(&[batch, p]);
    for b in 0..batch {
        for s in n_valid..p {
            *mask.at_mut(&[b, s]) = -1e9;
        }
    }
    let pos = vec![100i32, 37];

    for layer in [0, spec.n_layers - 1] {
        let (x_out, k_new, v_new) = mr
            .decode_block(
                "decode_p272",
                layer,
                x.clone(),
                k_sel.clone(),
                v_sel.clone(),
                mask.clone(),
                &pos,
            )
            .unwrap();
        for b in 0..batch {
            let krefs: Vec<&[f32]> = (0..n_valid).map(|s| k_rows[b * p + s].as_slice()).collect();
            let vrefs: Vec<&[f32]> = (0..n_valid).map(|s| v_rows[b * p + s].as_slice()).collect();
            let (want_x, want_k, want_v) =
                host.block(layer, x.row(&[b]), &krefs, &vrefs, None, pos[b]);
            assert!(
                mathx::rel_err(x_out.row(&[b]), &want_x) < 1e-3,
                "layer {layer} b {b}: x rel err {}",
                mathx::rel_err(x_out.row(&[b]), &want_x)
            );
            // artifact k_new is [Hkv, d]; host k_new is [Hkv*d] same order
            assert!(mathx::rel_err(k_new.row(&[b]), &want_k) < 1e-3);
            assert!(mathx::rel_err(v_new.row(&[b]), &want_v) < 1e-3);
        }
    }
}

#[test]
fn predict_scores_match_host_ref() {
    let Some(rt) = runtime() else { return };
    let batch = 2;
    let mr = ModelRuntime::new(rt.clone(), "nano", batch).unwrap();
    let host = host_model(&rt, "nano");
    let spec = host.spec.clone();
    let ncap = 1024;
    let rank = 16;
    let mut rng = kvswap::util::rng::Rng::new(8);
    let lens = [600i32, 37];
    let pos = [700i32, 90];
    let x = Tensor::from_vec(
        &[batch, spec.d_model],
        (0..batch * spec.d_model).map(|_| rng.normal_f32(1.0)).collect(),
    );
    let k_lr = Tensor::from_vec(
        &[batch, ncap, rank],
        (0..batch * ncap * rank).map(|_| rng.normal_f32(1.0)).collect(),
    );
    let layer = 2;
    let scores = mr
        .predict_scores(layer, ncap, rank, x.clone(), k_lr.clone(), &lens, &pos)
        .unwrap();
    let adapter = &rt.host_weights("nano").unwrap()[&format!("layer{layer}.A{rank}")].clone();
    for b in 0..batch {
        let rows: Vec<&[f32]> = (0..lens[b] as usize).map(|n| k_lr.row(&[b, n])).collect();
        let want = host.predict_scores(layer, x.row(&[b]), adapter, &rows, pos[b]);
        let got = &scores.row(&[b])[..lens[b] as usize];
        assert!(
            mathx::rel_err(got, &want) < 1e-3,
            "b {b}: rel err {}",
            mathx::rel_err(got, &want)
        );
        // masked tail is NEG_INF
        for s in lens[b] as usize..ncap {
            assert!(scores.at(&[b, s]) <= -1e8);
        }
    }
}

#[test]
fn prefill_blocks_match_host_ref_prefill() {
    let Some(rt) = runtime() else { return };
    let batch = 1;
    let mr = ModelRuntime::new(rt.clone(), "nano", batch).unwrap();
    let host = host_model(&rt, "nano");
    let spec = host.spec.clone();
    let info = &rt.manifest.presets["nano"];
    let (chunk, ncap) = (info.prefill_chunk, info.prefill_ncap);
    let (hkv, d) = (spec.n_kv_heads, spec.head_dim);

    let mut rng = kvswap::util::rng::Rng::new(9);
    let s_len = 2 * chunk; // two chunks
    let tokens: Vec<i32> = (0..s_len).map(|_| rng.below(spec.vocab) as i32).collect();
    let (want_xs, want_caches) = host.prefill(&tokens);

    // chunked prefill through artifacts, one KV cache tensor per layer
    let mut k_caches: Vec<Tensor> =
        (0..spec.n_layers).map(|_| Tensor::zeros(&[batch, hkv, ncap, d])).collect();
    let mut v_caches: Vec<Tensor> =
        (0..spec.n_layers).map(|_| Tensor::zeros(&[batch, hkv, ncap, d])).collect();
    let mut last_x_row = vec![0.0f32; spec.d_model];
    for c0 in (0..s_len).step_by(chunk) {
        let toks = TensorI32::from_vec(&[batch, chunk], tokens[c0..c0 + chunk].to_vec());
        let mut x = mr.embed_chunk(&toks, chunk).unwrap();
        let start = vec![c0 as i32];
        for layer in 0..spec.n_layers {
            let (x1, k_chunk, v_chunk) = mr
                .prefill_block(
                    layer,
                    chunk,
                    ncap,
                    x,
                    k_caches[layer].clone(),
                    v_caches[layer].clone(),
                    &start,
                )
                .unwrap();
            x = x1;
            for g in 0..hkv {
                for t in 0..chunk {
                    for dd in 0..d {
                        *k_caches[layer].at_mut(&[0, g, c0 + t, dd]) = k_chunk.at(&[0, g, t, dd]);
                        *v_caches[layer].at_mut(&[0, g, c0 + t, dd]) = v_chunk.at(&[0, g, t, dd]);
                    }
                }
            }
        }
        last_x_row.copy_from_slice(x.row(&[0, chunk - 1]));
    }

    // final hidden state of the last token matches host prefill
    let want_last = want_xs.last().unwrap();
    assert!(
        mathx::rel_err(&last_x_row, want_last) < 5e-3,
        "final x rel err {}",
        mathx::rel_err(&last_x_row, want_last)
    );
    // per-layer KV caches match (host rows are [Hkv*d] token-major)
    for layer in 0..spec.n_layers {
        for t in 0..s_len {
            let want_k = want_caches[layer].k_row(t);
            let mut got = vec![0.0f32; spec.kv_flat_dim()];
            for g in 0..hkv {
                for dd in 0..d {
                    got[g * d + dd] = k_caches[layer].at(&[0, g, t, dd]);
                }
            }
            assert!(
                mathx::rel_err(&got, want_k) < 5e-3,
                "layer {layer} tok {t}: k rel err {}",
                mathx::rel_err(&got, want_k)
            );
        }
    }
}
