//! Integration tests for the persistent KV store: bit-identical
//! restores across reopen, crash-safe manifest persistence, pinned LRU
//! eviction, and scheduled scrubbing of injected corruption.
//!
//! These tests need no AOT artifacts — the store operates on raw group
//! records below the engine.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kvswap::config::{FaultConfig, StoreConfig};
use kvswap::disk::{Backend, DiskProfile, Fault, FaultBackend, MemBackend};
use kvswap::kvcache::DiskLayout;
use kvswap::store::PersistentStore;
use kvswap::util::rng::Rng;

/// Small geometry: hd=8, G=4, 64-token capacity (16 groups), 2 layers,
/// no page padding. One 8-token entry = 2 groups x 2 layers x 256 B
/// = 1024 B.
fn layout() -> DiskLayout {
    DiskLayout::new(8, 4, 64, 2, 0)
}

fn cfg_mem(capacity: u64) -> StoreConfig {
    StoreConfig {
        enabled: true,
        dir: None,
        capacity_bytes: capacity,
        scrub_interval_s: 3600.0,
        scrub_budget: 4,
        pipelined_restore: true,
        // compaction off by default; the compaction tests opt in
        compact_free_frac: 1.0,
    }
}

fn cfg_dir(dir: &std::path::Path, capacity: u64) -> StoreConfig {
    StoreConfig {
        dir: Some(dir.to_path_buf()),
        ..cfg_mem(capacity)
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("kvswap-store-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tokens_for(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(512) as i32).collect()
}

fn rows_for(lo: &DiskLayout, n_tokens: usize, seed: u64) -> Vec<(Vec<f32>, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    (0..lo.n_layers)
        .map(|_| {
            let k: Vec<f32> = (0..n_tokens * lo.hd).map(|_| rng.normal_f32(1.0)).collect();
            let v: Vec<f32> = (0..n_tokens * lo.hd).map(|_| rng.normal_f32(1.0)).collect();
            (k, v)
        })
        .collect()
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|f| f.to_bits()).collect()
}

#[test]
fn restore_is_bit_identical_across_reopen() {
    let dir = tmp_dir("roundtrip");
    let lo = layout();
    let cfg = cfg_dir(&dir, 1 << 20);
    let fault = FaultConfig::default();
    let tokens = tokens_for(16, 1);
    let rows = rows_for(&lo, 16, 2);
    {
        let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
        assert_eq!(store.save(&tokens, &rows).unwrap(), 16);
    }

    // "next process": reopen from the manifest alone
    let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
    assert_eq!(store.entries(), 1);
    let m = store.lookup(&tokens).expect("stored prefix found after reopen");
    assert_eq!(m.tokens, 16);
    let restored = store.restore(&m, 16).unwrap();
    assert_eq!(restored.len(), lo.n_layers);
    for (layer, (k, v)) in restored.iter().enumerate() {
        assert_eq!(bits(k), bits(&rows[layer].0), "layer {layer} K rows");
        assert_eq!(bits(v), bits(&rows[layer].1), "layer {layer} V rows");
    }

    // a prompt diverging after 8 tokens matches only the shared,
    // group-aligned prefix; the partial restore is bit-identical too
    let mut fork = tokens[..8].to_vec();
    for i in 0..8 {
        fork.push((tokens[8 + i] + 1) % 512);
    }
    let m2 = store.lookup(&fork).expect("shared prefix found");
    assert_eq!(m2.tokens, 8);
    let part = store.restore(&m2, 8).unwrap();
    for (layer, (k, v)) in part.iter().enumerate() {
        assert_eq!(bits(k), bits(&rows[layer].0[..8 * lo.hd]));
        assert_eq!(bits(v), bits(&rows[layer].1[..8 * lo.hd]));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_survives_simulated_crash() {
    let dir = tmp_dir("crash");
    let lo = layout();
    let cfg = cfg_dir(&dir, 1 << 20);
    let fault = FaultConfig::default();
    let tokens = tokens_for(8, 3);
    {
        let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
        assert_eq!(store.save(&tokens, &rows_for(&lo, 8, 4)).unwrap(), 8);
    }

    // crash between temp write and rename: the unpublished temp file is
    // discarded on open and the last published manifest stays live
    std::fs::write(dir.join("manifest.json.tmp"), b"{\"version\": 99, \"gar").unwrap();
    {
        let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
        assert!(!dir.join("manifest.json.tmp").exists(), "temp discarded");
        assert_eq!(store.entries(), 1);
        assert!(store.lookup(&tokens).is_some());
    }

    // torn live manifest (crash mid-sector, truncated JSON): the store
    // reopens clean instead of refusing to start, and accepts new saves
    std::fs::write(dir.join("manifest.json"), b"{\"version\": 1, \"ent").unwrap();
    let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
    assert_eq!(store.entries(), 0);
    assert!(store.lookup(&tokens).is_none());
    assert_eq!(store.save(&tokens, &rows_for(&lo, 8, 4)).unwrap(), 8);
    assert!(store.lookup(&tokens).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lru_eviction_respects_capacity_and_pins() {
    let lo = layout();
    // room for exactly two 1024-B entries
    let store =
        PersistentStore::open(&cfg_mem(2048), DiskProfile::nvme(), &FaultConfig::default(), lo.clone())
            .unwrap();
    let (ta, tb, tc, td) = (
        tokens_for(8, 10),
        tokens_for(8, 11),
        tokens_for(8, 12),
        tokens_for(8, 13),
    );
    assert_eq!(store.save(&ta, &rows_for(&lo, 8, 20)).unwrap(), 8);
    assert_eq!(store.save(&tb, &rows_for(&lo, 8, 21)).unwrap(), 8);
    assert_eq!(store.entries(), 2);
    assert_eq!(store.stored_bytes(), 2048);

    // freshen A so B becomes the LRU victim
    assert!(store.lookup(&ta).is_some());
    assert_eq!(store.save(&tc, &rows_for(&lo, 8, 22)).unwrap(), 8);
    assert_eq!(store.entries(), 2);
    assert!(store.lookup(&tb).is_none(), "B evicted");
    assert!(store.lookup(&ta).is_some(), "A survived");

    // pin everything: the store must skip the save, never evict under a
    // pinned (in-restore) entry
    let ma = store.lookup(&ta).unwrap();
    let mc = store.lookup(&tc).unwrap();
    store.pin(ma.entry);
    store.pin(mc.entry);
    assert_eq!(store.save(&td, &rows_for(&lo, 8, 23)).unwrap(), 0);
    assert_eq!(store.entries(), 2);
    assert!(store.lookup(&td).is_none());

    // unpin A (now the oldest unpinned): D lands by evicting A
    store.unpin(ma.entry);
    assert_eq!(store.save(&td, &rows_for(&lo, 8, 23)).unwrap(), 8);
    assert!(store.lookup(&ta).is_none(), "A evicted after unpin");
    assert!(store.lookup(&tc).is_some(), "pinned C untouched");
    assert!(store.lookup(&td).is_some());
    let c = store.counters();
    assert!(c.evictions >= 2, "evictions counted: {c:?}");
    assert!(c.save_skips >= 1, "pinned-full save skipped: {c:?}");
    assert!(store.stored_bytes() <= store.capacity_bytes());
}

#[test]
fn scrub_detects_records_and_quarantines_corruption() {
    let lo = layout();
    let mem = Arc::new(MemBackend::new());
    let store = PersistentStore::open_with_backend(
        &cfg_mem(1 << 20),
        DiskProfile::nvme(),
        lo.clone(),
        mem.clone(),
    )
    .unwrap();
    let ta = tokens_for(8, 30);
    let tb = tokens_for(8, 31);
    assert_eq!(store.save(&ta, &rows_for(&lo, 8, 40)).unwrap(), 8);
    assert_eq!(store.save(&tb, &rows_for(&lo, 8, 41)).unwrap(), 8);

    // flip one byte of A's (slot 0) layer-0 group-1 record behind the
    // integrity map's back — silent media rot
    let off = lo.offset(0, 0, 1);
    let mut b = [0u8; 1];
    mem.read_at(off + 5, &mut b).unwrap();
    mem.write_at(off + 5, &[b[0] ^ 0xFF]).unwrap();

    let rep = store.scrub_now(usize::MAX);
    assert_eq!(rep.entries_scanned, 2);
    assert_eq!(rep.corruptions, 1);
    assert_eq!(rep.quarantined, 1);
    assert_eq!(store.entries(), 1, "poisoned entry quarantined");
    assert!(store.lookup(&ta).is_none());
    assert!(store.lookup(&tb).is_some(), "clean entry untouched");

    // the corruption site is recorded for post-mortem, pointing at the
    // exact record
    let sites = store.corruption_sites();
    assert_eq!(sites.len(), 1);
    assert_eq!(sites[0].layer, 0);
    assert_eq!(sites[0].group, 1);
    assert_eq!(sites[0].offset, off);
    let c = store.counters();
    assert_eq!(c.corruptions, 1);
    assert_eq!(c.quarantined, 1);
}

#[test]
fn scrub_heals_transient_faults_without_quarantine() {
    let lo = layout();
    let mem: Arc<dyn Backend> = Arc::new(MemBackend::new());
    let fb = Arc::new(FaultBackend::quiet(mem));
    let store = PersistentStore::open_with_backend(
        &cfg_mem(1 << 20),
        DiskProfile::nvme(),
        lo.clone(),
        fb.clone(),
    )
    .unwrap();
    let tokens = tokens_for(8, 50);
    assert_eq!(store.save(&tokens, &rows_for(&lo, 8, 51)).unwrap(), 8);

    // the scrub's first read fails transiently; its immediate re-read
    // succeeds and the entry stays
    fb.script_at(fb.snapshot().reads, Fault::TransientIo);
    let rep = store.scrub_now(usize::MAX);
    assert_eq!(rep.healed, 1);
    assert_eq!(rep.corruptions, 0);
    assert_eq!(rep.quarantined, 0);
    assert_eq!(store.entries(), 1);
    assert_eq!(store.counters().healed, 1);
}

/// Backend wrapper that makes writes slow, widening the save's
/// admission→commit window so racing saves actually overlap in it.
struct SlowWrites(Arc<MemBackend>);

impl Backend for SlowWrites {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> kvswap::disk::DiskResult<()> {
        self.0.read_at(offset, buf)
    }
    fn write_at(&self, offset: u64, data: &[u8]) -> kvswap::disk::DiskResult<()> {
        std::thread::sleep(Duration::from_millis(2));
        self.0.write_at(offset, data)
    }
    fn len(&self) -> u64 {
        self.0.len()
    }
}

#[test]
fn concurrent_saves_never_overshoot_capacity() {
    let lo = layout();
    // room for exactly two 1024-B entries; four threads race twelve
    // distinct saves into it
    let store = Arc::new(
        PersistentStore::open_with_backend(
            &cfg_mem(2048),
            DiskProfile::nvme(),
            lo.clone(),
            Arc::new(SlowWrites(Arc::new(MemBackend::new()))),
        )
        .unwrap(),
    );
    let n_threads = 4;
    let rounds = 3u64;
    let barrier = Arc::new(std::sync::Barrier::new(n_threads + 1));
    let mut handles = Vec::new();
    for t in 0..n_threads as u64 {
        let (store, lo, barrier) = (store.clone(), lo.clone(), barrier.clone());
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            for round in 0..rounds {
                let seed = 100 + t * 10 + round;
                store.save(&tokens_for(8, seed), &rows_for(&lo, 8, seed)).unwrap();
            }
        }));
    }
    barrier.wait();
    // capacity is an invariant DURING the race, not only after it:
    // bytes are reserved at admission (inside the capacity check), so a
    // save mid-write can never push the account past capacity, and its
    // uncommitted reservation is not evictable by a racing admission
    while handles.iter().any(|h| !h.is_finished()) {
        assert!(store.stored_bytes() <= store.capacity_bytes());
        std::thread::yield_now();
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(store.stored_bytes() <= store.capacity_bytes());
    assert!(store.entries() <= 2);
    // the account settles to exactly the committed entries — every
    // admission either committed or rolled its reservation back
    assert_eq!(store.stored_bytes(), store.entries() as u64 * 1024);
    let c = store.counters();
    assert_eq!(
        c.saves + c.save_skips,
        n_threads as u64 * rounds,
        "every save accounted exactly once: {c:?}"
    );
}

#[test]
fn chunked_restore_matches_full_restore_bit_for_bit() {
    // the pipelined warm start re-reads an entry as (layer, chunk)
    // units; those must reassemble to exactly the saved bytes — with
    // and without transient read faults in the way
    fn eventually<T>(what: &str, mut f: impl FnMut() -> anyhow::Result<T>) -> T {
        for _ in 0..50 {
            if let Ok(v) = f() {
                return v;
            }
        }
        panic!("{what}: transient faults never cleared in 50 attempts");
    }

    let lo = layout();
    for &(rate, seed) in &[(0.0, 0u64), (0.01, 7), (0.05, 11)] {
        let mem: Arc<dyn Backend> = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::new(
            mem,
            FaultConfig {
                rate,
                corruption_rate: 0.0,
                seed,
                persistent: false,
            },
        ));
        let store = PersistentStore::open_with_backend(
            &cfg_mem(1 << 20),
            DiskProfile::nvme(),
            lo.clone(),
            fb,
        )
        .unwrap();
        let tokens = tokens_for(16, 80);
        let rows = rows_for(&lo, 16, 81);
        assert_eq!(store.save(&tokens, &rows).unwrap(), 16);
        let m = store.lookup(&tokens).expect("saved prefix found");

        let full = eventually("full restore", || store.restore(&m, 16));
        let credited = store.counters().restored_tokens;
        for layer in 0..lo.n_layers {
            // 8-token chunks, assembled in order like the restore worker
            let mut k = Vec::new();
            let mut v = Vec::new();
            for c in 0..2 {
                let ch = eventually("chunk restore", || store.restore_chunk(&m, layer, c * 8, 8));
                assert_eq!((ch.layer, ch.start, ch.tokens), (layer, c * 8, 8));
                if rate == 0.0 {
                    assert!(ch.io_time > Duration::ZERO, "modeled read time surfaces");
                }
                k.extend_from_slice(&ch.k_rows);
                v.extend_from_slice(&ch.v_rows);
            }
            assert_eq!(bits(&k), bits(&full[layer].0), "rate {rate} layer {layer} K vs full");
            assert_eq!(bits(&v), bits(&full[layer].1), "rate {rate} layer {layer} V vs full");
            assert_eq!(bits(&k), bits(&rows[layer].0), "rate {rate} layer {layer} K vs saved");
            assert_eq!(bits(&v), bits(&rows[layer].1), "rate {rate} layer {layer} V vs saved");
        }
        // chunk reads never self-credit; the caller credits the
        // committed region once
        assert_eq!(store.counters().restored_tokens, credited);
        store.credit_restored(16);
        assert_eq!(store.counters().restored_tokens, credited + 16);
        // transient faults must not have quarantined anything
        assert_eq!(store.counters().quarantined, 0, "rate {rate}");
        assert_eq!(store.entries(), 1);
    }
}

#[test]
fn maintainer_gates_on_deadline_and_rotates_budget() {
    let lo = layout();
    let mut cfg = cfg_mem(1 << 20);
    cfg.scrub_interval_s = 3600.0;
    cfg.scrub_budget = 1;
    let store =
        PersistentStore::open(&cfg, DiskProfile::nvme(), &FaultConfig::default(), lo.clone())
            .unwrap();
    for s in 0..3u64 {
        assert_eq!(
            store.save(&tokens_for(8, 60 + s), &rows_for(&lo, 8, 70 + s)).unwrap(),
            8
        );
    }

    let now = Instant::now();
    // first pass runs immediately; a second call inside the interval is
    // gated
    let rep1 = store.maintain(now).expect("first pass due");
    assert_eq!(rep1.entries_scanned, 1, "budget of one entry per pass");
    assert!(store.maintain(now).is_none(), "deadline gates the next pass");

    // each deadline tick scrubs the next entry in rotation; after three
    // passes every record was scanned exactly once:
    // 3 entries x 2 layers x 2 groups = 12 records
    let rep2 = store.maintain(now + Duration::from_secs(3601)).expect("second pass");
    let rep3 = store.maintain(now + Duration::from_secs(7202)).expect("third pass");
    assert_eq!(rep2.entries_scanned, 1);
    assert_eq!(rep3.entries_scanned, 1);
    let c = store.counters();
    assert_eq!(c.scrub_passes, 3);
    assert_eq!(c.records_scrubbed, 12);
    assert_eq!(c.corruptions, 0);
}

#[test]
fn compaction_reclaims_freed_slots_and_keeps_restores_bit_identical() {
    let lo = layout();
    let mut cfg = cfg_mem(4096);
    cfg.compact_free_frac = 0.4;
    let mem = Arc::new(MemBackend::new());
    let store = PersistentStore::open_with_backend(
        &cfg,
        DiskProfile::nvme(),
        lo.clone(),
        mem.clone(),
    )
    .unwrap();

    // four 8-token entries fill slots 0..3 exactly
    let toks: Vec<Vec<i32>> = (0..4u64).map(|i| tokens_for(8, 200 + i)).collect();
    let rows: Vec<Vec<(Vec<f32>, Vec<f32>)>> =
        (0..4u64).map(|i| rows_for(&lo, 8, 210 + i)).collect();
    for (t, r) in toks.iter().zip(&rows) {
        assert_eq!(store.save(t, r).unwrap(), 8);
    }
    assert_eq!(store.entries(), 4);

    // a 16-token entry evicts two victims (A and B, after freshening C
    // and D) but reuses only one of their slots: one freed slot stays
    assert!(store.lookup(&toks[2]).is_some());
    assert!(store.lookup(&toks[3]).is_some());
    let (big_t, big_r) = (tokens_for(16, 300), rows_for(&lo, 16, 301));
    assert_eq!(store.save(&big_t, &big_r).unwrap(), 16);
    assert_eq!(store.entries(), 3);
    assert_eq!(store.compact_now(), 0, "1/4 freed is below the 0.4 gate");

    // quarantining D (still in slot 3) frees a second slot: 2/4 crosses
    let off = lo.offset(3, 0, 0);
    let mut b = [0u8; 1];
    mem.read_at(off + 3, &mut b).unwrap();
    mem.write_at(off + 3, &[b[0] ^ 0x01]).unwrap();
    assert_eq!(store.scrub_now(usize::MAX).quarantined, 1);
    assert_eq!(store.entries(), 2);

    // a pinned (in-restore) reader blocks the whole pass
    let mc = store.lookup(&toks[2]).unwrap();
    store.pin(mc.entry);
    assert_eq!(store.compact_now(), 0, "pinned reader must block compaction");
    store.unpin(mc.entry);

    let len_before = mem.len();
    let reclaimed = store.compact_now();
    assert!(reclaimed > 0, "2/4 freed must trigger compaction");
    assert!(mem.len() < len_before, "data file shrank");
    let c = store.counters();
    assert_eq!(c.compactions, 1);
    assert_eq!(c.reclaimed_bytes, reclaimed);
    assert_eq!(store.compact_now(), 0, "no freed slots left after the pass");

    // survivors restore bit-identically from their relocated slots
    let mc = store.lookup(&toks[2]).expect("C survived compaction");
    let got = store.restore(&mc, 8).unwrap();
    for (layer, (k, v)) in got.iter().enumerate() {
        assert_eq!(bits(k), bits(&rows[2][layer].0), "layer {layer} K moved intact");
        assert_eq!(bits(v), bits(&rows[2][layer].1), "layer {layer} V moved intact");
    }
    let mb = store.lookup(&big_t).expect("big entry survived compaction");
    let got = store.restore(&mb, 16).unwrap();
    for (layer, (k, v)) in got.iter().enumerate() {
        assert_eq!(bits(k), bits(&big_r[layer].0), "layer {layer} K moved intact");
        assert_eq!(bits(v), bits(&big_r[layer].1), "layer {layer} V moved intact");
    }
}

#[test]
fn compaction_is_crash_safe_across_reopen() {
    let dir = tmp_dir("compact");
    let lo = layout();
    let mut cfg = cfg_dir(&dir, 4096);
    cfg.compact_free_frac = 0.4;
    let fault = FaultConfig::default();
    let (b1_t, b1_r) = (tokens_for(16, 400), rows_for(&lo, 16, 401));
    let (b2_t, b2_r) = (tokens_for(16, 402), rows_for(&lo, 16, 403));
    {
        let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
        for s in 0..4u64 {
            assert_eq!(
                store.save(&tokens_for(8, 410 + s), &rows_for(&lo, 8, 420 + s)).unwrap(),
                8
            );
        }
        // each 16-token save evicts two small entries but takes only one
        // slot back: two freed slots remain and 2/4 crosses the gate
        assert_eq!(store.save(&b1_t, &b1_r).unwrap(), 16);
        assert_eq!(store.save(&b2_t, &b2_r).unwrap(), 16);
        assert_eq!(store.entries(), 2);
        // maintain() drives the pass: scrub batch first, then compaction
        assert!(store.maintain(Instant::now()).is_some());
        let c = store.counters();
        assert_eq!(c.compactions, 1, "maintain must compact past the gate: {c:?}");
        assert!(c.reclaimed_bytes > 0);
    }

    // "next process": the compacted manifest (remapped slots) and the
    // truncated data file agree, and the moved records verify
    let store = PersistentStore::open(&cfg, DiskProfile::nvme(), &fault, lo.clone()).unwrap();
    assert_eq!(store.entries(), 2);
    for (t, r) in [(&b1_t, &b1_r), (&b2_t, &b2_r)] {
        let m = store.lookup(t).expect("entry found after reopen");
        assert_eq!(m.tokens, 16);
        let got = store.restore(&m, 16).unwrap();
        for (layer, (k, v)) in got.iter().enumerate() {
            assert_eq!(bits(k), bits(&r[layer].0), "layer {layer} K after reopen");
            assert_eq!(bits(v), bits(&r[layer].1), "layer {layer} V after reopen");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
