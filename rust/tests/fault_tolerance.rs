//! Integration: fault tolerance of the disk pipeline and the engine.
//!
//! Disk-level tests run without artifacts: a seeded [`FaultBackend`]
//! injects transient I/O errors, latency spikes, short reads, silent bit
//! flips, and worker panics, and the prefetch pipeline must deliver
//! bit-identical bytes (or typed errors) under all of them. Engine-level
//! tests (artifact-gated) close the loop: decode output is bit-identical
//! under a 5% flaky disk, and a persistently failing disk degrades decode
//! instead of aborting it.

use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use kvswap::config::{FaultConfig, KvSwapConfig, PrefetchConfig, RetryConfig};
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::{
    Backend, BreakerState, DiskError, DiskProfile, Fault, FaultBackend, MemBackend, PlannedExtent,
    Prefetcher, PreloadPlan, RetryPolicy, SimDisk,
};
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(Manifest::load(dir).unwrap()).unwrap()))
}

// ---------------------------------------------------------------------
// disk-level (no artifacts needed)

const EXT_LEN: usize = 128;
/// Extents live at `i * EXT_STRIDE`, leaving a 128-byte hole between
/// neighbours so `coalesce_gap: 0` keeps every extent its own run (one
/// independent fault draw per extent).
const EXT_STRIDE: u64 = 256;

/// A `SimDisk` over a fault-injecting backend, with `n` checksummed
/// extents written through the legitimate write path (so the integrity
/// map is stamped). Returns the injector handle and the ground truth.
fn stamped_disk(cfg: FaultConfig, n: usize) -> (Arc<FaultBackend>, Arc<SimDisk>, Vec<Vec<u8>>) {
    let fb = Arc::new(FaultBackend::new(Arc::new(MemBackend::new()), cfg));
    let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), fb.clone(), None));
    let mut records = Vec::with_capacity(n);
    for i in 0..n {
        let rec: Vec<u8> = (0..EXT_LEN).map(|j| ((i * 131 + j * 17) % 251) as u8).collect();
        disk.write(i as u64 * EXT_STRIDE, &rec).unwrap();
        records.push(rec);
    }
    (fb, disk, records)
}

fn plan_for(layer: usize, ids: &[usize]) -> PreloadPlan {
    PreloadPlan {
        layer,
        per_seq: vec![(
            0,
            ids.iter()
                .map(|&i| PlannedExtent {
                    tag: i as u32,
                    offset: i as u64 * EXT_STRIDE,
                    len: EXT_LEN,
                })
                .collect(),
        )],
    }
}

#[test]
fn staging_is_bit_identical_under_probabilistic_faults() {
    // the issue's acceptance bar: a 5% flaky disk (plus 2% silent bit
    // flips) must not change a single staged byte
    let n_ext = 256;
    let (fb, disk, records) = stamped_disk(
        FaultConfig {
            rate: 0.05,
            corruption_rate: 0.02,
            seed: 7,
            persistent: false,
        },
        n_ext,
    );
    let pf_cfg = PrefetchConfig {
        workers: 2,
        queue_depth: 2,
        coalesce_gap: 0,
        dispatch_window: 1,
        ..PrefetchConfig::default()
    };
    let retry = RetryPolicy::new(RetryConfig {
        max_retries: 6,
        ..RetryConfig::default()
    });
    let mut p = Prefetcher::spawn_with(disk, &pf_cfg, retry);

    let n_plans = 64;
    for pi in 0..n_plans {
        let ids: Vec<usize> = (0..4).map(|k| (pi * 4 + k) % n_ext).collect();
        p.submit(plan_for(pi % 8, &ids)).unwrap();
        let staged = p.recv().unwrap_or_else(|e| panic!("plan {pi} failed: {e}"));
        assert_eq!(staged.layer, pi % 8);
        let (seq, chunks) = &staged.per_seq[0];
        assert_eq!(*seq, 0);
        assert_eq!(chunks.len(), ids.len());
        for ((tag, bytes), &id) in chunks.iter().zip(&ids) {
            assert_eq!(*tag, id as u32);
            assert_eq!(bytes, &records[id], "extent {id} bytes diverged (plan {pi})");
        }
    }

    let s = p.summary();
    let snap = fb.snapshot();
    assert_eq!(s.plans, n_plans as u64);
    assert_eq!(s.plans_failed, 0, "every plan must recover: {s:?}");
    // ~256 extent reads at a 7% combined rate: the odds of a fault-free
    // run are ~1e-8, so the recovery machinery demonstrably fired
    assert!(snap.total_injected() > 0, "injector idle over {} reads", snap.reads);
    assert!(s.io_retries >= 1, "recovery must have re-issued reads: {s:?}");
    // a flip is only *detected* when its run survives to verification
    // (a batch aborted by a sibling's EIO discards the flipped buffer)
    assert!(
        s.corrupt_detected <= snap.injected_flips,
        "detected {} flips but only {} were injected",
        s.corrupt_detected,
        snap.injected_flips
    );
}

#[test]
fn scripted_bit_flip_is_detected_and_healed_by_reread() {
    let (fb, disk, records) = stamped_disk(FaultConfig::default(), 8);
    fb.script_at(0, Fault::BitFlip);
    let pf_cfg = PrefetchConfig {
        workers: 0,
        queue_depth: 2,
        coalesce_gap: 0,
        dispatch_window: 1,
        ..PrefetchConfig::default()
    };
    let mut p = Prefetcher::spawn_with(disk.clone(), &pf_cfg, RetryPolicy::default());
    p.submit(plan_for(0, &[2])).unwrap();
    let staged = p.recv().unwrap();
    assert_eq!(staged.per_seq[0].1[0].1, records[2], "flip leaked to the caller");
    let s = p.summary();
    assert_eq!(s.corrupt_detected, 1, "checksum must catch the flip: {s:?}");
    assert!(s.io_retries >= 1);
    assert_eq!(disk.stats().snapshot().corruptions_detected, 1);
}

#[test]
fn persistent_silent_corruption_surfaces_typed_corrupt_error() {
    // corrupt the stored image *behind the checksum's back*: every
    // re-read returns the same wrong bytes, so the retry budget drains
    // and the typed Corrupt error reaches the caller
    let inner = Arc::new(MemBackend::new());
    let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), inner.clone(), None));
    let rec: Vec<u8> = (0..EXT_LEN).map(|i| (i * 3 % 255) as u8).collect();
    disk.write(512, &rec).unwrap();
    let mut b = [0u8; 1];
    inner.read_at(517, &mut b).unwrap();
    inner.write_at(517, &[b[0] ^ 0x40]).unwrap();

    let pf_cfg = PrefetchConfig {
        workers: 0,
        queue_depth: 1,
        coalesce_gap: 0,
        dispatch_window: 1,
        ..PrefetchConfig::default()
    };
    let retry = RetryPolicy::new(RetryConfig {
        max_retries: 2,
        backoff_base_ms: 0.05,
        backoff_max_ms: 0.2,
        ..RetryConfig::default()
    });
    let mut p = Prefetcher::spawn_with(disk, &pf_cfg, retry);
    p.submit(PreloadPlan {
        layer: 0,
        per_seq: vec![(
            0,
            vec![PlannedExtent {
                tag: 0,
                offset: 512,
                len: EXT_LEN,
            }],
        )],
    })
    .unwrap();
    match p.recv() {
        Err(DiskError::Corrupt { offset, .. }) => assert_eq!(offset, 512),
        other => panic!("expected Corrupt, got {other:?}"),
    }
    let s = p.summary();
    assert_eq!(s.plans_failed, 1);
    assert_eq!(s.io_retries, 3, "budget 2 = three re-issues of the bad run");
}

#[test]
fn breaker_opens_under_persistent_faults_and_recovers_after_heal() {
    let (fb, disk, records) = stamped_disk(FaultConfig::default(), 8);
    fb.poison(0, EXT_STRIDE * 8);
    let pf_cfg = PrefetchConfig {
        workers: 1,
        queue_depth: 2,
        coalesce_gap: 0,
        dispatch_window: 1,
        ..PrefetchConfig::default()
    };
    let retry = RetryPolicy::new(RetryConfig {
        max_retries: 0,
        backoff_base_ms: 0.05,
        backoff_max_ms: 0.2,
        breaker_threshold: 3,
        breaker_probe_after: 2,
        ..RetryConfig::default()
    });
    let mut p = Prefetcher::spawn_with(disk, &pf_cfg, retry);

    // threshold consecutive threaded failures trip the breaker
    for i in 0..3 {
        p.submit(plan_for(0, &[i])).unwrap();
        assert!(p.recv().is_err(), "poisoned read {i} must fail");
    }
    assert_eq!(p.breaker_state(), BreakerState::Open);

    fb.heal();
    // clean inline plans while open earn a half-open probe...
    for i in 0..2 {
        p.submit(plan_for(1, &[i])).unwrap();
        let staged = p.recv().unwrap();
        assert_eq!(staged.per_seq[0].1[0].1, records[i]);
    }
    assert_eq!(p.breaker_state(), BreakerState::Open, "probe not yet earned");
    // ...and the probe's success closes the breaker again
    p.submit(plan_for(2, &[5])).unwrap();
    assert!(p.recv().is_ok());
    assert_eq!(p.breaker_state(), BreakerState::Closed);

    let s = p.summary();
    assert_eq!(s.breaker_trips, 1);
    assert_eq!(s.plans_failed, 3);
}

#[test]
fn worker_panic_is_contained_and_shutdown_is_bounded() {
    let (fb, disk, records) = stamped_disk(FaultConfig::default(), 8);
    fb.script_at(0, Fault::Panic);
    let pf_cfg = PrefetchConfig {
        workers: 2,
        queue_depth: 2,
        coalesce_gap: 0,
        dispatch_window: 1,
        ..PrefetchConfig::default()
    };
    let mut p = Prefetcher::spawn_with(disk, &pf_cfg, RetryPolicy::disabled());
    p.submit(plan_for(0, &[1])).unwrap();
    match p.recv() {
        Err(DiskError::WorkerPanic { .. }) => {}
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
    // the panic cost that plan, not the pipeline
    p.submit(plan_for(1, &[3])).unwrap();
    let staged = p.recv().unwrap();
    assert_eq!(staged.per_seq[0].1[0].1, records[3]);
    assert_eq!(p.summary().worker_panics, 1);

    // bounded shutdown; afterwards the API reports closure, never hangs
    p.shutdown(Duration::from_secs(5));
    assert!(matches!(p.submit(plan_for(0, &[0])), Err(DiskError::QueueClosed)));
    assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
}

// ---------------------------------------------------------------------
// engine-level (artifact-gated)

fn engine_cfg(fault: FaultConfig, retry: RetryConfig) -> EngineConfig {
    EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .prefetch(PrefetchConfig::default())
        .fault(fault)
        .retry(retry)
        .max_context(1024)
        .seed(11)
        .build()
        .expect("valid test config")
}

#[test]
fn engine_output_is_bit_identical_under_transient_faults() {
    let Some(rt) = runtime() else { return };
    let steps = 6;
    let run = |fault: FaultConfig| {
        let retry = RetryConfig {
            max_retries: 6,
            ..RetryConfig::default()
        };
        let mut e = Engine::new(rt.clone(), engine_cfg(fault, retry)).unwrap();
        e.ingest_synthetic(&[320]).unwrap();
        e.decode(steps, true, None).unwrap()
    };
    let (clean_stats, clean_xs, clean_toks) = run(FaultConfig::default());
    let (f_stats, f_xs, f_toks) = run(FaultConfig {
        rate: 0.05,
        corruption_rate: 0.02,
        seed: 7,
        persistent: false,
    });

    assert_eq!(clean_toks, f_toks, "token trajectories diverged under faults");
    assert_eq!(clean_xs.len(), f_xs.len());
    for (step, (cx, fx)) in clean_xs.iter().zip(&f_xs).enumerate() {
        assert_eq!(cx.data, fx.data, "activations diverged at step {step}");
    }
    // transient faults are absorbed below the engine: nothing degrades
    assert_eq!(clean_stats.degraded_steps, 0);
    assert_eq!(f_stats.degraded_steps, 0, "retries must absorb transients: {:?}", f_stats.prefetch);
}

#[test]
fn engine_degrades_but_completes_under_persistent_faults() {
    let Some(rt) = runtime() else { return };
    // a majority-failing, poisoning disk: reads cannot be retried back to
    // health, so the engine must walk down the degradation ladder instead
    // of aborting — decode completes on resident state
    let fault = FaultConfig {
        rate: 0.5,
        corruption_rate: 0.0,
        seed: 3,
        persistent: true,
    };
    let retry = RetryConfig {
        max_retries: 1,
        backoff_base_ms: 0.05,
        backoff_max_ms: 0.2,
        breaker_threshold: 2,
        ..RetryConfig::default()
    };
    let mut e = Engine::new(rt.clone(), engine_cfg(fault, retry)).unwrap();
    e.ingest_synthetic(&[320]).unwrap();
    let steps = 8;
    let (stats, _, toks) = e
        .decode(steps, true, None)
        .expect("decode must survive a persistently failing disk");
    assert_eq!(stats.steps, steps as u64, "every step must complete");
    assert!(!toks.is_empty());
    assert!(
        stats.degraded_steps > 0,
        "persistent faults must show up as degraded layer-steps: {:?}",
        stats.prefetch
    );
}
