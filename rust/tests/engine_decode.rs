//! Integration: the full decode engine across policies.
//!
//! Checks that (a) every policy decodes end-to-end through the PJRT
//! artifacts, (b) KVSwap's selected-attention activations track the
//! Full-KV oracle closely, (c) the I/O orderings the paper claims hold
//! (grouped ≪ per-token bytes-on-wire; reuse reduces loads).

use std::rc::Rc;

use kvswap::config::KvSwapConfig;
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::DiskProfile;
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};
use kvswap::util::mathx;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(Manifest::load(dir).unwrap()).unwrap()))
}

fn cfg(policy: Policy, batch: usize, context: usize) -> EngineConfig {
    EngineConfig::builder()
        .preset("nano")
        .batch(batch)
        .policy(policy)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .max_context(context.max(512))
        .seed(7)
        .build()
        .expect("valid test config")
}

#[test]
fn kvswap_decodes_and_tracks_full_kv_oracle() {
    let Some(rt) = runtime() else { return };
    let steps = 12;
    let context = 512; // > MG + rb so selection is non-trivial

    // identical real prefills through the AOT artifacts (the SVD
    // adapters were calibrated on the real K distribution, so synthetic
    // isotropic KV would defeat the predictor by construction)
    let prompts: Vec<Vec<i32>> = (0..2)
        .map(|i| {
            let mut rng = kvswap::util::rng::Rng::new(100 + i);
            (0..context).map(|_| rng.below(512) as i32).collect()
        })
        .collect();

    let mut oracle = Engine::new(rt.clone(), cfg(Policy::FullMemory, 2, 2048)).unwrap();
    let of = oracle.prefill(&prompts).unwrap();
    let (ostats, oxs, otoks) = oracle.decode(steps, true, None).unwrap();
    assert_eq!(ostats.steps as usize, steps);

    let mut kv = Engine::new(rt.clone(), cfg(Policy::KvSwap, 2, 2048)).unwrap();
    let kf = kv.prefill(&prompts).unwrap();
    assert_eq!(of, kf, "prefill first tokens must agree");
    // teacher-forced on the oracle trajectory: per-step activation
    // fidelity then measures pure attention-approximation error
    let (kstats, kxs, _) = kv.decode(steps, true, Some(&otoks)).unwrap();
    assert_eq!(kstats.steps as usize, steps);
    assert!(kstats.tokens == 2 * steps as u64);

    // activations track the oracle (selected attention ≈ full attention)
    let mut cos_sum = 0.0;
    let mut n = 0;
    for (ox, kx) in oxs.iter().zip(&kxs) {
        for b in 0..2 {
            cos_sum += mathx::cosine(ox.row(&[b]), kx.row(&[b])) as f64;
            n += 1;
        }
    }
    let mean_cos = cos_sum / n as f64;
    assert!(
        mean_cos > 0.7,
        "kvswap diverged from oracle: mean cosine {mean_cos}"
    );

    // kvswap moved far fewer bytes than the full cache per step
    let full_bytes_per_step = kv.spec().kv_cache_bytes(2, context);
    assert!(kstats.bytes_loaded < full_bytes_per_step * steps as u64 / 2);
    // reuse is active
    assert!(kstats.reuse_rate.unwrap_or(0.0) > 0.3, "reuse {:?}", kstats.reuse_rate);
}

#[test]
fn every_policy_decodes() {
    let Some(rt) = runtime() else { return };
    for policy in [
        Policy::KvSwap,
        Policy::FlexGen,
        Policy::InfiniGen {
            head_agg: false,
            reuse: false,
        },
        Policy::InfiniGen {
            head_agg: true,
            reuse: false,
        },
        Policy::InfiniGen {
            head_agg: true,
            reuse: true,
        },
        Policy::Loki,
        Policy::ShadowKv { chunk: 8, rank: 32 },
        Policy::FullMemory,
    ] {
        let name = policy.name();
        let mut e = Engine::new(rt.clone(), cfg(policy, 1, 1024)).unwrap();
        e.ingest_synthetic(&[320]).unwrap();
        let (stats, _, _) = e.decode(4, false, None).unwrap_or_else(|err| panic!("{name}: {err}"));
        assert_eq!(stats.steps, 4, "{name}");
        assert!(stats.seconds > 0.0, "{name}");
        assert!(stats.tokens_per_sec() > 0.0, "{name}");
    }
}

#[test]
fn grouped_loads_move_fewer_bytes_than_token_granular() {
    let Some(rt) = runtime() else { return };
    let steps = 6;
    let context = 512;

    let run = |policy: Policy| {
        let mut e = Engine::new(rt.clone(), cfg(policy, 1, 1024)).unwrap();
        e.ingest_synthetic(&[context]).unwrap();
        let (stats, _, _) = e.decode(steps, false, None).unwrap();
        let snap = e.disk.stats().snapshot();
        (stats, snap)
    };

    let (_kv_stats, kv_snap) = run(Policy::KvSwap);
    let (_ig_stats, ig_snap) = run(Policy::InfiniGen {
        head_agg: true,
        reuse: false,
    });
    // same entry budget, but per-token access amplifies physical reads
    assert!(
        ig_snap.physical_read_bytes > kv_snap.physical_read_bytes,
        "infinigen* {} vs kvswap {}",
        ig_snap.physical_read_bytes,
        kv_snap.physical_read_bytes
    );
    // and needs many more read ops
    assert!(ig_snap.read_ops > kv_snap.read_ops * 2);
}

#[test]
fn reuse_buffer_cuts_disk_traffic() {
    let Some(rt) = runtime() else { return };
    let context = 512;
    let steps = 8;

    let mut with = Engine::new(rt.clone(), cfg(Policy::KvSwap, 1, 1024)).unwrap();
    with.ingest_synthetic(&[context]).unwrap();
    let (wstats, _, _) = with.decode(steps, false, None).unwrap();

    let mut cfg_no = cfg(Policy::KvSwap, 1, 1024);
    cfg_no.kv.use_reuse = false;
    let mut without = Engine::new(rt.clone(), cfg_no).unwrap();
    without.ingest_synthetic(&[context]).unwrap();
    let (nstats, _, _) = without.decode(steps, false, None).unwrap();

    assert!(
        wstats.bytes_loaded * 2 < nstats.bytes_loaded,
        "reuse {} vs no-reuse {}",
        wstats.bytes_loaded,
        nstats.bytes_loaded
    );
    assert!(wstats.reuse_rate.is_some());
    assert!(nstats.reuse_rate.is_none());
}

#[test]
fn flexgen_loads_everything_every_step() {
    let Some(rt) = runtime() else { return };
    let context = 512;
    let steps = 3;
    let mut e = Engine::new(rt.clone(), cfg(Policy::FlexGen, 1, 1024)).unwrap();
    e.ingest_synthetic(&[context]).unwrap();
    let (stats, _, _) = e.decode(steps, false, None).unwrap();
    // every step reads ~the whole flushed cache for every layer
    let spec = e.spec().clone();
    let per_step_min = spec.kv_cache_bytes(1, context - 64); // allow RB slack
    assert!(
        stats.bytes_loaded >= per_step_min * steps as u64,
        "flexgen bytes {} < {}",
        stats.bytes_loaded,
        per_step_min * steps as u64
    );
}

#[test]
fn emmc_is_slower_than_nvme_for_kvswap() {
    let Some(rt) = runtime() else { return };
    let context = 512;
    let steps = 6;
    let run = |disk: DiskProfile| {
        let mut c = cfg(Policy::KvSwap, 1, 1024);
        c.disk = disk;
        let mut e = Engine::new(rt.clone(), c).unwrap();
        e.ingest_synthetic(&[context]).unwrap();
        let (stats, _, _) = e.decode(steps, false, None).unwrap();
        let busy = e.disk.stats().snapshot().read_busy;
        (stats.tokens_per_sec(), busy)
    };
    let (nvme_tps, nvme_busy) = run(DiskProfile::nvme());
    let (emmc_tps, emmc_busy) = run(DiskProfile::emmc());
    // the modeled device time is strictly ordered; throughput only
    // within a noise margin (at this size both disks hide under compute,
    // especially in debug builds)
    assert!(
        emmc_busy > nvme_busy,
        "emmc busy {emmc_busy:?} should exceed nvme {nvme_busy:?}"
    );
    assert!(
        nvme_tps >= emmc_tps * 0.8,
        "nvme {nvme_tps} well below emmc {emmc_tps}"
    );
}
