//! Integration: the unified priority I/O scheduler — cross-plan merge
//! correctness when Critical and Warm plans interleave over overlapping
//! and duplicate extents, and Background progress (aging promotion)
//! under a sustained Critical backlog.
//!
//! These tests need no AOT artifacts — they drive the scheduler directly
//! against a gated in-memory backend.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use kvswap::config::{PrefetchConfig, RetryConfig};
use kvswap::disk::prefetch::PrefetchCounters;
use kvswap::disk::{
    Backend, DiskProfile, DiskResult, IoRequest, IoScheduler, Lane, MemBackend, RetryPolicy,
    SimDisk,
};
use kvswap::util::rng::Rng;

/// Backend whose reads block until the gate opens (writes pass). Parking
/// the single worker mid-read lets a test queue plans *behind* it, so
/// dispatch-window membership is decided over a fully populated queue —
/// deterministic, not a race against the worker.
struct GatedBackend {
    inner: MemBackend,
    gate: Mutex<bool>,
    cv: Condvar,
}

impl GatedBackend {
    fn new() -> Arc<GatedBackend> {
        Arc::new(GatedBackend {
            inner: MemBackend::new(),
            gate: Mutex::new(false),
            cv: Condvar::new(),
        })
    }

    /// One-way latch: every blocked and future read proceeds.
    fn open(&self) {
        *self.gate.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

impl Backend for GatedBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> DiskResult<()> {
        let mut open = self.gate.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
        drop(open);
        self.inner.read_at(offset, buf)
    }
    fn write_at(&self, offset: u64, data: &[u8]) -> DiskResult<()> {
        self.inner.write_at(offset, data)
    }
    fn len(&self) -> u64 {
        self.inner.len()
    }
}

fn cfg(workers: usize, depth: usize, window: usize, aging_ms: u64) -> PrefetchConfig {
    PrefetchConfig {
        workers,
        queue_depth: depth,
        coalesce_gap: 64,
        dispatch_window: window,
        aging_ms,
        unified_io: true,
    }
}

fn retry() -> RetryPolicy {
    RetryPolicy::new(RetryConfig {
        max_retries: 2,
        backoff_base_ms: 0.05,
        backoff_max_ms: 0.2,
        ..RetryConfig::default()
    })
}

fn req(disk: &Arc<SimDisk>, lane: Lane, extents: &[(u64, usize)]) -> IoRequest {
    IoRequest {
        lane,
        disk: disk.clone(),
        extents: extents.to_vec(),
        counters: Arc::new(PrefetchCounters::default()),
    }
}

fn gated_disk(n: usize, salt: usize) -> (Arc<GatedBackend>, Arc<SimDisk>, Vec<u8>) {
    let gate = GatedBackend::new();
    let image: Vec<u8> = (0..n).map(|i| ((i * 131 + salt * 11) % 251) as u8).collect();
    gate.write_at(0, &image).unwrap();
    let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), gate.clone(), None));
    (gate, disk, image)
}

#[test]
fn merged_plans_serve_every_extent_once_bit_identically() {
    let (gate, disk, image) = gated_disk(32 * 1024, 0);
    let s = IoScheduler::new(&cfg(1, 8, 4, 10_000), retry());

    // park the single worker on a far-away plug read; nothing can merge
    // with it (no combined-run saving), so the plans queued behind it
    // form their dispatch groups only after the gate opens
    let plug = s.submit(req(&disk, Lane::Critical, &[(16 * 1024, 64)])).unwrap();

    // Critical and Warm plans over overlapping and duplicate extents
    let plans: Vec<(Lane, Vec<(u64, usize)>)> = vec![
        (Lane::Critical, vec![(0, 128), (128, 128)]),
        (Lane::Warm, vec![(256, 128), (0, 128)]),
        (Lane::Critical, vec![(384, 128)]),
        (Lane::Warm, vec![(128, 128), (384, 128)]),
    ];
    let tickets: Vec<_> = plans
        .iter()
        .map(|(lane, ex)| s.submit(req(&disk, *lane, ex)).unwrap())
        .collect();
    gate.open();
    let _ = s.wait(plug, Duration::from_secs(5)).unwrap();
    for (t, (_, ex)) in tickets.into_iter().zip(&plans) {
        let c = s.wait(t, Duration::from_secs(5)).unwrap();
        assert_eq!(c.chunks.len(), ex.len(), "one chunk per extent, in plan order");
        for (chunk, &(off, len)) in c.chunks.iter().zip(ex) {
            assert_eq!(chunk, &image[off as usize..off as usize + len]);
        }
    }
    let ls = s.lane_summary();
    // the worker pops the first Critical plan and pulls both Warm plans
    // into its window (each strictly lowers the combined run count: the
    // four extents 0..512 collapse to one sequential run); the second
    // Critical plan is too far from the group to profit and runs alone
    assert_eq!(ls.cross_plan_merges, 2, "window membership is deterministic");
    assert_eq!(ls.lane_dispatched[Lane::Critical.idx()], 3);
    assert_eq!(ls.lane_dispatched[Lane::Warm.idx()], 2);
}

#[test]
fn interleaved_plans_are_bit_identical_across_window_shapes() {
    // property sweep: whatever the window decides to merge — duplicates,
    // overlaps, nothing — every extent of every plan must come back
    // exactly once, in plan order, with the stored bytes
    let mut rng = Rng::new(1234);
    for round in 0..6usize {
        let (gate, disk, image) = gated_disk(16 * 1024, round);
        let window = 2 + round % 3;
        let s = IoScheduler::new(&cfg(1, 16, window, 10_000), retry());
        let plug = s.submit(req(&disk, Lane::Critical, &[(12 * 1024, 64)])).unwrap();

        let plans: Vec<(Lane, Vec<(u64, usize)>)> = (0..8usize)
            .map(|pi| {
                let lane = if pi % 2 == 0 { Lane::Critical } else { Lane::Warm };
                let extents = (0..1 + rng.below(3))
                    .map(|_| (rng.below(64) as u64 * 128, 128))
                    .collect();
                (lane, extents)
            })
            .collect();
        let tickets: Vec<_> = plans
            .iter()
            .map(|(lane, ex)| s.submit(req(&disk, *lane, ex)).unwrap())
            .collect();
        gate.open();
        let _ = s.wait(plug, Duration::from_secs(5)).unwrap();
        for (pi, (t, (_, ex))) in tickets.into_iter().zip(&plans).enumerate() {
            let c = s.wait(t, Duration::from_secs(5)).unwrap();
            assert_eq!(c.chunks.len(), ex.len(), "round {round} plan {pi}");
            for (ei, (chunk, &(off, len))) in c.chunks.iter().zip(ex).enumerate() {
                assert_eq!(
                    chunk,
                    &image[off as usize..off as usize + len],
                    "round {round} plan {pi} extent {ei} diverged"
                );
            }
        }
    }
}

#[test]
fn background_completes_and_is_aged_past_sustained_critical_load() {
    let (gate, disk, image) = gated_disk(32 * 1024, 3);
    let s = IoScheduler::new(&cfg(1, 8, 1, 10), retry());

    // park the worker, then queue a critical backlog ahead of one
    // background read; strict priority alone would hold it last
    let plug = s.submit(req(&disk, Lane::Critical, &[(0, 64)])).unwrap();
    let crit: Vec<_> = (1..=4u64)
        .map(|i| s.submit(req(&disk, Lane::Critical, &[(i * 1024, 64)])).unwrap())
        .collect();
    let tb = s.submit(req(&disk, Lane::Background, &[(24 * 1024, 64)])).unwrap();
    // age the background head past the 10 ms bound while everything waits
    std::thread::sleep(Duration::from_millis(60));
    gate.open();

    let c = s.wait(tb, Duration::from_secs(5)).unwrap();
    assert_eq!(c.chunks[0], &image[24 * 1024..24 * 1024 + 64]);
    for t in crit {
        let _ = s.wait(t, Duration::from_secs(5)).unwrap();
    }
    let _ = s.wait(plug, Duration::from_secs(5)).unwrap();
    let ls = s.lane_summary();
    assert!(
        ls.aged_promotions >= 1,
        "aged background head must preempt the critical backlog: {ls:?}"
    );
    assert_eq!(ls.lane_dispatched[Lane::Background.idx()], 1);
    assert_eq!(ls.lane_dispatched[Lane::Critical.idx()], 5);
}
