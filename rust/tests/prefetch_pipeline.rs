//! Integration: the threaded prefetch pipeline against the synchronous
//! read path. A latency-injecting backend makes device time real, so the
//! pipeline must (a) produce bit-identical output to the synchronous
//! baseline and (b) hide most of the injected read latency behind
//! compute. Plus property tests of the coalescer's byte-exactness that
//! run without artifacts.

use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use kvswap::config::{KvSwapConfig, PrefetchConfig};
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::prefetch::{read_coalesced, PrefetchCounters};
use kvswap::disk::{
    Backend, BufferPool, DiskError, DiskProfile, DiskResult, MemBackend, ReadReq, SimDisk,
    StorageBackend,
};
use kvswap::metrics::Phase;
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};
use kvswap::util::rng::Rng;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(Manifest::load(dir).unwrap()).unwrap()))
}

/// A backend that sleeps on every read — real latency without a real
/// slow device, so overlap is physically measurable in a test.
struct SlowBackend {
    inner: MemBackend,
    delay: Duration,
}

impl SlowBackend {
    fn new(delay: Duration) -> SlowBackend {
        SlowBackend {
            inner: MemBackend::new(),
            delay,
        }
    }
}

impl Backend for SlowBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> DiskResult<()> {
        std::thread::sleep(self.delay);
        self.inner.read_at(offset, buf)
    }

    fn write_at(&self, offset: u64, data: &[u8]) -> DiskResult<()> {
        self.inner.write_at(offset, data)
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }
    // read_batch: default impl — one injected delay per coalesced run
}

fn slow_cfg(prefetch: PrefetchConfig, delay: Duration) -> EngineConfig {
    EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .storage(StorageBackend::Custom(Arc::new(SlowBackend::new(delay))))
        .prefetch(prefetch)
        // real clock so the injected latency is physically measured, but
        // scale 0 so the *modeled* device time adds no extra sleeping
        .real_time(true)
        .time_scale(0.0)
        .max_context(1024)
        .seed(11)
        .build()
        .expect("valid test config")
}

#[test]
fn prefetch_pipeline_is_bit_identical_and_hides_latency() {
    let Some(rt) = runtime() else { return };
    let steps = 6;
    let delay = Duration::from_micros(300);

    let run = |prefetch: PrefetchConfig| {
        let mut e = Engine::new(rt.clone(), slow_cfg(prefetch, delay)).unwrap();
        e.ingest_synthetic(&[320]).unwrap();
        let (stats, xs, toks) = e.decode(steps, true, None).unwrap();
        (stats, xs, toks)
    };
    let (sync_stats, sync_xs, sync_toks) = run(PrefetchConfig::synchronous());
    let (pf_stats, pf_xs, pf_toks) = run(PrefetchConfig::default());

    // (a) threading must not change a single bit of the computation
    assert_eq!(sync_toks, pf_toks, "token trajectories diverged");
    assert_eq!(sync_xs.len(), pf_xs.len());
    for (step, (sx, px)) in sync_xs.iter().zip(&pf_xs).enumerate() {
        assert_eq!(sx.data, px.data, "activations diverged at step {step}");
    }
    // both pipelines staged real work (counters may differ by the one
    // trailing layer-0 plan that only the threaded pool executes eagerly)
    assert!(sync_stats.prefetch.plans > 0);
    assert!(pf_stats.prefetch.plans >= sync_stats.prefetch.plans);
    assert!(pf_stats.prefetch.bytes_staged >= sync_stats.prefetch.bytes_staged);

    // (b) the injected latency is hidden behind compute: the residual
    // stall must be well below the synchronous pipeline's, which pays
    // one delay per issued read inline
    let sync_wait = sync_stats.breakdown.get(Phase::IoWait);
    let pf_wait = pf_stats.breakdown.get(Phase::IoWait);
    let total_read_time = delay * sync_stats.prefetch.runs as u32;
    assert!(
        sync_wait >= total_read_time / 2,
        "sync baseline should pay the injected latency: waited {sync_wait:?} \
         of {total_read_time:?} injected"
    );
    assert!(
        pf_wait < sync_wait / 2,
        "prefetch hid too little: {pf_wait:?} vs sync {sync_wait:?}"
    );
    assert!(
        pf_wait < total_read_time,
        "prefetch residual {pf_wait:?} not below total read time {total_read_time:?}"
    );
}

// ---------------------------------------------------------------------
// coalescing byte-exactness (no artifacts needed)

#[test]
fn coalesced_reads_are_byte_exact_under_random_plans() {
    let mut rng = Rng::new(0xC0A1);
    let image_len = 1 << 16;
    let image: Vec<u8> = (0..image_len).map(|_| rng.below(256) as u8).collect();
    let backend = Arc::new(MemBackend::new());
    backend.write_at(0, &image).unwrap();
    let disk = SimDisk::new(DiskProfile::nvme(), backend, None);
    let pool = BufferPool::new(8);
    let counters = PrefetchCounters::default();

    for case in 0..40 {
        let gap = [0u64, 1, 64, 4096][case % 4];
        let n = rng.range(1, 24);
        let extents: Vec<(u64, usize)> = (0..n)
            .map(|_| {
                let len = rng.range(1, 700);
                let off = rng.below(image_len - len) as u64;
                (off, len)
            })
            .collect();
        let (chunks, _) = read_coalesced(&disk, &extents, gap, &pool, &counters)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(chunks.len(), extents.len());
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(
                chunks[i],
                &image[off as usize..off as usize + len],
                "case {case} extent {i} at {off}+{len} (gap {gap})"
            );
        }
    }
    let s = counters.summary();
    assert!(s.runs <= s.extents, "coalescing can only merge");
    assert!(s.coalesce_factor() >= 1.0);
}

#[test]
fn out_of_bounds_requests_error_instead_of_panicking() {
    let backend = Arc::new(MemBackend::new());
    backend.write_at(0, &[7u8; 128]).unwrap();
    let disk = SimDisk::new(DiskProfile::nvme(), backend.clone(), None);

    // adversarial offsets near u64::MAX must not wrap into a panic
    let mut buf = [0u8; 16];
    assert!(matches!(
        backend.read_at(u64::MAX - 8, &mut buf),
        Err(DiskError::OutOfBounds { .. })
    ));
    let mut reqs = vec![ReadReq::new(0, 16), ReadReq::new(u64::MAX - 2, 8)];
    assert!(matches!(
        disk.read_batch(&mut reqs),
        Err(DiskError::OutOfBounds { .. })
    ));
    // and an in-bounds batch still works afterwards
    let mut ok = vec![ReadReq::new(64, 32)];
    disk.read_batch(&mut ok).unwrap();
    assert!(ok[0].buf.iter().all(|&b| b == 7));
}
