//! Integration: pipelined warm-start restores through the engine —
//! bit-identity against blocking and cold prefill (with and without
//! fault injection), chunk-granular degrade on a torn record, and the
//! router's wave-failure containment + padding-pollution fixes.
//!
//! Needs AOT artifacts (each test skips without them, like the other
//! engine-level suites).

use std::rc::Rc;
use std::sync::Arc;

use kvswap::config::{FaultConfig, KvSwapConfig, StoreConfig};
use kvswap::coordinator::batcher::BatcherConfig;
use kvswap::coordinator::router::Router;
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::{Backend, DiskProfile, MemBackend};
use kvswap::kvcache::DiskLayout;
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};
use kvswap::store::PersistentStore;
use kvswap::util::rng::Rng;
use kvswap::workload::tracegen::Request;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(Manifest::load(dir).unwrap()).unwrap()))
}

fn store_cfg() -> StoreConfig {
    StoreConfig {
        enabled: true,
        dir: None,
        capacity_bytes: 64 << 20,
        scrub_interval_s: 3600.0,
        scrub_budget: 4,
        pipelined_restore: true,
        compact_free_frac: 1.0,
    }
}

fn cfg(max_context: usize) -> EngineConfig {
    let mut c = EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .max_context(max_context)
        .build()
        .expect("valid test config");
    c.store = store_cfg();
    c
}

/// Prompt geometry: a few chunks, clamped to the prefill artifact.
fn prompt_for(rt: &PjrtRuntime, seed: u64) -> (Vec<i32>, usize, usize) {
    let info = &rt.manifest.presets["nano"];
    let chunk = info.prefill_chunk;
    let n_chunks = (info.prefill_ncap / chunk).clamp(2, 4);
    let s_len = n_chunks * chunk;
    let mut rng = Rng::new(seed);
    let prompt = (0..s_len).map(|_| rng.below(info.spec.vocab) as i32).collect();
    (prompt, s_len, chunk)
}

#[test]
fn pipelined_restore_is_bit_identical_and_overlapped() {
    let Some(rt) = runtime() else { return };
    let (prompt, s_len, chunk) = prompt_for(&rt, 42);
    let base = cfg(s_len);

    let mut cold = Engine::new(rt.clone(), base.clone()).unwrap();
    let first_cold = cold.prefill(&[prompt.clone()]).unwrap();
    assert!(cold.prefill_io_overlap_ratio().is_none(), "cold run never restored");
    let store = cold.store().expect("store open");

    let mut blk_cfg = base.clone();
    blk_cfg.store.pipelined_restore = false;
    let mut blocking = Engine::with_store(rt.clone(), blk_cfg, Some(store.clone())).unwrap();
    let first_blk = blocking.prefill(&[prompt.clone()]).unwrap();

    let mut pipelined = Engine::with_store(rt.clone(), base, Some(store.clone())).unwrap();
    let first_pipe = pipelined.prefill(&[prompt.clone()]).unwrap();

    assert_eq!(first_cold, first_blk, "blocking restore diverged from cold");
    assert_eq!(first_cold, first_pipe, "pipelined restore diverged from cold");
    // both warm modes reuse everything but the final (recomputed) chunk
    assert_eq!(blocking.reused_prefix_tokens() as usize, s_len - chunk);
    assert_eq!(pipelined.reused_prefix_tokens() as usize, s_len - chunk);
    // nothing hides a blocking restore; the worker hides at least some
    // of the pipelined one
    let blk = blocking.prefill_io_overlap_ratio().expect("blocking warm ran");
    let pipe = pipelined.prefill_io_overlap_ratio().expect("pipelined warm ran");
    assert!(blk < 0.05, "blocking restore claims overlap: {blk:.3}");
    assert!(pipe > 0.0, "pipelined restore hid nothing: {pipe:.3}");
}

#[test]
fn pipelined_restore_stays_bit_identical_under_faults() {
    let Some(rt) = runtime() else { return };
    for &(rate, seed) in &[(0.01f64, 7u64), (0.05, 11)] {
        let (prompt, s_len, _) = prompt_for(&rt, 43 + seed);
        let mut base = cfg(s_len);
        base.fault = FaultConfig {
            rate,
            corruption_rate: 0.0,
            seed,
            persistent: false,
        };

        let mut cold = Engine::new(rt.clone(), base.clone()).unwrap();
        let first_cold = cold.prefill(&[prompt.clone()]).unwrap();
        let store = cold.store().expect("store open");

        let mut blk_cfg = base.clone();
        blk_cfg.store.pipelined_restore = false;
        let mut blocking = Engine::with_store(rt.clone(), blk_cfg, Some(store.clone())).unwrap();
        let first_blk = blocking.prefill(&[prompt.clone()]).unwrap();

        let mut pipelined = Engine::with_store(rt.clone(), base, Some(store)).unwrap();
        let first_pipe = pipelined.prefill(&[prompt.clone()]).unwrap();

        // under transient faults a restore may tear and recompute more —
        // the produced tokens must not change either way
        assert_eq!(first_cold, first_blk, "rate {rate}: blocking diverged");
        assert_eq!(first_cold, first_pipe, "rate {rate}: pipelined diverged");
    }
}

#[test]
fn torn_chunk_degrades_at_chunk_granularity() {
    let Some(rt) = runtime() else { return };
    let (prompt, s_len, chunk) = prompt_for(&rt, 44);
    let base = cfg(s_len);

    // build the store over an inspectable backend, replicating the
    // engine's slot geometry (Engine::with_store checks the match)
    let info = &rt.manifest.presets["nano"];
    let layout = DiskLayout::new(
        info.spec.kv_flat_dim(),
        base.kv.group_size,
        base.max_context + 1024,
        info.spec.n_layers,
        DiskProfile::nvme().page_bytes.min(4096),
    );
    let mem = Arc::new(MemBackend::new());
    let store = Arc::new(
        PersistentStore::open_with_backend(
            &store_cfg(),
            DiskProfile::nvme(),
            layout.clone(),
            mem.clone(),
        )
        .unwrap(),
    );

    let mut cold = Engine::with_store(rt.clone(), base.clone(), Some(store.clone())).unwrap();
    let first_cold = cold.prefill(&[prompt.clone()]).unwrap();
    assert_eq!(store.entries(), 1, "cold prefill persisted the prompt");

    // rot one byte of the record backing warm chunk 1 of layer 0 (the
    // first save of a fresh store lands in slot 0)
    let gi = chunk / layout.group;
    let off = layout.offset(0, 0, gi);
    let mut b = [0u8; 1];
    mem.read_at(off + 3, &mut b).unwrap();
    mem.write_at(off + 3, &[b[0] ^ 0xFF]).unwrap();

    let mut warm = Engine::with_store(rt.clone(), base, Some(store.clone())).unwrap();
    let first_warm = warm.prefill(&[prompt.clone()]).unwrap();

    // the tear at chunk 1 discards the warm region from there on but
    // keeps chunk 0 — partial reuse, not a cold fallback
    assert_eq!(first_cold, first_warm, "degraded restore diverged");
    assert_eq!(
        warm.reused_prefix_tokens() as usize,
        chunk,
        "expected exactly the pre-tear chunk reused"
    );
    let c = store.counters();
    assert!(c.corruptions >= 1, "corruption detected and logged: {c:?}");
    assert_eq!(c.restored_tokens as usize, chunk, "credit only what survived");
    let sites = store.corruption_sites();
    assert!(
        sites.iter().any(|s| s.layer == 0 && s.group == gi),
        "corruption site pins the rotten record: {sites:?}"
    );
}

#[test]
fn router_survives_a_failed_wave() {
    let Some(_) = runtime() else { return };
    let engine_cfg = EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .max_context(1024)
        .build()
        .expect("valid router config");
    // the batcher admits far more context than the engine can prefill,
    // so the oversized request fails inside the wave, not at the door
    let batcher_cfg = BatcherConfig {
        supported: vec![1],
        linger_s: 0.01,
        max_context: 1 << 20,
    };
    let router = Router::spawn(default_artifacts_dir(), engine_cfg, batcher_cfg);

    router.submit(Request {
        id: 1,
        context: 1 << 19, // over any compiled prefill capacity
        decode: 2,
        arrival_s: 0.0,
        seed: 1,
        tokens: None,
    });
    router.flush();
    let c = router
        .recv_timeout(std::time::Duration::from_secs(300))
        .expect("error completion for the failed wave");
    assert_eq!(c.id, 1);
    assert!(c.tokens.is_empty());
    assert!(
        c.error.as_deref().is_some_and(|e| e.contains("too long")),
        "error surfaces the cause: {:?}",
        c.error
    );

    // the session keeps serving after the failure
    router.submit(Request {
        id: 2,
        context: 256,
        decode: 3,
        arrival_s: 0.0,
        seed: 2,
        tokens: None,
    });
    router.flush();
    let c2 = router
        .recv_timeout(std::time::Duration::from_secs(300))
        .expect("completion after the failed wave");
    assert_eq!(c2.id, 2);
    assert_eq!(c2.tokens.len(), 3);
    assert!(c2.error.is_none());

    let s = router.stats().expect("stats after failure");
    assert_eq!(s.usize_or("waves", 0), 2);
    assert_eq!(s.usize_or("wave_errors", 0), 1);
    router.stop().unwrap();
}

#[test]
fn ragged_wave_padding_never_reaches_the_store() {
    let Some(_) = runtime() else { return };
    let mut engine_cfg = EngineConfig::builder()
        .preset("nano")
        .batch(1)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(DiskProfile::nvme())
        .max_context(1024)
        .build()
        .expect("valid router config");
    engine_cfg.store = store_cfg();
    // force one wave of batch 4 out of three ragged requests: the
    // fourth row is all-zero padding and the short rows get zero tails
    let batcher_cfg = BatcherConfig {
        supported: vec![4],
        linger_s: 0.01,
        max_context: 1024,
    };
    let router = Router::spawn(default_artifacts_dir(), engine_cfg, batcher_cfg);
    for (id, context) in [(1u64, 256usize), (2, 256), (3, 320)] {
        router.submit(Request {
            id,
            context,
            decode: 2,
            arrival_s: 0.0,
            seed: id,
            tokens: None,
        });
    }
    router.flush();
    for _ in 0..3 {
        let c = router
            .recv_timeout(std::time::Duration::from_secs(300))
            .expect("completion");
        assert!(c.error.is_none());
        assert_eq!(c.batch, 4);
    }
    let s = router.stats().expect("stats");
    let store = s.get("store").expect("store counters present");
    // one save per real request — unpadded prefixes only — and the
    // padding row counted as an explicit skip
    assert_eq!(store.usize_or("saves", 0), 3);
    assert_eq!(store.usize_or("pad_skips", 0), 1);
    assert_eq!(s.usize_or("wave_errors", 9), 0);
    router.stop().unwrap();
}
