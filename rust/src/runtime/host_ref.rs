//! Pure-Rust f32 reference transformer — the host-side oracle.
//!
//! Mirrors `python/compile/model.py` exactly (RMSNorm / RoPE / GQA /
//! SwiGLU, same weight tensors). Used by integration tests to validate
//! the HLO artifacts' numerics end-to-end, by the quality harness as the
//! Full-KV oracle, and by unit tests that need model-shaped data without
//! a PJRT client. Everything here is per-sequence (no batch dim).

use std::collections::HashMap;
use std::rc::Rc;

use crate::config::ModelSpec;
use crate::runtime::tensor::Tensor;
use crate::util::mathx;

pub const NEG_INF: f32 = -1e9;

/// Per-layer KV cache rows: token-major, row = all KV heads concatenated
/// (`Hkv * d` floats) — the same flattened layout §3.2 compresses and the
/// disk layout stores.
#[derive(Debug, Clone, Default)]
pub struct KvLayer {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub row: usize,
}

impl KvLayer {
    pub fn new(row: usize) -> KvLayer {
        KvLayer {
            k: Vec::new(),
            v: Vec::new(),
            row,
        }
    }

    pub fn len(&self) -> usize {
        self.k.len() / self.row
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.row);
        assert_eq!(v.len(), self.row);
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
    }

    pub fn k_row(&self, n: usize) -> &[f32] {
        &self.k[n * self.row..(n + 1) * self.row]
    }

    pub fn v_row(&self, n: usize) -> &[f32] {
        &self.v[n * self.row..(n + 1) * self.row]
    }
}

pub struct HostModel {
    pub spec: ModelSpec,
    pub weights: Rc<HashMap<String, Tensor>>,
}

impl HostModel {
    pub fn new(spec: ModelSpec, weights: Rc<HashMap<String, Tensor>>) -> HostModel {
        HostModel { spec, weights }
    }

    fn w(&self, name: &str) -> &Tensor {
        self.weights
            .get(name)
            .unwrap_or_else(|| panic!("missing weight {name}"))
    }

    fn lw(&self, layer: usize, t: &str) -> &Tensor {
        self.w(&format!("layer{layer}.{t}"))
    }

    pub fn rmsnorm(&self, x: &[f32], g: &[f32]) -> Vec<f32> {
        let mean_sq = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        let r = 1.0 / (mean_sq + self.spec.rms_eps as f32).sqrt();
        x.iter().zip(g).map(|(v, gg)| v * r * gg).collect()
    }

    /// RoPE on one head vector (length d, d even), matching model.rope.
    pub fn rope_head(&self, x: &mut [f32], pos: i32) {
        let d = x.len();
        let half = d / 2;
        let base = self.spec.rope_base as f32;
        for j in 0..half {
            let freq = base.powf(-(j as f32) / half as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let x1 = x[j];
            let x2 = x[j + half];
            x[j] = x1 * cos - x2 * sin;
            x[j + half] = x1 * sin + x2 * cos;
        }
    }

    fn rope_all_heads(&self, x: &mut [f32], pos: i32) {
        let d = self.spec.head_dim;
        for h in 0..(x.len() / d) {
            self.rope_head(&mut x[h * d..(h + 1) * d], pos);
        }
    }

    pub fn embed(&self, token: i32) -> Vec<f32> {
        self.w("emb").row(&[token as usize]).to_vec()
    }

    /// Project x through one layer's QKV; returns (q roped [Hq*d],
    /// k_new roped [Hkv*d], v_new [Hkv*d]).
    pub fn qkv(&self, layer: usize, x: &[f32], pos: i32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let spec = &self.spec;
        let h = self.rmsnorm(x, &self.lw(layer, "ln1").data);
        let mut q = vec![0.0; spec.q_flat_dim()];
        let mut k = vec![0.0; spec.kv_flat_dim()];
        let mut v = vec![0.0; spec.kv_flat_dim()];
        mathx::matmul(&h, &self.lw(layer, "wq").data, 1, spec.d_model, spec.q_flat_dim(), &mut q);
        mathx::matmul(&h, &self.lw(layer, "wk").data, 1, spec.d_model, spec.kv_flat_dim(), &mut k);
        mathx::matmul(&h, &self.lw(layer, "wv").data, 1, spec.d_model, spec.kv_flat_dim(), &mut v);
        self.rope_all_heads(&mut q, pos);
        self.rope_all_heads(&mut k, pos);
        (q, k, v)
    }

    /// GQA attention of `q` over KV rows, with an optional per-row
    /// validity mask. Returns [Hq*d].
    pub fn attention(
        &self,
        q: &[f32],
        k_rows: &[&[f32]],
        v_rows: &[&[f32]],
        valid: Option<&[bool]>,
    ) -> Vec<f32> {
        let spec = &self.spec;
        let d = spec.head_dim;
        let scale = 1.0 / (d as f32).sqrt();
        let n = k_rows.len();
        let mut out = vec![0.0; spec.q_flat_dim()];
        let mut scores = vec![0.0f32; n];
        for hq in 0..spec.n_q_heads {
            let g = hq / spec.n_rep();
            let qh = &q[hq * d..(hq + 1) * d];
            for (i, krow) in k_rows.iter().enumerate() {
                let ok = valid.map(|m| m[i]).unwrap_or(true);
                scores[i] = if ok {
                    mathx::dot(qh, &krow[g * d..(g + 1) * d]) * scale
                } else {
                    NEG_INF
                };
            }
            mathx::softmax(&mut scores);
            let oh = &mut out[hq * d..(hq + 1) * d];
            for (i, vrow) in v_rows.iter().enumerate() {
                let w = scores[i];
                if w == 0.0 {
                    continue;
                }
                for (o, vv) in oh.iter_mut().zip(&vrow[g * d..(g + 1) * d]) {
                    *o += w * vv;
                }
            }
        }
        out
    }

    /// Full transformer block over explicit KV rows (the current token's
    /// KV is computed internally and appended, like decode_block_fn).
    /// Returns (x_next, k_new, v_new).
    pub fn block(
        &self,
        layer: usize,
        x: &[f32],
        k_rows: &[&[f32]],
        v_rows: &[&[f32]],
        valid: Option<&[bool]>,
        pos: i32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let spec = &self.spec;
        let (q, k_new, v_new) = self.qkv(layer, x, pos);
        let mut krows: Vec<&[f32]> = k_rows.to_vec();
        let mut vrows: Vec<&[f32]> = v_rows.to_vec();
        krows.push(&k_new);
        vrows.push(&v_new);
        let valid_ext: Option<Vec<bool>> = valid.map(|m| {
            let mut v = m.to_vec();
            v.push(true);
            v
        });
        let o = self.attention(&q, &krows, &vrows, valid_ext.as_deref());
        let mut x1 = x.to_vec();
        let mut proj = vec![0.0; spec.d_model];
        mathx::matmul(&o, &self.lw(layer, "wo").data, 1, spec.q_flat_dim(), spec.d_model, &mut proj);
        for (a, b) in x1.iter_mut().zip(&proj) {
            *a += b;
        }
        // SwiGLU MLP
        let h2 = self.rmsnorm(&x1, &self.lw(layer, "ln2").data);
        let f = spec.d_ff;
        let mut gate = vec![0.0; f];
        let mut up = vec![0.0; f];
        mathx::matmul(&h2, &self.lw(layer, "wg").data, 1, spec.d_model, f, &mut gate);
        mathx::matmul(&h2, &self.lw(layer, "wu").data, 1, spec.d_model, f, &mut up);
        for (g, u) in gate.iter_mut().zip(&up) {
            let silu = *g / (1.0 + (-*g).exp());
            *g = silu * u;
        }
        let mut down = vec![0.0; spec.d_model];
        mathx::matmul(&gate, &self.lw(layer, "wd").data, 1, f, spec.d_model, &mut down);
        for (a, b) in x1.iter_mut().zip(&down) {
            *a += b;
        }
        (x1, k_new, v_new)
    }

    /// Full-KV oracle decode step over per-layer caches (appends new KV).
    pub fn decode_step(&self, x0: &[f32], caches: &mut [KvLayer], pos: i32) -> Vec<f32> {
        let mut x = x0.to_vec();
        for layer in 0..self.spec.n_layers {
            let cache = &caches[layer];
            let n = cache.len();
            let krows: Vec<&[f32]> = (0..n).map(|i| cache.k_row(i)).collect();
            let vrows: Vec<&[f32]> = (0..n).map(|i| cache.v_row(i)).collect();
            let (x1, k_new, v_new) = self.block(layer, &x, &krows, &vrows, None, pos);
            x = x1;
            caches[layer].push(&k_new, &v_new);
        }
        x
    }

    /// Full prefill: returns final hidden of each token and per-layer caches.
    pub fn prefill(&self, tokens: &[i32]) -> (Vec<Vec<f32>>, Vec<KvLayer>) {
        let spec = &self.spec;
        let hd = spec.kv_flat_dim();
        let mut caches: Vec<KvLayer> = (0..spec.n_layers).map(|_| KvLayer::new(hd)).collect();
        let mut xs: Vec<Vec<f32>> = tokens.iter().map(|&t| self.embed(t)).collect();
        for layer in 0..spec.n_layers {
            let mut new_k: Vec<Vec<f32>> = Vec::with_capacity(tokens.len());
            let mut new_v: Vec<Vec<f32>> = Vec::with_capacity(tokens.len());
            let mut new_x: Vec<Vec<f32>> = Vec::with_capacity(tokens.len());
            for (t, x) in xs.iter().enumerate() {
                let krows: Vec<&[f32]> = new_k.iter().map(|r| r.as_slice()).collect();
                let vrows: Vec<&[f32]> = new_v.iter().map(|r| r.as_slice()).collect();
                let (x1, k_new, v_new) = self.block(layer, x, &krows, &vrows, None, t as i32);
                new_k.push(k_new);
                new_v.push(v_new);
                new_x.push(x1);
            }
            for (k, v) in new_k.iter().zip(&new_v) {
                caches[layer].push(k, v);
            }
            xs = new_x;
        }
        (xs, caches)
    }

    /// Predictor oracle: head-summed low-rank token scores (§3.3, Eq. 1).
    /// `adapter` is [Hkv*d, r] row-major; `k_lr` rows are [r].
    pub fn predict_scores(
        &self,
        layer: usize,
        x: &[f32],
        adapter: &Tensor,
        k_lr_rows: &[&[f32]],
        pos: i32,
    ) -> Vec<f32> {
        let spec = &self.spec;
        let d = spec.head_dim;
        let r = adapter.shape[1];
        let h = self.rmsnorm(x, &self.lw(layer, "ln1").data);
        let mut q = vec![0.0; spec.q_flat_dim()];
        mathx::matmul(&h, &self.lw(layer, "wq").data, 1, spec.d_model, spec.q_flat_dim(), &mut q);
        self.rope_all_heads(&mut q, pos);
        // q_lr[h] = q_h @ A_{g(h)}  (A rows g*d..(g+1)*d)
        let mut q_lr = vec![0.0; spec.n_q_heads * r];
        for hq in 0..spec.n_q_heads {
            let g = hq / spec.n_rep();
            let qh = &q[hq * d..(hq + 1) * d];
            let out = &mut q_lr[hq * r..(hq + 1) * r];
            for (di, &qv) in qh.iter().enumerate() {
                let arow = &adapter.data[(g * d + di) * r..(g * d + di + 1) * r];
                for (o, a) in out.iter_mut().zip(arow) {
                    *o += qv * a;
                }
            }
        }
        // head-summed scores per row
        k_lr_rows
            .iter()
            .map(|row| {
                let mut s = 0.0;
                for hq in 0..spec.n_q_heads {
                    s += mathx::dot(&q_lr[hq * r..(hq + 1) * r], row);
                }
                s
            })
            .collect()
    }

    /// Per-head predictor scores (no head aggregation — the InfiniGen
    /// baseline's selection granularity): one score vector per query head.
    pub fn predict_scores_per_head(
        &self,
        layer: usize,
        x: &[f32],
        adapter: &Tensor,
        k_lr_rows: &[&[f32]],
        pos: i32,
    ) -> Vec<Vec<f32>> {
        let spec = &self.spec;
        let d = spec.head_dim;
        let r = adapter.shape[1];
        let h = self.rmsnorm(x, &self.lw(layer, "ln1").data);
        let mut q = vec![0.0; spec.q_flat_dim()];
        mathx::matmul(&h, &self.lw(layer, "wq").data, 1, spec.d_model, spec.q_flat_dim(), &mut q);
        self.rope_all_heads(&mut q, pos);
        (0..spec.n_q_heads)
            .map(|hq| {
                let g = hq / spec.n_rep();
                let qh = &q[hq * d..(hq + 1) * d];
                let mut q_lr = vec![0.0; r];
                for (di, &qv) in qh.iter().enumerate() {
                    let arow = &adapter.data[(g * d + di) * r..(g * d + di + 1) * r];
                    for (o, a) in q_lr.iter_mut().zip(arow) {
                        *o += qv * a;
                    }
                }
                k_lr_rows.iter().map(|row| mathx::dot(&q_lr, row)).collect()
            })
            .collect()
    }

    /// Compress K rows to K_lr rows with the adapter: K_lr = K A.
    pub fn compress_k(&self, adapter: &Tensor, k_row: &[f32]) -> Vec<f32> {
        let r = adapter.shape[1];
        let mut out = vec![0.0; r];
        mathx::matmul(k_row, &adapter.data, 1, k_row.len(), r, &mut out);
        out
    }

    pub fn logits_argmax(&self, x: &[f32]) -> (i32, f32) {
        let spec = &self.spec;
        let h = self.rmsnorm(x, &self.w("fln").data);
        let emb = self.w("emb");
        let mut best = (0i32, f32::NEG_INFINITY);
        for v in 0..spec.vocab {
            let logit = mathx::dot(&h, emb.row(&[v]));
            if logit > best.1 {
                best = (v as i32, logit);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            n_q_heads: 4,
            n_kv_heads: 2,
            head_dim: 4,
            d_ff: 32,
            vocab: 32,
            rope_base: 10000.0,
            rms_eps: 1e-5,
        }
    }

    fn tiny_model(seed: u64) -> HostModel {
        let spec = tiny_spec();
        let mut rng = Rng::new(seed);
        let mut w = HashMap::new();
        let base = 1.0 / (spec.d_model as f32).sqrt();
        let mut norm = |shape: &[usize], std: f32| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(std)).collect())
        };
        w.insert("emb".into(), norm(&[spec.vocab, spec.d_model], base));
        w.insert("fln".into(), Tensor::full(&[spec.d_model], 1.0));
        for i in 0..spec.n_layers {
            w.insert(format!("layer{i}.ln1"), Tensor::full(&[spec.d_model], 1.0));
            w.insert(format!("layer{i}.ln2"), Tensor::full(&[spec.d_model], 1.0));
            w.insert(format!("layer{i}.wq"), norm(&[spec.d_model, spec.q_flat_dim()], base));
            w.insert(format!("layer{i}.wk"), norm(&[spec.d_model, spec.kv_flat_dim()], base));
            w.insert(format!("layer{i}.wv"), norm(&[spec.d_model, spec.kv_flat_dim()], base));
            w.insert(format!("layer{i}.wo"), norm(&[spec.q_flat_dim(), spec.d_model], base));
            w.insert(format!("layer{i}.wg"), norm(&[spec.d_model, spec.d_ff], base));
            w.insert(format!("layer{i}.wu"), norm(&[spec.d_model, spec.d_ff], base));
            w.insert(format!("layer{i}.wd"), norm(&[spec.d_ff, spec.d_model], base));
        }
        HostModel::new(spec, Rc::new(w))
    }

    #[test]
    fn rope_preserves_norm_and_identity_at_zero() {
        let m = tiny_model(0);
        let mut x = vec![0.3, -0.7, 1.1, 0.5];
        let orig = x.clone();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        m.rope_head(&mut x, 0);
        assert_eq!(x, orig);
        m.rope_head(&mut x, 57);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn attention_single_row_returns_value() {
        let m = tiny_model(1);
        let d = m.spec.head_dim;
        let q = vec![0.5; m.spec.q_flat_dim()];
        let k = vec![0.1; m.spec.kv_flat_dim()];
        let v: Vec<f32> = (0..m.spec.kv_flat_dim()).map(|i| i as f32).collect();
        let out = m.attention(&q, &[&k], &[&v], None);
        for hq in 0..m.spec.n_q_heads {
            let g = hq / m.spec.n_rep();
            assert_eq!(&out[hq * d..(hq + 1) * d], &v[g * d..(g + 1) * d]);
        }
    }

    #[test]
    fn attention_masked_rows_ignored() {
        let m = tiny_model(2);
        let mut rng = Rng::new(9);
        let q: Vec<f32> = (0..m.spec.q_flat_dim()).map(|_| rng.normal_f32(1.0)).collect();
        let rows: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..m.spec.kv_flat_dim()).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let valid = vec![true, true, true, false, false, false];
        let out1 = m.attention(&q, &refs[..], &refs[..], Some(&valid));
        let out2 = m.attention(&q, &refs[..3], &refs[..3], None);
        for (a, b) in out1.iter().zip(&out2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn decode_step_appends_kv_and_changes_x() {
        let m = tiny_model(3);
        let mut caches: Vec<KvLayer> =
            (0..m.spec.n_layers).map(|_| KvLayer::new(m.spec.kv_flat_dim())).collect();
        let x0 = m.embed(5);
        let x1 = m.decode_step(&x0, &mut caches, 0);
        assert_eq!(caches[0].len(), 1);
        assert_eq!(caches[1].len(), 1);
        assert_ne!(x0, x1);
        let x2 = m.decode_step(&x1, &mut caches, 1);
        assert_eq!(caches[0].len(), 2);
        assert_ne!(x1, x2);
    }

    #[test]
    fn prefill_then_decode_consistent_with_streaming_decode() {
        // Prefilling S tokens then decoding must equal decoding token-by-
        // token from an empty cache (same math, different batching).
        let m = tiny_model(4);
        let tokens = [3, 11, 7, 19];
        let (xs, caches) = m.prefill(&tokens);

        let mut caches2: Vec<KvLayer> =
            (0..m.spec.n_layers).map(|_| KvLayer::new(m.spec.kv_flat_dim())).collect();
        let mut last_x = Vec::new();
        for (t, &tok) in tokens.iter().enumerate() {
            last_x = m.decode_step(&m.embed(tok), &mut caches2, t as i32);
        }
        for (a, b) in xs.last().unwrap().iter().zip(&last_x) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for l in 0..m.spec.n_layers {
            assert_eq!(caches[l].len(), caches2[l].len());
            for (a, b) in caches[l].k.iter().zip(&caches2[l].k) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn predict_scores_match_full_scores_with_identity_adapter() {
        // With a full-rank orthonormal adapter (identity), predicted
        // scores must equal the true head-summed q.k scores.
        let m = tiny_model(5);
        let hd = m.spec.kv_flat_dim();
        let mut eye = Tensor::zeros(&[hd, hd]);
        for i in 0..hd {
            *eye.at_mut(&[i, i]) = 1.0;
        }
        let (_, caches) = m.prefill(&[1, 2, 3, 4, 5]);
        let x = m.embed(9);
        let layer = 1;
        // K_lr with identity adapter == K rows themselves
        let k_lr_rows: Vec<&[f32]> = (0..caches[layer].len()).map(|i| caches[layer].k_row(i)).collect();
        let pred = m.predict_scores(layer, &x, &eye, &k_lr_rows, 5);
        // true scores: q_h . k_row[g-slice]
        let (q, _, _) = m.qkv(layer, &x, 5);
        let d = m.spec.head_dim;
        for (i, row) in k_lr_rows.iter().enumerate() {
            let mut want = 0.0;
            for hq in 0..m.spec.n_q_heads {
                let g = hq / m.spec.n_rep();
                want += mathx::dot(&q[hq * d..(hq + 1) * d], &row[g * d..(g + 1) * d]);
            }
            assert!((pred[i] - want).abs() < 1e-3, "{} vs {}", pred[i], want);
        }
    }

    #[test]
    fn logits_argmax_picks_max() {
        let m = tiny_model(6);
        let x = m.embed(4);
        let (tok, top) = m.logits_argmax(&x);
        assert!((0..m.spec.vocab as i32).contains(&tok));
        // verify it is the max by recompute
        let h = m.rmsnorm(&x, &m.w("fln").data);
        let emb = m.w("emb");
        for v in 0..m.spec.vocab {
            assert!(mathx::dot(&h, emb.row(&[v])) <= top + 1e-6);
        }
    }
}
