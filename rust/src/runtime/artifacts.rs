//! Artifact manifest: the AOT contract between `python/compile/aot.py`
//! and the Rust runtime. Parses `artifacts/manifest.json`, loads weight
//! blobs, and resolves (preset, batch, name) -> HLO file path + signature.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::ModelSpec;
use crate::runtime::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub preset: String,
    pub batch: usize,
    pub name: String,
    pub path: PathBuf,
    /// Input shapes/dtypes in call order.
    pub inputs: Vec<(Vec<usize>, String)>,
    /// Names of the trailing weight arguments (manifest `weight_args`).
    pub weight_args: Vec<String>,
    pub n_outputs: usize,
    pub params: HashMap<String, usize>,
}

impl ArtifactMeta {
    /// Number of leading dynamic (non-weight) arguments.
    pub fn n_dynamic(&self) -> usize {
        self.inputs.len() - self.weight_args.len()
    }
}

#[derive(Debug, Clone)]
pub struct PresetInfo {
    pub spec: ModelSpec,
    pub weights_path: PathBuf,
    pub weight_index: Vec<(String, Vec<usize>, usize, usize)>, // name, shape, offset, nbytes
    pub ranks: Vec<usize>,
    pub ncaps: Vec<usize>,
    pub batches: Vec<usize>,
    pub defaults: HashMap<String, usize>,
    pub prefill_chunk: usize,
    pub prefill_ncap: usize,
}

pub struct Manifest {
    pub root: PathBuf,
    pub presets: HashMap<String, PresetInfo>,
    artifacts: HashMap<(String, usize, String), ArtifactMeta>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(root: P) -> anyhow::Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let src = std::fs::read_to_string(root.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("cannot read manifest in {root:?}: {e}"))?;
        let j = Json::parse(&src).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;

        let mut presets = HashMap::new();
        for (pname, stanza) in j.req("presets")?.as_obj().unwrap_or(&[]) {
            let spec = ModelSpec::from_json(stanza.req("model")?)?;
            let w = stanza.req("weights")?;
            let weight_index = w
                .req("tensors")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|t| {
                    Ok((
                        t.req("name")?.as_str().unwrap_or("").to_string(),
                        t.req("shape")?.usize_vec()?,
                        t.req("offset")?.as_usize().unwrap_or(0),
                        t.req("nbytes")?.as_usize().unwrap_or(0),
                    ))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let defaults = stanza
                .req("defaults")?
                .as_obj()
                .unwrap_or(&[])
                .iter()
                .filter_map(|(k, v)| v.as_usize().map(|u| (k.clone(), u)))
                .collect();
            let prefill = stanza.req("prefill")?;
            presets.insert(
                pname.clone(),
                PresetInfo {
                    spec,
                    weights_path: root.join(w.req("path")?.as_str().unwrap_or("")),
                    weight_index,
                    ranks: stanza.req("ranks")?.usize_vec()?,
                    ncaps: stanza.req("ncaps")?.usize_vec()?,
                    batches: stanza.req("batches")?.usize_vec()?,
                    defaults,
                    prefill_chunk: prefill.usize_or("chunk", 128),
                    prefill_ncap: prefill.usize_or("ncap", 2048),
                },
            );
        }

        let mut artifacts = HashMap::new();
        for ent in j.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let meta = ArtifactMeta {
                preset: ent.str_or("preset", ""),
                batch: ent.usize_or("batch", 0),
                name: ent.str_or("name", ""),
                path: root.join(ent.str_or("path", "")),
                inputs: ent
                    .req("inputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|i| {
                        Ok((
                            i.req("shape")?.usize_vec()?,
                            i.str_or("dtype", "float32"),
                        ))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
                weight_args: ent
                    .req("weight_args")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_str().map(|s| s.to_string()))
                    .collect(),
                n_outputs: ent.usize_or("n_outputs", 1),
                params: ent
                    .req("params")?
                    .as_obj()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|(k, v)| v.as_usize().map(|u| (k.clone(), u)))
                    .collect(),
            };
            artifacts.insert((meta.preset.clone(), meta.batch, meta.name.clone()), meta);
        }

        Ok(Manifest {
            root,
            presets,
            artifacts,
        })
    }

    pub fn get(&self, preset: &str, batch: usize, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(&(preset.to_string(), batch, name.to_string()))
            .ok_or_else(|| {
                anyhow::anyhow!("artifact not found: {preset}/b{batch}/{name} (rerun `make artifacts`?)")
            })
    }

    pub fn has(&self, preset: &str, batch: usize, name: &str) -> bool {
        self.artifacts
            .contains_key(&(preset.to_string(), batch, name.to_string()))
    }

    pub fn artifact_names(&self, preset: &str, batch: usize) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .keys()
            .filter(|(p, b, _)| p == preset && *b == batch)
            .map(|(_, _, n)| n.clone())
            .collect();
        v.sort();
        v
    }

    /// Load every weight tensor (plus SVD adapters) for a preset.
    pub fn load_weights(&self, preset: &str) -> anyhow::Result<HashMap<String, Tensor>> {
        let info = self
            .presets
            .get(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {preset}"))?;
        let blob = std::fs::read(&info.weights_path)?;
        let mut out = HashMap::new();
        for (name, shape, offset, nbytes) in &info.weight_index {
            let bytes = blob
                .get(*offset..offset + nbytes)
                .ok_or_else(|| anyhow::anyhow!("weight {name} out of blob bounds"))?;
            out.insert(name.clone(), Tensor::from_le_bytes(shape, bytes));
        }
        Ok(out)
    }
}

/// Locate the artifacts directory: $KVSWAP_ARTIFACTS or ./artifacts
/// relative to the crate root / CWD.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("KVSWAP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest_dir.join("manifest.json").exists() {
        return manifest_dir;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn built() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn loads_built_manifest() {
        let Some(m) = built() else { return };
        assert!(m.presets.contains_key("nano"));
        let info = &m.presets["nano"];
        assert_eq!(info.spec.kv_flat_dim(), 128);
        assert!(info.ranks.contains(&16));
        let meta = m.get("nano", 1, "decode_p272").unwrap();
        assert_eq!(meta.n_outputs, 3);
        assert_eq!(meta.weight_args.len(), 9);
        assert_eq!(meta.n_dynamic(), 5);
        assert!(meta.path.exists());
    }

    #[test]
    fn loads_weights_with_adapters() {
        let Some(m) = built() else { return };
        let w = m.load_weights("nano").unwrap();
        assert!(w.contains_key("emb"));
        assert!(w.contains_key("layer0.wq"));
        assert!(w.contains_key("layer0.A16"));
        let spec = &m.presets["nano"].spec;
        assert_eq!(
            w["layer0.wq"].shape,
            vec![spec.d_model, spec.q_flat_dim()]
        );
        assert_eq!(w["layer0.A16"].shape, vec![spec.kv_flat_dim(), 16]);
        // adapters are orthonormal: A^T A = I
        let a = &w["layer0.A16"];
        let (hd, r) = (a.shape[0], a.shape[1]);
        for i in 0..r {
            for j in 0..r {
                let mut dot = 0.0f32;
                for k in 0..hd {
                    dot += a.data[k * r + i] * a.data[k * r + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-3, "gram[{i}][{j}]={dot}");
            }
        }
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let Some(m) = built() else { return };
        let err = m.get("nano", 1, "nonexistent").unwrap_err().to_string();
        assert!(err.contains("nonexistent"));
    }
}
