//! Runtime layer: PJRT client + AOT-artifact loading (the xla crate path:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute_b`), host tensors, and the pure-Rust reference transformer
//! used as a numerics oracle.

pub mod artifacts;
pub mod host_ref;
pub mod pjrt;
pub mod tensor;

pub use artifacts::{default_artifacts_dir, ArtifactMeta, Manifest};
pub use host_ref::{HostModel, KvLayer};
pub use pjrt::{literal_to_i32, literal_to_tensor, ModelRuntime, PjrtRuntime};
pub use tensor::{HostArg, Tensor, TensorI32};
