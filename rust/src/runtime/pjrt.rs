//! PJRT execution layer: loads HLO-text artifacts, compiles them on the
//! CPU PJRT client, keeps model weights resident as device buffers, and
//! exposes typed wrappers for each artifact family.
//!
//! Hot-path contract (DESIGN.md §4): weights are uploaded **once** per
//! preset and passed to `execute_b` as persistent `PjRtBuffer`s; only the
//! small dynamic tensors (activations, gathered KV, masks) are uploaded
//! per call. Python is never involved.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use super::artifacts::{ArtifactMeta, Manifest};
use super::tensor::{HostArg, Tensor, TensorI32};

/// Cumulative timing of runtime activity, for the perf breakdown.
#[derive(Debug, Default, Clone, Copy)]
pub struct RuntimeTiming {
    pub upload: Duration,
    pub execute: Duration,
    pub download: Duration,
    pub compile: Duration,
    pub calls: u64,
}

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<(String, usize, String), Rc<xla::PjRtLoadedExecutable>>>,
    /// preset -> weight name -> device buffer (uploaded once).
    weight_bufs: RefCell<HashMap<String, Rc<HashMap<String, xla::PjRtBuffer>>>>,
    /// preset -> host copy of the weights (kept for host_ref oracles).
    host_weights: RefCell<HashMap<String, Rc<HashMap<String, Tensor>>>>,
    timing: RefCell<RuntimeTiming>,
}

impl PjrtRuntime {
    pub fn new(manifest: Manifest) -> anyhow::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            weight_bufs: RefCell::new(HashMap::new()),
            host_weights: RefCell::new(HashMap::new()),
            timing: RefCell::new(RuntimeTiming::default()),
        })
    }

    pub fn timing(&self) -> RuntimeTiming {
        *self.timing.borrow()
    }

    pub fn reset_timing(&self) {
        *self.timing.borrow_mut() = RuntimeTiming::default();
    }

    /// Host-side weights for a preset (loads + caches on first use).
    pub fn host_weights(&self, preset: &str) -> anyhow::Result<Rc<HashMap<String, Tensor>>> {
        if let Some(w) = self.host_weights.borrow().get(preset) {
            return Ok(w.clone());
        }
        let w = Rc::new(self.manifest.load_weights(preset)?);
        self.host_weights
            .borrow_mut()
            .insert(preset.to_string(), w.clone());
        Ok(w)
    }

    /// Device-resident weight buffers for a preset (uploads on first use).
    fn weight_buffers(
        &self,
        preset: &str,
    ) -> anyhow::Result<Rc<HashMap<String, xla::PjRtBuffer>>> {
        if let Some(b) = self.weight_bufs.borrow().get(preset) {
            return Ok(b.clone());
        }
        let host = self.host_weights(preset)?;
        let t0 = Instant::now();
        let mut bufs = HashMap::new();
        for (name, tensor) in host.iter() {
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(&tensor.data, &tensor.shape, None)
                .map_err(|e| anyhow::anyhow!("upload weight {name}: {e:?}"))?;
            bufs.insert(name.clone(), buf);
        }
        self.timing.borrow_mut().upload += t0.elapsed();
        let rc = Rc::new(bufs);
        self.weight_bufs
            .borrow_mut()
            .insert(preset.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-upload a preset's weights to device buffers (warmup path).
    pub fn warm_weights(&self, preset: &str) -> anyhow::Result<()> {
        self.weight_buffers(preset).map(|_| ())
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(
        &self,
        preset: &str,
        batch: usize,
        name: &str,
    ) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (preset.to_string(), batch, name.to_string());
        if let Some(e) = self.exes.borrow().get(&key) {
            return Ok(e.clone());
        }
        let meta = self.manifest.get(preset, batch, name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse hlo {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        self.timing.borrow_mut().compile += t0.elapsed();
        crate::log_debug!(
            "compiled {preset}/b{batch}/{name} in {:?}",
            t0.elapsed()
        );
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// How many executables have been compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    fn upload_arg(&self, arg: &HostArg) -> anyhow::Result<xla::PjRtBuffer> {
        let buf = match arg {
            HostArg::F32(t) => self
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None),
            HostArg::I32(t) => self
                .client
                .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None),
        };
        buf.map_err(|e| anyhow::anyhow!("upload arg: {e:?}"))
    }

    /// Resolve the weight-argument names of an artifact to buffer keys.
    /// `layer` substitutes per-layer tensors; `rank` picks the adapter.
    fn weight_keys(
        meta: &ArtifactMeta,
        layer: Option<usize>,
        rank: Option<usize>,
    ) -> anyhow::Result<Vec<String>> {
        meta.weight_args
            .iter()
            .map(|w| match w.as_str() {
                "emb" => Ok("emb".to_string()),
                "fln" => Ok("fln".to_string()),
                "A" => {
                    let l = layer.ok_or_else(|| anyhow::anyhow!("{}: layer required", meta.name))?;
                    let r = rank.ok_or_else(|| anyhow::anyhow!("{}: rank required", meta.name))?;
                    Ok(format!("layer{l}.A{r}"))
                }
                t => {
                    let l = layer.ok_or_else(|| anyhow::anyhow!("{}: layer required", meta.name))?;
                    Ok(format!("layer{l}.{t}"))
                }
            })
            .collect()
    }

    /// Execute an artifact: dynamic args uploaded per call, weight args
    /// resolved to the persistent buffers. Returns decomposed outputs.
    pub fn exec(
        &self,
        preset: &str,
        batch: usize,
        name: &str,
        dynamic: &[HostArg],
        layer: Option<usize>,
        rank: Option<usize>,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let meta = self.manifest.get(preset, batch, name)?.clone();
        anyhow::ensure!(
            dynamic.len() == meta.n_dynamic(),
            "{name}: expected {} dynamic args, got {}",
            meta.n_dynamic(),
            dynamic.len()
        );
        // shape-check against the manifest: catches mis-wired callers early
        for (i, arg) in dynamic.iter().enumerate() {
            anyhow::ensure!(
                arg.shape() == &meta.inputs[i].0[..],
                "{name}: arg {i} shape {:?} != manifest {:?}",
                arg.shape(),
                meta.inputs[i].0
            );
        }
        let exe = self.executable(preset, batch, name)?;
        let wbufs = self.weight_buffers(preset)?;
        let wkeys = Self::weight_keys(&meta, layer, rank)?;

        let t0 = Instant::now();
        let mut dyn_bufs = Vec::with_capacity(dynamic.len());
        for a in dynamic {
            dyn_bufs.push(self.upload_arg(a)?);
        }
        let t_upload = t0.elapsed();

        let mut args: Vec<&xla::PjRtBuffer> = dyn_bufs.iter().collect();
        for k in &wkeys {
            args.push(
                wbufs
                    .get(k)
                    .ok_or_else(|| anyhow::anyhow!("missing weight buffer {k}"))?,
            );
        }

        let t1 = Instant::now();
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let t_exec = t1.elapsed();

        let t2 = Instant::now();
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download {name}: {e:?}"))?;
        let outs = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        let t_dl = t2.elapsed();

        let mut tm = self.timing.borrow_mut();
        tm.upload += t_upload;
        tm.execute += t_exec;
        tm.download += t_dl;
        tm.calls += 1;
        anyhow::ensure!(
            outs.len() == meta.n_outputs,
            "{name}: expected {} outputs, got {}",
            meta.n_outputs,
            outs.len()
        );
        Ok(outs)
    }
}

/// Convert an output literal to a host f32 tensor with a known shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> anyhow::Result<Tensor> {
    let v = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal->f32: {e:?}"))?;
    Ok(Tensor::from_vec(shape, v))
}

pub fn literal_to_i32(lit: &xla::Literal) -> anyhow::Result<Vec<i32>> {
    lit.to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("literal->i32: {e:?}"))
}

// ---------------------------------------------------------------------------
// Typed model-level wrapper

/// Typed facade over the artifacts of one (preset, batch): the engine's
/// view of the model. All methods are single decode-step granular; the
/// engine owns the loop and the KV state.
pub struct ModelRuntime {
    pub rt: Rc<PjrtRuntime>,
    pub preset: String,
    pub batch: usize,
    pub p_sel: usize,
}

impl ModelRuntime {
    pub fn new(rt: Rc<PjrtRuntime>, preset: &str, batch: usize) -> anyhow::Result<ModelRuntime> {
        let p_sel = rt
            .manifest
            .presets
            .get(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {preset}"))?
            .defaults
            .get("p_sel")
            .copied()
            .unwrap_or(272);
        Ok(ModelRuntime {
            rt,
            preset: preset.to_string(),
            batch,
            p_sel,
        })
    }

    pub fn spec(&self) -> crate::config::ModelSpec {
        self.rt.manifest.presets[&self.preset].spec.clone()
    }

    /// tokens [b] -> x [b, D]
    pub fn embed(&self, tokens: &[i32]) -> anyhow::Result<Tensor> {
        let spec = self.spec();
        let outs = self.rt.exec(
            &self.preset,
            self.batch,
            "embed",
            &[TensorI32::vec1(tokens.to_vec()).into()],
            None,
            None,
        )?;
        literal_to_tensor(&outs[0], &[self.batch, spec.d_model])
    }

    /// One transformer block over gathered KV (width `p`; the artifact
    /// named decode_p{p} or decode_full_n{p} must exist).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_block(
        &self,
        artifact: &str,
        layer: usize,
        x: Tensor,
        k_sel: Tensor,
        v_sel: Tensor,
        mask: Tensor,
        pos: &[i32],
    ) -> anyhow::Result<(Tensor, Tensor, Tensor)> {
        let spec = self.spec();
        let (b, hkv, d) = (self.batch, spec.n_kv_heads, spec.head_dim);
        let outs = self.rt.exec(
            &self.preset,
            self.batch,
            artifact,
            &[
                x.into(),
                k_sel.into(),
                v_sel.into(),
                mask.into(),
                TensorI32::vec1(pos.to_vec()).into(),
            ],
            Some(layer),
            None,
        )?;
        Ok((
            literal_to_tensor(&outs[0], &[b, spec.d_model])?,
            literal_to_tensor(&outs[1], &[b, hkv, d])?,
            literal_to_tensor(&outs[2], &[b, hkv, d])?,
        ))
    }

    /// Predictor: token scores for `layer`'s K cache from input `x`
    /// (paper §3.3). `ncap`/`rank` select the compiled variant.
    pub fn predict_scores(
        &self,
        layer: usize,
        ncap: usize,
        rank: usize,
        x: Tensor,
        k_lr: Tensor,
        lens: &[i32],
        pos: &[i32],
    ) -> anyhow::Result<Tensor> {
        let name = format!("predict_n{ncap}_r{rank}");
        let outs = self.rt.exec(
            &self.preset,
            self.batch,
            &name,
            &[
                x.into(),
                k_lr.into(),
                TensorI32::vec1(lens.to_vec()).into(),
                TensorI32::vec1(pos.to_vec()).into(),
            ],
            Some(layer),
            Some(rank),
        )?;
        literal_to_tensor(&outs[0], &[self.batch, ncap])
    }

    /// x [b, D] -> (next tokens [b], top logits [b])
    pub fn logits_argmax(&self, x: Tensor) -> anyhow::Result<(Vec<i32>, Vec<f32>)> {
        let outs = self.rt.exec(
            &self.preset,
            self.batch,
            "logits_argmax",
            &[x.into()],
            None,
            None,
        )?;
        let toks = literal_to_i32(&outs[0])?;
        let tops = outs[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok((toks, tops))
    }

    /// tokens [b, T] -> x [b, T, D]
    pub fn embed_chunk(&self, tokens: &TensorI32, chunk: usize) -> anyhow::Result<Tensor> {
        let spec = self.spec();
        let name = format!("embed_chunk_t{chunk}");
        let outs = self.rt.exec(
            &self.preset,
            self.batch,
            &name,
            &[tokens.clone().into()],
            None,
            None,
        )?;
        literal_to_tensor(&outs[0], &[self.batch, chunk, spec.d_model])
    }

    /// One prefill block over a chunk. Returns (x', k_chunk, v_chunk).
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_block(
        &self,
        layer: usize,
        chunk: usize,
        ncap: usize,
        x: Tensor,
        k_cache: Tensor,
        v_cache: Tensor,
        start: &[i32],
    ) -> anyhow::Result<(Tensor, Tensor, Tensor)> {
        let spec = self.spec();
        let name = format!("prefill_t{chunk}_n{ncap}");
        let outs = self.rt.exec(
            &self.preset,
            self.batch,
            &name,
            &[
                x.into(),
                k_cache.into(),
                v_cache.into(),
                TensorI32::vec1(start.to_vec()).into(),
            ],
            Some(layer),
            None,
        )?;
        Ok((
            literal_to_tensor(&outs[0], &[self.batch, chunk, spec.d_model])?,
            literal_to_tensor(&outs[1], &[self.batch, spec.n_kv_heads, chunk, spec.head_dim])?,
            literal_to_tensor(&outs[2], &[self.batch, spec.n_kv_heads, chunk, spec.head_dim])?,
        ))
    }
}
