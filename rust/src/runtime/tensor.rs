//! Host-side tensors (f32 / i32) — the currency between the coordinator
//! and the PJRT runtime. Row-major, shape-checked helpers only; all heavy
//! math lives in the HLO artifacts (or `host_ref` for test oracles).

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn nbytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Strides in elements (row-major).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        idx.iter()
            .zip(&strides)
            .zip(&self.shape)
            .map(|((i, s), dim)| {
                assert!(i < dim, "index {i} out of bound {dim}");
                i * s
            })
            .sum()
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.offset(idx);
        &mut self.data[o]
    }

    /// Contiguous row slice for the leading indices (all trailing dims).
    pub fn row(&self, lead: &[usize]) -> &[f32] {
        let tail: usize = self.shape[lead.len()..].iter().product();
        let mut idx = lead.to_vec();
        idx.extend(std::iter::repeat(0).take(self.shape.len() - lead.len()));
        let off = self.offset(&idx);
        &self.data[off..off + tail]
    }

    pub fn row_mut(&mut self, lead: &[usize]) -> &mut [f32] {
        let tail: usize = self.shape[lead.len()..].iter().product();
        let mut idx = lead.to_vec();
        idx.extend(std::iter::repeat(0).take(self.shape.len() - lead.len()));
        let off = self.offset(&idx);
        &mut self.data[off..off + tail]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn from_le_bytes(shape: &[usize], bytes: &[u8]) -> Tensor {
        assert_eq!(bytes.len() % 4, 0);
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::from_vec(shape, data)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> TensorI32 {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn vec1(data: Vec<i32>) -> TensorI32 {
        TensorI32 {
            shape: vec![data.len()],
            data,
        }
    }
}

/// An argument to an HLO executable.
#[derive(Debug, Clone)]
pub enum HostArg {
    F32(Tensor),
    I32(TensorI32),
}

impl From<Tensor> for HostArg {
    fn from(t: Tensor) -> HostArg {
        HostArg::F32(t)
    }
}

impl From<TensorI32> for HostArg {
    fn from(t: TensorI32) -> HostArg {
        HostArg::I32(t)
    }
}

impl HostArg {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostArg::F32(t) => &t.shape,
            HostArg::I32(t) => &t.shape,
        }
    }

    pub fn nbytes(&self) -> usize {
        match self {
            HostArg::F32(t) => t.data.len() * 4,
            HostArg::I32(t) => t.data.len() * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_and_strides() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        *t.at_mut(&[1, 2, 3]) = 5.0;
        assert_eq!(t.at(&[1, 2, 3]), 5.0);
        assert_eq!(t.data[23], 5.0);
    }

    #[test]
    fn rows_are_contiguous_tails() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.row(&[0]), &[0.0, 1.0, 2.0]);
        assert_eq!(t.row(&[1]), &[3.0, 4.0, 5.0]);
        let t3 = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t3.row(&[1, 0]), &[4.0, 5.0]);
        assert_eq!(t3.row(&[1]), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn row_mut_writes_through() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.row_mut(&[1]).copy_from_slice(&[7.0, 8.0]);
        assert_eq!(t.data, vec![0.0, 0.0, 7.0, 8.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn le_bytes_roundtrip() {
        let t = Tensor::from_vec(&[3], vec![1.0, -2.5, 3.25]);
        let bytes: Vec<u8> = t.data.iter().flat_map(|f| f.to_le_bytes()).collect();
        let back = Tensor::from_le_bytes(&[3], &bytes);
        assert_eq!(back, t);
    }

    #[test]
    fn host_arg_shapes() {
        let a: HostArg = Tensor::zeros(&[2, 2]).into();
        assert_eq!(a.shape(), &[2, 2]);
        assert_eq!(a.nbytes(), 16);
        let b: HostArg = TensorI32::vec1(vec![1, 2, 3]).into();
        assert_eq!(b.shape(), &[3]);
    }
}
