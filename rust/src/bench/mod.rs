//! Bench harness utilities (criterion is unavailable offline): shared
//! setup for the per-exhibit bench binaries under `rust/benches/`.

use std::rc::Rc;

use crate::config::KvSwapConfig;
use crate::coordinator::{Engine, EngineConfig, Policy};
use crate::disk::DiskProfile;
use crate::metrics::DecodeStats;
use crate::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};

/// Load the runtime or explain how to build artifacts.
pub fn runtime() -> anyhow::Result<Rc<PjrtRuntime>> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts not found in {dir:?}; run `make artifacts` first"
    );
    Ok(Rc::new(PjrtRuntime::new(Manifest::load(dir)?)?))
}

/// Standard bench engine config (virtual clock).
pub fn engine_cfg(
    preset: &str,
    batch: usize,
    policy: Policy,
    kv: KvSwapConfig,
    disk: DiskProfile,
    max_context: usize,
) -> EngineConfig {
    EngineConfig::builder()
        .preset(preset)
        .batch(batch)
        .policy(policy)
        .kv(kv)
        .disk(disk)
        .max_context(max_context)
        .build()
        .expect("valid bench config")
}

/// Run a decode-throughput measurement: synthetic contexts, `steps`
/// decode steps after `warmup_steps` (excluded from stats).
pub fn run_throughput(
    rt: Rc<PjrtRuntime>,
    cfg: EngineConfig,
    context: usize,
    warmup_steps: usize,
    steps: usize,
) -> anyhow::Result<(DecodeStats, Engine)> {
    let mut e = Engine::new(rt, cfg.clone())?;
    e.ingest_synthetic(&vec![context; cfg.batch])?;
    if warmup_steps > 0 {
        let _ = e.decode(warmup_steps, false, None)?;
    }
    let (stats, _, _) = e.decode(steps, false, None)?;
    Ok((stats, e))
}

/// Pretty banner for bench outputs.
pub fn banner(title: &str, note: &str) {
    println!("\n==== {title} ====");
    if !note.is_empty() {
        println!("{note}");
    }
}

/// Paper-scale context label for our scaled-down contexts (DESIGN.md §2:
/// nano's 8K plays the paper's 32K).
pub fn paper_context_label(ours: usize) -> String {
    format!("{}K(paper {}K)", ours / 1024, ours * 4 / 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(paper_context_label(8192), "8K(paper 32K)");
        assert_eq!(paper_context_label(2048), "2K(paper 8K)");
    }
}
