//! # KVSwap — disk-aware KV-cache offloading for long-context on-device inference
//!
//! Rust + JAX + Pallas reproduction of the CS.DC 2025 paper. This crate is
//! the **Layer-3 coordinator**: it owns the serving event loop, the
//! disk-resident KV cache and its in-memory metadata, the grouped
//! critical-KV predictor driver, the I/O/compute-overlapped decode
//! pipeline, the offline parameter tuner, and the baseline offloading
//! policies the paper compares against.
//!
//! Dense math executes through AOT-compiled HLO artifacts (Layer 2 JAX
//! calling Layer 1 Pallas kernels) loaded via the PJRT C API — Python is
//! never on the request path. See `DESIGN.md` for the full architecture
//! and `EXPERIMENTS.md` for the paper-vs-measured results.
//!
//! ## Storage API and the prefetch pipeline
//!
//! The disk substrate ([`disk`]) is built around three seams:
//!
//! * [`disk::Backend`] — where offloaded bytes physically live (RAM file
//!   image, a real file with positional syscalls, or a caller-supplied
//!   implementation via [`disk::StorageBackend::Custom`]). Multi-extent
//!   access goes through `Backend::read_batch`, which backends override
//!   with their preferred submission order. Everything speaks typed
//!   [`disk::DiskError`]s.
//! * [`disk::coalesce`] — merges near-adjacent planned extents into large
//!   sequential runs (paper §3.3: over-reading a small gap is cheaper
//!   than paying another device op).
//! * [`disk::Prefetcher`] — a worker pool that consumes per-layer
//!   [`disk::PreloadPlan`]s ahead of compute, stages the coalesced bytes
//!   into recycled buffers, and hands them back over a bounded channel in
//!   submission order (paper §3.4). With `workers: 0` it degrades to a
//!   synchronous, bit-identical baseline pipeline.
//!
//! The decode engine ([`coordinator`]) never reads the disk on its hot
//! path: plans are submitted while earlier layers compute, and
//! `Phase::IoWait` measures only the residual stall. Engine configs are
//! built with the validating [`coordinator::EngineConfig::builder`].
//!
//! ## Persistent KV store
//!
//! The working cache above dies with the process; the [`store`]
//! subsystem persists prefill results across requests *and* restarts. A
//! versioned manifest (atomic temp+rename writes, per-record checksums
//! re-armed into the [`disk::IntegrityMap`] on open) maps token-prefix
//! hash chains to disk extents; a boundary-hash index finds the longest
//! stored prefix so the engine warm-starts prefill at the divergence
//! point, bit-identical to recompute; LRU eviction with pinning bounds
//! capacity; and a deadline/idle-budget maintainer scrubs records,
//! persisting corruption sites and quarantining poisoned entries.

pub mod util;
pub mod config;
pub mod disk;
pub mod runtime;
pub mod kvcache;
pub mod predictor;
pub mod store;
pub mod coordinator;
pub mod baselines;
pub mod tuner;
pub mod metrics;
pub mod workload;
pub mod quality;
pub mod server;
pub mod bench;
