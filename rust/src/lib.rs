//! # KVSwap — disk-aware KV-cache offloading for long-context on-device inference
//!
//! Rust + JAX + Pallas reproduction of the CS.DC 2025 paper. This crate is
//! the **Layer-3 coordinator**: it owns the serving event loop, the
//! disk-resident KV cache and its in-memory metadata, the grouped
//! critical-KV predictor driver, the I/O/compute-overlapped decode
//! pipeline, the offline parameter tuner, and the baseline offloading
//! policies the paper compares against.
//!
//! Dense math executes through AOT-compiled HLO artifacts (Layer 2 JAX
//! calling Layer 1 Pallas kernels) loaded via the PJRT C API — Python is
//! never on the request path. See `DESIGN.md` for the full architecture
//! and `EXPERIMENTS.md` for the paper-vs-measured results.

pub mod util;
pub mod config;
pub mod disk;
pub mod runtime;
pub mod kvcache;
pub mod predictor;
pub mod coordinator;
pub mod baselines;
pub mod tuner;
pub mod metrics;
pub mod workload;
pub mod quality;
pub mod server;
pub mod bench;
