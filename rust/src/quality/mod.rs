//! Quality harness: the paper's generation-quality metrics re-expressed
//! for random-weight models (DESIGN.md §2 substitution):
//!
//! * **fidelity** — teacher-forced cosine between a method's final
//!   activations and the Full-KV oracle's, per decode step (the analogue
//!   of the paper's "relative accuracy loss" in Tab. 2/3);
//! * **token agreement** — fraction of free-running decode steps where
//!   the method samples the oracle's token;
//! * **NIAH retrieval** — needle planted in KV space at a (context,
//!   depth) cell (Fig. 9): retrieval score = cosine between method and
//!   oracle outputs, which the planted marker dominates.

use std::rc::Rc;

use crate::coordinator::{Engine, EngineConfig, Policy};
use crate::runtime::host_ref::{HostModel, KvLayer};
use crate::runtime::PjrtRuntime;
use crate::util::mathx;
use crate::util::rng::Rng;
use crate::workload::needle;

#[derive(Debug, Clone)]
pub struct QualityReport {
    pub policy: String,
    /// Mean per-step activation cosine vs the oracle (teacher-forced).
    pub fidelity: f64,
    /// Token agreement rate over a free-running decode.
    pub token_agreement: f64,
    pub steps: usize,
}

fn prompts_for(batch: usize, context: usize, vocab: usize, seed: u64) -> Vec<Vec<i32>> {
    (0..batch)
        .map(|i| {
            let mut rng = Rng::new(seed ^ (0xA11CE + i as u64));
            (0..context).map(|_| rng.below(vocab) as i32).collect()
        })
        .collect()
}

/// Teacher-forced fidelity + free-running token agreement of one policy
/// against the Full-KV oracle under the same engine config.
pub fn evaluate_policy(
    rt: Rc<PjrtRuntime>,
    mut cfg: EngineConfig,
    context: usize,
    steps: usize,
    seed: u64,
) -> anyhow::Result<QualityReport> {
    let policy = cfg.policy.clone();
    cfg.real_time = false;
    let vocab = rt.manifest.presets[&cfg.preset].spec.vocab;
    let prompts = prompts_for(cfg.batch, context, vocab, seed);

    let mut oracle_cfg = cfg.clone();
    oracle_cfg.policy = Policy::FullMemory;
    let mut oracle = Engine::new(rt.clone(), oracle_cfg)?;
    oracle.prefill(&prompts)?;
    let (_, oxs, otoks) = oracle.decode(steps, true, None)?;

    // teacher-forced pass: per-step fidelity
    let mut m1 = Engine::new(rt.clone(), cfg.clone())?;
    m1.prefill(&prompts)?;
    let (_, mxs, _) = m1.decode(steps, true, Some(&otoks))?;
    let mut cos = 0.0;
    let mut n = 0;
    for (ox, mx) in oxs.iter().zip(&mxs) {
        for b in 0..cfg.batch {
            cos += mathx::cosine(ox.row(&[b]), mx.row(&[b])).max(0.0) as f64;
            n += 1;
        }
    }

    // free-running pass: token agreement
    let mut m2 = Engine::new(rt.clone(), cfg.clone())?;
    m2.prefill(&prompts)?;
    let (_, _, mtoks) = m2.decode(steps, false, None)?;
    let mut agree = 0usize;
    let mut total = 0usize;
    for (o, m) in otoks.iter().zip(&mtoks) {
        for (a, b) in o.iter().zip(m) {
            agree += (a == b) as usize;
            total += 1;
        }
    }

    Ok(QualityReport {
        policy: policy.name(),
        fidelity: cos / n.max(1) as f64,
        token_agreement: agree as f64 / total.max(1) as f64,
        steps,
    })
}

/// Per-layer query vectors the model will issue at the next decode step
/// (needed to construct a query-aligned needle).
pub fn collect_layer_queries(
    host: &HostModel,
    x0: &[f32],
    caches: &[KvLayer],
    pos: i32,
) -> Vec<Vec<f32>> {
    let mut x = x0.to_vec();
    let mut qs = Vec::with_capacity(host.spec.n_layers);
    for layer in 0..host.spec.n_layers {
        let (q, _, _) = host.qkv(layer, &x, pos);
        qs.push(q);
        let n = caches[layer].len();
        let krows: Vec<&[f32]> = (0..n).map(|i| caches[layer].k_row(i)).collect();
        let vrows: Vec<&[f32]> = (0..n).map(|i| caches[layer].v_row(i)).collect();
        let (x1, _, _) = host.block(layer, &x, &krows, &vrows, None, pos);
        x = x1;
    }
    qs
}

/// One NIAH heat-map cell (Fig. 9): plant a needle at `depth_frac` of a
/// `context`-token prompt and measure the method's retrieval score.
pub fn niah_cell(
    rt: Rc<PjrtRuntime>,
    mut cfg: EngineConfig,
    context: usize,
    depth_frac: f64,
    seed: u64,
    strength: f32,
) -> anyhow::Result<f64> {
    cfg.real_time = false;
    let spec = rt.manifest.presets[&cfg.preset].spec.clone();
    let vocab = spec.vocab;
    anyhow::ensure!(cfg.batch == 1, "niah_cell uses batch 1");
    let prompts = prompts_for(1, context, vocab, seed);

    // host-side mirror: prefill + the queries of the evaluation step
    let host = HostModel::new(spec.clone(), rt.host_weights(&cfg.preset)?);
    let (xs_last, caches) = host.prefill(&prompts[0]);
    let (tok0, _) = host.logits_argmax(xs_last.last().unwrap());
    let x0 = host.embed(tok0);
    let queries = collect_layer_queries(&host, &x0, &caches, context as i32);

    // needle position: inside the flushed region, away from the rolling
    // window
    let g = cfg.kv.group_size;
    let flushed = (context / g) * g;
    let max_pos = flushed.saturating_sub(cfg.kv.rb_slots + g).max(1);
    let pos = ((max_pos - 1) as f64 * depth_frac) as usize;

    let hd = spec.kv_flat_dim();
    let keys: Vec<Vec<f32>> = queries
        .iter()
        .map(|q| needle::needle_key(q, spec.n_kv_heads, spec.head_dim, spec.n_rep(), strength))
        .collect();
    let values: Vec<Vec<f32>> = (0..spec.n_layers)
        .map(|l| needle::marker_value(hd, seed ^ l as u64, 3.0))
        .collect();

    // oracle with needle
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.policy = Policy::FullMemory;
    let mut oracle = Engine::new(rt.clone(), oracle_cfg)?;
    oracle.prefill(&prompts)?;
    oracle.plant_needle(0, pos, &keys, &values)?;
    let (_, oxs, _) = oracle.decode(1, true, None)?;

    // method with needle
    let mut m = Engine::new(rt.clone(), cfg)?;
    m.prefill(&prompts)?;
    m.plant_needle(0, pos, &keys, &values)?;
    let (_, mxs, _) = m.decode(1, true, None)?;

    Ok(needle::retrieval_score(
        mxs[0].row(&[0]),
        oxs[0].row(&[0]),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::runtime::tensor::Tensor;
    use std::collections::HashMap;

    fn tiny_host() -> HostModel {
        let spec = ModelSpec {
            name: "t".into(),
            n_layers: 2,
            d_model: 16,
            n_q_heads: 4,
            n_kv_heads: 2,
            head_dim: 4,
            d_ff: 32,
            vocab: 32,
            rope_base: 10000.0,
            rms_eps: 1e-5,
        };
        let mut rng = Rng::new(3);
        let mut w = HashMap::new();
        w.insert("emb".into(), Tensor::from_vec(&[32, 16], (0..512).map(|_| rng.normal_f32(0.1)).collect()));
        w.insert("fln".into(), Tensor::full(&[16], 1.0));
        for i in 0..2 {
            for (t, shape) in [
                ("ln1", vec![16]),
                ("wq", vec![16, 16]),
                ("wk", vec![16, 8]),
                ("wv", vec![16, 8]),
                ("wo", vec![16, 16]),
                ("ln2", vec![16]),
                ("wg", vec![16, 32]),
                ("wu", vec![16, 32]),
                ("wd", vec![32, 16]),
            ] {
                let n: usize = shape.iter().product();
                let data = if t.starts_with("ln") {
                    vec![1.0; n]
                } else {
                    (0..n).map(|_| rng.normal_f32(0.15)).collect()
                };
                w.insert(format!("layer{i}.{t}"), Tensor::from_vec(&shape, data));
            }
        }
        HostModel::new(spec, Rc::new(w))
    }

    #[test]
    fn collect_layer_queries_matches_qkv_of_decode_path() {
        let host = tiny_host();
        let (_, caches) = host.prefill(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let x0 = host.embed(3);
        let qs = collect_layer_queries(&host, &x0, &caches, 8);
        assert_eq!(qs.len(), 2);
        // layer-0 query comes straight from x0
        let (q0, _, _) = host.qkv(0, &x0, 8);
        assert_eq!(qs[0], q0);
        // layer-1 query differs (x evolved through layer 0)
        let (q1_wrong, _, _) = host.qkv(1, &x0, 8);
        assert_ne!(qs[1], q1_wrong);
    }

    #[test]
    fn planted_needle_dominates_host_attention() {
        let host = tiny_host();
        let (_, mut caches) = host.prefill(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let x0 = host.embed(3);
        let qs = collect_layer_queries(&host, &x0, &caches, 8);
        let hd = host.spec.kv_flat_dim();
        let key = needle::needle_key(&qs[0], 2, 4, 2, 12.0);
        let marker = needle::marker_value(hd, 9, 3.0);
        caches[0].k[2 * hd..3 * hd].copy_from_slice(&key);
        caches[0].v[2 * hd..3 * hd].copy_from_slice(&marker);
        // attention at layer 0 should now return ~the marker
        let n = caches[0].len();
        let krows: Vec<&[f32]> = (0..n).map(|i| caches[0].k_row(i)).collect();
        let vrows: Vec<&[f32]> = (0..n).map(|i| caches[0].v_row(i)).collect();
        let out = host.attention(&qs[0], &krows, &vrows, None);
        let d = host.spec.head_dim;
        for hq in 0..host.spec.n_q_heads {
            let g = hq / host.spec.n_rep();
            let cos = mathx::cosine(&out[hq * d..(hq + 1) * d], &marker[g * d..(g + 1) * d]);
            assert!(cos > 0.95, "head {hq}: cos {cos}");
        }
    }
}
