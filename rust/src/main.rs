//! KVSwap CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   serve   — TCP serving front (newline JSON; see server module)
//!   run     — one-shot decode run with a chosen policy, prints stats
//!   quality — fidelity/token-agreement of a policy vs the Full-KV oracle
//!   tune    — offline parameter tuning (paper §3.5 / Appendix A)
//!   inspect — artifact manifest + preset summary
//!
//! Examples:
//!   kvswap run --policy kvswap --batch 4 --context 2048 --steps 64 --disk nvme
//!   kvswap run --policy kvswap --fault-rate 0.05 --fault-seed 7 --io-retries 5
//!   kvswap run --policy kvswap --store-dir /tmp/kv-store --store-capacity 256
//!   kvswap tune --budget-mib 2 --disk emmc --out kvswap_tuned.json
//!   kvswap serve --addr 127.0.0.1:7777 --policy kvswap --disk nvme
//!
//! Persistent-store flags (run/serve/quality):
//!   --store-dir PATH            persist the cross-request KV store here
//!   --store-mem                 enable the store on an in-memory backend
//!   --store-capacity MIB        store capacity before LRU eviction (256)
//!   --store-scrub-interval SEC  maintenance scrub deadline (5.0)
//!   --store-scrub-budget N      entries scrubbed per idle slice (4)
//!   --store-pipelined-restore on|off
//!                               stream warm-start restores under prefill
//!                               compute (on) or block up front (off)
//!
//! Serve flags:
//!   --batch-max-context N       batcher admission limit (defaults to
//!                               --max-context; set higher to exercise
//!                               contained wave errors)
//!   --max-conns N               stop after serving N connections

use kvswap::baselines::{configure, Budget};
use kvswap::config::{FaultConfig, KvSwapConfig, PrefetchConfig, RetryConfig, StoreConfig};
use kvswap::coordinator::batcher::BatcherConfig;
use kvswap::coordinator::router::Router;
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::{DiskProfile, StorageBackend};
use kvswap::metrics::Table;
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};
use kvswap::tuner;
use kvswap::util::cli::Args;
use kvswap::util::json::Json;
use kvswap::{log_info, quality};

fn main() {
    let args = Args::parse_env();
    if args.flag("verbose") {
        kvswap::util::set_log_level(2);
    }
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "quality" => cmd_quality(&args),
        "tune" => cmd_tune(&args),
        "inspect" => cmd_inspect(&args),
        _ => {
            eprintln!(
                "usage: kvswap <serve|run|quality|tune|inspect> [--options]\n\
                 see `rust/src/main.rs` header for examples"
            );
            Err(anyhow::anyhow!("unknown command {cmd:?}"))
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_common(args: &Args) -> anyhow::Result<EngineConfig> {
    let policy = Policy::by_name(&args.str_or("policy", "kvswap"))
        .ok_or_else(|| anyhow::anyhow!("unknown policy"))?;
    let disk = DiskProfile::by_name(&args.str_or("disk", "nvme"))
        .ok_or_else(|| anyhow::anyhow!("unknown disk"))?;
    let budget = if args.flag("tight") {
        Budget::Tight
    } else {
        Budget::Relaxed
    };
    let group = args.usize_or("group", if disk.name == "emmc" { 8 } else { 4 });
    let (policy, mut kv) = configure(&policy, budget, group);
    if let Some(r) = args.get("rank") {
        kv.rank = r.parse().unwrap_or(kv.rank);
    }
    if args.flag("no-reuse") {
        kv.use_reuse = false;
    }
    let pf_default = PrefetchConfig::default();
    let prefetch = if args.flag("sync-io") {
        PrefetchConfig::synchronous()
    } else {
        PrefetchConfig {
            workers: args.usize_or("prefetch-workers", pf_default.workers),
            queue_depth: args.usize_or("queue-depth", pf_default.queue_depth),
            coalesce_gap: args.usize_or("coalesce-gap", pf_default.coalesce_gap as usize) as u64,
            dispatch_window: args.usize_or("dispatch-window", pf_default.dispatch_window),
            aging_ms: args.u64_or("aging-ms", pf_default.aging_ms),
            unified_io: !args.flag("separate-io"),
        }
    };
    let storage = match args.get("storage-file") {
        Some(path) => StorageBackend::File(path.into()),
        None => StorageBackend::Mem,
    };
    let fault = FaultConfig {
        rate: args.f64_or("fault-rate", 0.0),
        corruption_rate: args.f64_or("fault-corrupt-rate", 0.0),
        seed: args.u64_or("fault-seed", 0),
        persistent: args.flag("fault-persistent"),
    };
    let store_default = StoreConfig::default();
    let store = StoreConfig {
        enabled: args.get("store-dir").is_some() || args.flag("store-mem"),
        dir: args.get("store-dir").map(std::path::PathBuf::from),
        capacity_bytes: (args.f64_or(
            "store-capacity",
            store_default.capacity_bytes as f64 / (1024.0 * 1024.0),
        ) * 1024.0
            * 1024.0) as u64,
        scrub_interval_s: args.f64_or("store-scrub-interval", store_default.scrub_interval_s),
        scrub_budget: args.usize_or("store-scrub-budget", store_default.scrub_budget),
        pipelined_restore: !matches!(
            args.get("store-pipelined-restore"),
            Some("off") | Some("false") | Some("0")
        ),
        compact_free_frac: args.f64_or("store-compact-frac", store_default.compact_free_frac),
    };
    let retry_default = RetryConfig::default();
    let retry = RetryConfig {
        max_retries: args.u64_or("io-retries", retry_default.max_retries as u64) as u32,
        breaker_threshold: args.u64_or(
            "breaker-threshold",
            retry_default.breaker_threshold as u64,
        ) as u32,
        ..retry_default
    };
    EngineConfig::builder()
        .preset(args.str_or("preset", "nano"))
        .batch(args.usize_or("batch", 1))
        .policy(policy)
        .kv(kv)
        .disk(disk)
        .storage(storage)
        .prefetch(prefetch)
        .fault(fault)
        .retry(retry)
        .store(store)
        .real_time(args.flag("real-time"))
        .time_scale(args.f64_or("time-scale", 1.0))
        .max_context(args.usize_or("max-context", args.usize_or("context", 2048)))
        .seed(args.u64_or("seed", 0))
        .build()
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_common(args)?;
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 32);
    let rt = std::rc::Rc::new(PjrtRuntime::new(Manifest::load(default_artifacts_dir())?)?);
    log_info!(
        "run: policy={} preset={} b={} context={} disk={} steps={}",
        cfg.policy.name(),
        cfg.preset,
        cfg.batch,
        context,
        cfg.disk.name,
        steps
    );
    let mut engine = Engine::new(rt, cfg.clone())?;
    engine.ingest_synthetic(&vec![context; cfg.batch])?;
    let (stats, _, _) = engine.decode(steps, false, None)?;
    println!(
        "throughput: {:.2} tokens/s  ({} tokens in {:.2}s {})",
        stats.tokens_per_sec(),
        stats.tokens,
        stats.seconds,
        if cfg.real_time { "wall" } else { "virtual" }
    );
    println!("bytes loaded: {}", kvswap::util::fmt_bytes(stats.bytes_loaded));
    println!("io utilization: {:.1}%", stats.io_utilization * 100.0);
    if let Some(r) = stats.reuse_rate {
        println!("reuse rate: {:.1}%", r * 100.0);
    }
    println!("selection overlap: {:.1}%", stats.mean_overlap * 100.0);
    let pf = stats.prefetch;
    if pf.io_retries + pf.corrupt_detected + pf.worker_panics + pf.breaker_trips > 0
        || stats.degraded_steps > 0
    {
        println!(
            "fault recovery: {} retries, {} corrupt extents, {} worker panics \
             ({} respawns), {} breaker trips, {} degraded layer-steps",
            pf.io_retries,
            pf.corrupt_detected,
            pf.worker_panics,
            pf.workers_restarted,
            pf.breaker_trips,
            stats.degraded_steps
        );
    }
    let lanes = engine.lane_summary();
    println!(
        "io lanes: critical {} ({:.0}us mean wait), warm {}, background {}, \
         {} cross-plan merges, {} aged promotions",
        lanes.lane_dispatched[kvswap::disk::Lane::Critical.idx()],
        lanes.mean_wait_us(kvswap::disk::Lane::Critical),
        lanes.lane_dispatched[kvswap::disk::Lane::Warm.idx()],
        lanes.lane_dispatched[kvswap::disk::Lane::Background.idx()],
        lanes.cross_plan_merges,
        lanes.aged_promotions
    );
    println!(
        "management memory: {}",
        kvswap::util::fmt_bytes(engine.management_bytes())
    );
    // Exercise the persistent store when enabled: persist this run's KV,
    // then run a full scrub pass so fault-injection runs cover the
    // detect → record → quarantine path end to end.
    if let Some(store) = engine.store() {
        let saved = engine.persist_synthetic()?;
        let report = store.scrub_now(usize::MAX);
        let c = store.counters();
        println!(
            "store: {} entries ({} used / {} capacity), {} persisted this run",
            store.entries(),
            kvswap::util::fmt_bytes(store.stored_bytes()),
            kvswap::util::fmt_bytes(store.capacity_bytes()),
            saved
        );
        println!(
            "store scrub: {} records scanned, {} corrupt, {} healed, {} quarantined",
            report.records_clean + report.corruptions,
            report.corruptions,
            report.healed,
            report.quarantined
        );
        println!(
            "store counters: {} hits, {} misses, {} saves, {} evictions, {} corruption sites",
            c.hits,
            c.misses,
            c.saves,
            c.evictions,
            store.corruption_sites().len()
        );
    }
    println!("latency breakdown:\n{}", stats.breakdown.report());
    Ok(())
}

fn cmd_quality(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_common(args)?;
    let context = args.usize_or("context", 1024);
    let steps = args.usize_or("steps", 16);
    let rt = std::rc::Rc::new(PjrtRuntime::new(Manifest::load(default_artifacts_dir())?)?);
    let rep = quality::evaluate_policy(rt, cfg, context, steps, args.u64_or("seed", 0))?;
    println!(
        "{}: fidelity={:.4} token_agreement={:.3} (context {context}, {} steps)",
        rep.policy, rep.fidelity, rep.token_agreement, rep.steps
    );
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let rt = std::rc::Rc::new(PjrtRuntime::new(Manifest::load(default_artifacts_dir())?)?);
    let preset = args.str_or("preset", "nano");
    let spec = rt
        .manifest
        .presets
        .get(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?
        .spec
        .clone();
    let disk = DiskProfile::by_name(&args.str_or("disk", "nvme"))
        .ok_or_else(|| anyhow::anyhow!("unknown disk"))?;
    let cfg = tuner::SolverConfig {
        budget_bytes: (args.f64_or("budget-mib", 2.0) * 1024.0 * 1024.0) as u64,
        s_max: args.usize_or("s-max", 2048),
        b_max: args.usize_or("b-max", 8),
        mg_entries: args.usize_or("mg", 256),
        alpha: args.f64_or("alpha", 0.15),
        ..Default::default()
    };
    // lookup table from the locality model (or measured via `run`)
    let table = tuner::tables::ReuseTable::from_locality_model(
        cfg.mg_entries / 4,
        0.77,
        &[0, 16, 32, 64, 128, 256, 512],
    );
    // profile a few live points so T_model is measured, not guessed
    let mut delays = tuner::DelayModel::default();
    for &(b, s) in &[(1usize, 1024usize), (1, 2048), (4, 2048)] {
        if b > cfg.b_max || s > cfg.s_max || !rt.manifest.has(&preset, b, "embed") {
            continue;
        }
        let mut e = Engine::new(
            rt.clone(),
            EngineConfig::builder()
                .preset(preset.clone())
                .batch(b)
                .policy(Policy::KvSwap)
                .kv(KvSwapConfig::default())
                .disk(disk.clone())
                .max_context(s)
                .build()?,
        )?;
        e.ingest_synthetic(&vec![s - 64; b])?;
        let (stats, _, _) = e.decode(6, false, None)?;
        let layers = spec.n_layers as f64;
        delays.add(tuner::ProfileSample {
            batch: b,
            context: s,
            group: 4,
            rank: 16,
            reuse_slots: KvSwapConfig::default().reuse_slots,
            t_io: stats.breakdown.get(kvswap::metrics::Phase::IoWait).as_secs_f64()
                / (stats.steps as f64 * layers),
            t_compute: (stats.breakdown.get(kvswap::metrics::Phase::Attention)
                + stats.breakdown.get(kvswap::metrics::Phase::Predict))
            .as_secs_f64()
                / (stats.steps as f64 * layers),
        });
        log_info!("profiled (b={b}, S={s})");
    }

    let sols = tuner::solve(&spec, &disk, &table, &delays, &cfg);
    let mut out = Json::obj();
    out.set("preset", preset.as_str().into());
    out.set("disk", disk.name.into());
    out.set("budget_bytes", (cfg.budget_bytes as usize).into());
    out.set("solutions", tuner::solver::solutions_to_json(&sols));
    let path = args.str_or("out", "kvswap_tuned.json");
    std::fs::write(&path, out.to_string_pretty())?;

    let mut t = Table::new(&["b", "S", "G", "rank", "C", "unhidden_io", "mgmt", "feasible"]);
    for s in &sols {
        t.row(vec![
            s.batch.to_string(),
            s.context.to_string(),
            s.group.to_string(),
            s.rank.to_string(),
            s.reuse_slots.to_string(),
            format!("{:.2}", s.unhidden_io),
            kvswap::util::fmt_bytes(s.mgmt_bytes),
            s.feasible.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("wrote {path}");
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_common(args)?;
    let addr = args.str_or("addr", "127.0.0.1:7777");
    let batcher = BatcherConfig {
        supported: args.usize_list_or("batches", &[1, 2, 4, 8]),
        linger_s: args.f64_or("linger", 0.05),
        // letting the batcher admit more than the engine is provisioned
        // for turns oversized requests into contained wave errors — the
        // CI fault smoke drives that path deliberately
        max_context: args.usize_or("batch-max-context", cfg.max_context),
    };
    let router = Router::spawn(default_artifacts_dir(), cfg, batcher);
    let max_conns = args.get("max-conns").and_then(|v| v.parse().ok());
    kvswap::server::serve(&addr, &router, max_conns)?;
    router.stop()
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let manifest = Manifest::load(default_artifacts_dir())?;
    let mut t = Table::new(&["preset", "params", "layers", "kv/token", "batches", "ncaps", "ranks"]);
    let mut names: Vec<&String> = manifest.presets.keys().collect();
    names.sort();
    for name in names {
        let p = &manifest.presets[name];
        t.row(vec![
            name.clone(),
            format!("{:.2}M", p.spec.n_params() as f64 / 1e6),
            p.spec.n_layers.to_string(),
            format!("{} B", p.spec.kv_bytes_per_token()),
            format!("{:?}", p.batches),
            format!("{:?}", p.ncaps),
            format!("{:?}", p.ranks),
        ]);
    }
    println!("{}", t.render());
    if args.flag("artifacts") {
        for name in manifest.presets.keys() {
            for b in &manifest.presets[name].batches {
                for a in manifest.artifact_names(name, *b) {
                    println!("{name}/b{b}/{a}");
                }
            }
        }
    }
    Ok(())
}
