//! Analytical memory models — reproduce the paper's shape-arithmetic
//! exhibits (Fig. 1 KV footprint, Fig. 3a management memory of prior
//! offloading schemes) without running the large models.

use crate::config::ModelSpec;

/// KV-cache bytes at f16 (the paper's W16A16 setting) for batch/context.
pub fn kv_cache_f16_bytes(spec: &ModelSpec, batch: usize, context: usize) -> u64 {
    // our ModelSpec arithmetic is f32; the paper's models store f16
    spec.kv_cache_bytes(batch, context) / 2
}

/// Management-memory models of the offloading baselines (paper Fig. 3a,
/// §2.4): what each scheme must keep *in memory* per sequence to decide
/// and serve selective loads. All in bytes, f16 entries like the paper.
pub mod mgmt {
    use super::*;

    /// InfiniGen keeps partial-weight projected K (ratio of the full K
    /// cache, default partial weight ratio 0.5 -> ~half the K cache) plus
    /// staging for selected entries.
    pub fn infinigen(spec: &ModelSpec, batch: usize, context: usize, partial_ratio: f64) -> u64 {
        let k_cache_f16 = spec.kv_cache_bytes(batch, context) / 2 / 2; // K only
        (k_cache_f16 as f64 * partial_ratio) as u64
    }

    /// ShadowKV keeps a conservative-rank low-rank K on GPU plus chunk
    /// landmarks and outliers; V goes off-memory. Rank per its paper:
    /// r=160 of head_dim*... modeled as rank/head_dim fraction of K cache
    /// plus 1/8 outliers.
    pub fn shadowkv(spec: &ModelSpec, batch: usize, context: usize, rank: usize) -> u64 {
        let hd = spec.kv_flat_dim();
        let k_cache_f16 = spec.kv_cache_bytes(batch, context) / 2 / 2;
        let lowrank = (k_cache_f16 as f64 * rank as f64 / hd as f64) as u64;
        let outliers = k_cache_f16 / 8;
        lowrank + outliers
    }

    /// KVSwap keeps only the compressed K cache (sigma compression) plus
    /// fixed-size buffers (reuse + rolling + staging).
    pub fn kvswap(
        spec: &ModelSpec,
        batch: usize,
        context: usize,
        sigma: f64,
        reuse_slots: usize,
        group: usize,
        rb: usize,
        mg: usize,
    ) -> u64 {
        let k_cache_f16 = spec.kv_cache_bytes(batch, context) / 2 / 2;
        let klr = (k_cache_f16 as f64 / sigma) as u64;
        let entry = spec.kv_bytes_per_token_layer() / 2; // f16 K+V one layer
        let l = spec.n_layers as u64;
        let fixed = batch as u64
            * (reuse_slots as u64 * group as u64 * entry * l + rb as u64 * entry * l
                + mg as u64 * entry);
        klr + fixed
    }

    /// Full cache in memory (vLLM-like / Full-KV).
    pub fn full(spec: &ModelSpec, batch: usize, context: usize) -> u64 {
        kv_cache_f16_bytes(spec, batch, context)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_spec;

    #[test]
    fn fig1_qwen3_4b_numbers() {
        let q = paper_spec("qwen3-4b");
        // paper: 16K ctx, batch 4 -> ~9 GiB
        let gib = kv_cache_f16_bytes(&q, 4, 16384) as f64 / (1u64 << 30) as f64;
        assert!((8.0..10.0).contains(&gib), "{gib}");
        // 32K ctx, batch 12 -> ~54 GiB
        let gib2 = kv_cache_f16_bytes(&q, 12, 32768) as f64 / (1u64 << 30) as f64;
        assert!((50.0..58.0).contains(&gib2), "{gib2}");
    }

    #[test]
    fn fig3a_infinigen_shadowkv_are_heavy_kvswap_is_light() {
        let l = paper_spec("llama3-8b");
        let (b, s) = (8, 16384);
        let ig = mgmt::infinigen(&l, b, s, 0.5);
        let sk = mgmt::shadowkv(&l, b, s, 160);
        // tuned KVSwap-t config at paper scale: sigma=32, C=24 groups
        let kv = mgmt::kvswap(&l, b, s, 32.0, 24, 8, 16, 400);
        let full = mgmt::full(&l, b, s);
        // paper Fig. 3a: InfiniGen ~4 GiB, ShadowKV ~2.7 GiB at 16K, b=8
        let gib = |x: u64| x as f64 / (1u64 << 30) as f64;
        assert!((3.0..5.5).contains(&gib(ig)), "infinigen {}", gib(ig));
        assert!((1.8..3.8).contains(&gib(sk)), "shadowkv {}", gib(sk));
        // KVSwap management memory is far below both and below full/13
        assert!(kv < sk / 3, "kvswap {} vs shadowkv {}", gib(kv), gib(sk));
        assert!(kv < full / 13, "kvswap {} vs full {}", gib(kv), gib(full));
    }

    #[test]
    fn mgmt_memory_scales_linearly_with_context() {
        let l = paper_spec("llama3-8b");
        let a = mgmt::infinigen(&l, 8, 8192, 0.5);
        let b = mgmt::infinigen(&l, 8, 16384, 0.5);
        assert!((b as f64 / a as f64 - 2.0).abs() < 0.01);
    }
}
