//! Request-trace generation for the serving benches and the TCP example:
//! long-context requests with configurable context lengths, decode
//! lengths, and Poisson-ish arrivals.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Prompt context length (tokens already in the KV cache).
    pub context: usize,
    /// Tokens to generate.
    pub decode: usize,
    /// Arrival time offset in seconds from trace start.
    pub arrival_s: f64,
    /// Seed for the request's synthetic content.
    pub seed: u64,
    /// Explicit prompt tokens (server `"tokens": [...]` payloads). When
    /// set, `prompt_tokens` returns these verbatim — the path that lets
    /// repeated real prompts hit the persistent KV store; when `None`
    /// the prompt is derived from `seed`.
    pub tokens: Option<Vec<i32>>,
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub context_min: usize,
    pub context_max: usize,
    pub decode_min: usize,
    pub decode_max: usize,
    /// Mean arrival rate (req/s); 0 = all arrive at t=0.
    pub rate: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 8,
            context_min: 512,
            context_max: 2048,
            decode_min: 32,
            decode_max: 128,
            rate: 0.0,
            seed: 0,
        }
    }
}

pub fn generate(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0;
    (0..cfg.n_requests)
        .map(|i| {
            if cfg.rate > 0.0 {
                // exponential inter-arrival
                t += -(1.0 - rng.f64()).ln() / cfg.rate;
            }
            Request {
                id: i as u64,
                context: if cfg.context_max > cfg.context_min {
                    rng.range(cfg.context_min, cfg.context_max + 1)
                } else {
                    cfg.context_min
                },
                decode: if cfg.decode_max > cfg.decode_min {
                    rng.range(cfg.decode_min, cfg.decode_max + 1)
                } else {
                    cfg.decode_min
                },
                arrival_s: t,
                seed: cfg.seed.wrapping_add(i as u64 * 7919),
                tokens: None,
            }
        })
        .collect()
}

/// Prompt for a request: the explicit tokens when the client sent them,
/// else a seeded random prompt (vocabulary-bounded).
pub fn prompt_tokens(req: &Request, vocab: usize) -> Vec<i32> {
    if let Some(t) = &req.tokens {
        return t.clone();
    }
    let mut rng = Rng::new(req.seed);
    (0..req.context).map(|_| rng.below(vocab) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_respects_bounds_and_is_deterministic() {
        let cfg = TraceConfig {
            n_requests: 20,
            context_min: 100,
            context_max: 200,
            decode_min: 5,
            decode_max: 10,
            rate: 2.0,
            seed: 3,
        };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        let mut last_t = 0.0;
        for r in &a {
            assert!((100..=200).contains(&r.context));
            assert!((5..=10).contains(&r.decode));
            assert!(r.arrival_s >= last_t);
            last_t = r.arrival_s;
        }
    }

    #[test]
    fn zero_rate_means_batch_arrival() {
        let cfg = TraceConfig {
            rate: 0.0,
            ..Default::default()
        };
        for r in generate(&cfg) {
            assert_eq!(r.arrival_s, 0.0);
        }
    }

    #[test]
    fn prompt_tokens_in_vocab() {
        let r = Request {
            id: 0,
            context: 50,
            decode: 1,
            arrival_s: 0.0,
            seed: 9,
            tokens: None,
        };
        let toks = prompt_tokens(&r, 512);
        assert_eq!(toks.len(), 50);
        assert!(toks.iter().all(|&t| (0..512).contains(&t)));
        assert_eq!(toks, prompt_tokens(&r, 512));
    }

    #[test]
    fn explicit_tokens_override_seeded_prompt() {
        let r = Request {
            id: 0,
            context: 3,
            decode: 1,
            arrival_s: 0.0,
            seed: 9,
            tokens: Some(vec![5, 6, 7]),
        };
        assert_eq!(prompt_tokens(&r, 512), vec![5, 6, 7]);
    }
}
