//! Needle-in-a-haystack (NIAH) synthetic quality workload.
//!
//! The paper evaluates retrieval with NIAH [32] on pretrained models. Our
//! models are random-initialized (DESIGN.md §2), so we plant the needle
//! *in KV space*: given the true query the model will issue at the
//! evaluation step, we overwrite the K row at the needle position with a
//! strongly query-aligned key and the V row with a distinctive marker.
//! Full-KV attention then provably retrieves the marker; an offloading
//! method retrieves it only if (a) its compressed predictor still scores
//! the needle's group on top and (b) it actually loads the group — which
//! is exactly the selection-quality mechanism the paper's NIAH heatmaps
//! (Fig. 9) measure.

use crate::util::mathx;
use crate::util::rng::Rng;

/// Build the query-aligned needle key row for a GQA model: KV head g gets
/// the normalized sum of its query heads, scaled by `strength`.
pub fn needle_key(q_flat: &[f32], n_kv_heads: usize, d: usize, n_rep: usize, strength: f32) -> Vec<f32> {
    assert_eq!(q_flat.len(), n_kv_heads * n_rep * d);
    let mut k = vec![0.0f32; n_kv_heads * d];
    for g in 0..n_kv_heads {
        let dst = &mut k[g * d..(g + 1) * d];
        for r in 0..n_rep {
            let h = g * n_rep + r;
            for (o, q) in dst.iter_mut().zip(&q_flat[h * d..(h + 1) * d]) {
                *o += q;
            }
        }
        let norm = mathx::l2(dst).max(1e-9);
        for o in dst.iter_mut() {
            *o *= strength / norm;
        }
    }
    k
}

/// Distinctive marker value row (deterministic per tag).
pub fn marker_value(hd: usize, tag: u64, scale: f32) -> Vec<f32> {
    let mut rng = Rng::new(0xBEEF ^ tag);
    let mut v: Vec<f32> = (0..hd).map(|_| rng.normal_f32(1.0)).collect();
    let norm = mathx::l2(&v).max(1e-9);
    for x in v.iter_mut() {
        *x *= scale / norm;
    }
    v
}

/// Overwrite the KV rows at `token_pos` in token-major row storage.
pub fn plant(
    k_rows: &mut [f32],
    v_rows: &mut [f32],
    hd: usize,
    token_pos: usize,
    key: &[f32],
    value: &[f32],
) {
    assert_eq!(key.len(), hd);
    assert_eq!(value.len(), hd);
    k_rows[token_pos * hd..(token_pos + 1) * hd].copy_from_slice(key);
    v_rows[token_pos * hd..(token_pos + 1) * hd].copy_from_slice(value);
}

/// Retrieval is judged by cosine similarity between the method's
/// attention output and the Full-KV oracle output (which the planted
/// needle dominates). The paper's heatmap scores map to this in [0, 1].
pub fn retrieval_score(method_out: &[f32], oracle_out: &[f32]) -> f64 {
    mathx::cosine(method_out, oracle_out).max(0.0) as f64
}

/// Needle depths for the Fig. 9 heatmap y-axis: fractions of the context.
pub fn depth_positions(context: usize, n_depths: usize) -> Vec<usize> {
    (0..n_depths)
        .map(|i| {
            let frac = i as f64 / (n_depths.saturating_sub(1).max(1)) as f64;
            ((context - 1) as f64 * frac) as usize
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needle_key_is_query_aligned_per_group() {
        let (hkv, d, n_rep) = (2, 4, 2);
        let q: Vec<f32> = (0..hkv * n_rep * d).map(|i| (i % 5) as f32 - 2.0).collect();
        let k = needle_key(&q, hkv, d, n_rep, 10.0);
        assert_eq!(k.len(), hkv * d);
        for g in 0..hkv {
            let kg = &k[g * d..(g + 1) * d];
            assert!((mathx::l2(kg) - 10.0).abs() < 1e-4);
            // dot with each of the group's query heads is positive overall
            let mut dot_sum = 0.0;
            for r in 0..n_rep {
                let h = g * n_rep + r;
                dot_sum += mathx::dot(kg, &q[h * d..(h + 1) * d]);
            }
            assert!(dot_sum > 0.0);
        }
    }

    #[test]
    fn plant_overwrites_only_target_row() {
        let hd = 4;
        let mut k = vec![1.0f32; 3 * hd];
        let mut v = vec![2.0f32; 3 * hd];
        plant(&mut k, &mut v, hd, 1, &[9.0; 4], &[8.0; 4]);
        assert_eq!(&k[0..4], &[1.0; 4]);
        assert_eq!(&k[4..8], &[9.0; 4]);
        assert_eq!(&k[8..12], &[1.0; 4]);
        assert_eq!(&v[4..8], &[8.0; 4]);
    }

    #[test]
    fn marker_deterministic_distinct() {
        let a = marker_value(8, 1, 3.0);
        let b = marker_value(8, 1, 3.0);
        let c = marker_value(8, 2, 3.0);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!((mathx::l2(&a) - 3.0).abs() < 1e-4);
    }

    #[test]
    fn depth_positions_span_context() {
        let d = depth_positions(1000, 5);
        assert_eq!(d.first(), Some(&0));
        assert_eq!(d.last(), Some(&999));
        assert_eq!(d.len(), 5);
        assert!(d.windows(2).all(|w| w[1] > w[0]));
        assert_eq!(depth_positions(10, 1), vec![0]);
    }

    #[test]
    fn retrieval_score_bounds() {
        let a = [1.0, 0.0];
        assert!((retrieval_score(&a, &a) - 1.0).abs() < 1e-6);
        assert_eq!(retrieval_score(&[-1.0, 0.0], &a), 0.0); // clamped
    }
}
