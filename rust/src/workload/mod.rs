//! Workloads: request trace generation, synthetic KV materialization for
//! long-context decode benches, needle planting for the NIAH quality
//! harness, and the analytical memory models behind Fig. 1 / Fig. 3a.

pub mod memory_model;
pub mod needle;
pub mod tracegen;

use crate::util::rng::Rng;

/// Materialize realistic-scale synthetic KV rows for decode-throughput
/// benches (decode speed does not depend on KV *content*; quality benches
/// use real prefill instead — DESIGN.md §2). Rows are N(0, 0.6) like
/// post-RoPE K/V of the nano model.
pub fn synthetic_kv_rows(n_tokens: usize, hd: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let k = (0..n_tokens * hd).map(|_| rng.normal_f32(0.6)).collect();
    let v = (0..n_tokens * hd).map(|_| rng.normal_f32(0.6)).collect();
    (k, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_kv_deterministic_and_scaled() {
        let (k1, v1) = synthetic_kv_rows(16, 8, 42);
        let (k2, _) = synthetic_kv_rows(16, 8, 42);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 128);
        let std = {
            let mean = k1.iter().sum::<f32>() / k1.len() as f32;
            (k1.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / k1.len() as f32).sqrt()
        };
        assert!((0.3..0.9).contains(&std), "std {std}");
        assert_ne!(k1, v1);
    }
}
