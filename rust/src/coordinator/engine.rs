//! The decode engine: KVSwap's layer-pipelined, I/O-overlapped decode
//! loop (paper §3.4), shared by every baseline policy.
//!
//! Per decode step (policy = KvSwap):
//!
//! ```text
//! x0 = embed(tok)                 (loads for layer 0 were issued at the
//! for layer l in 0..L:             end of the previous step)
//!     recv staged bytes, layer l ── prefetch pool (coalesced batch reads)
//!     predict layer l+1 scores from x_l (HLO predict artifact, Eq. 1)
//!     select top-M groups, diff vs reuse buffer, submit preload plan ──►
//!     gather: mapping table -> contiguous k_sel/v_sel/mask
//!     x_{l+1} = decode_block(l, x_l, gathered KV)   (Pallas kernel)
//! tok' = logits_argmax(x_L); append per-layer new KV (rolling buffer,
//! group flush -> disk + K_lr); predict layer 0 for the next step.
//! ```
//!
//! The hot path never calls `Backend::read_at` synchronously: plans are
//! submitted to the [`Prefetcher`] ahead of compute and the gather only
//! waits on already-staged buffers, so `Phase::IoWait` measures the
//! *residual* stall, not full read latency. The prefetch workers touch
//! only `Backend` + staging memory — the `Rc<PjrtRuntime>` stays here.
//!
//! Timing: in **real** mode the prefetch workers genuinely sleep (SimDisk
//! pacing) and the pipeline overlap is physical. In **virtual** mode the
//! engine folds measured compute and modeled I/O into a virtual clock:
//! per layer, `stall = max(0, io_time - compute_since_issue)` — the
//! overlap accounting of Appendix A.4.

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::policy::Policy;
use crate::config::{FaultConfig, KvSwapConfig, ModelSpec, PrefetchConfig, RetryConfig, StoreConfig};
use crate::disk::{
    Backend, BreakerState, DiskProfile, FaultBackend, IoScheduler, LaneSummary, PlannedExtent,
    Prefetcher, PreloadPlan, RetryPolicy, SimDisk, StorageBackend,
};
use crate::kvcache::{DiskLayout, KvManager, ManagerConfig, SeqState};
use crate::metrics::{Breakdown, DecodeStats, Phase};
use crate::predictor::{self, OverlapTracker};
use crate::store::{ChunkTicket, PersistentStore, PrefixMatch};
use crate::runtime::host_ref::{HostModel, KvLayer};
use crate::runtime::tensor::{Tensor, TensorI32};
use crate::runtime::{ModelRuntime, PjrtRuntime};
use crate::util::clock::Clock;
use crate::util::mathx;
use crate::util::rng::Rng;
use crate::workload::synthetic_kv_rows;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub preset: String,
    pub batch: usize,
    pub policy: Policy,
    pub kv: KvSwapConfig,
    pub disk: DiskProfile,
    /// Where the offloaded KV bytes physically live.
    pub storage: StorageBackend,
    /// Prefetch-pipeline shape (workers / queue depth / coalescing gap).
    pub prefetch: PrefetchConfig,
    /// Fault injection on the storage read path (disabled by default;
    /// non-zero rates wrap the backend in a [`FaultBackend`]).
    pub fault: FaultConfig,
    /// Retry/backoff + circuit-breaker policy for staging reads.
    pub retry: RetryConfig,
    /// Persistent KV store for cross-request prefix reuse (opt-in).
    pub store: StoreConfig,
    /// true: SimDisk sleeps (scaled); false: virtual-clock accounting.
    pub real_time: bool,
    pub time_scale: f64,
    /// Maximum context to provision (chooses ncap + disk capacity).
    pub max_context: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            preset: "nano".into(),
            batch: 1,
            policy: Policy::KvSwap,
            kv: KvSwapConfig::default(),
            disk: DiskProfile::nvme(),
            storage: StorageBackend::Mem,
            prefetch: PrefetchConfig::default(),
            fault: FaultConfig::default(),
            retry: RetryConfig::default(),
            store: StoreConfig::default(),
            real_time: false,
            time_scale: 1.0,
            max_context: 2048,
            seed: 0,
        }
    }
}

impl EngineConfig {
    /// Validating construction — the supported way to build a config
    /// (struct literals remain possible for tests via `Default`).
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder {
            cfg: EngineConfig::default(),
        }
    }
}

/// Chainable, validating builder for [`EngineConfig`]. `build()` rejects
/// shapes the engine cannot run (zero group size, zero queue depth, an
/// n-cap / attention width too small for the selection it must hold).
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    pub fn preset(mut self, preset: impl Into<String>) -> Self {
        self.cfg.preset = preset.into();
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.cfg.batch = batch;
        self
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn kv(mut self, kv: KvSwapConfig) -> Self {
        self.cfg.kv = kv;
        self
    }

    pub fn disk(mut self, disk: DiskProfile) -> Self {
        self.cfg.disk = disk;
        self
    }

    pub fn storage(mut self, storage: StorageBackend) -> Self {
        self.cfg.storage = storage;
        self
    }

    pub fn prefetch(mut self, prefetch: PrefetchConfig) -> Self {
        self.cfg.prefetch = prefetch;
        self
    }

    pub fn fault(mut self, fault: FaultConfig) -> Self {
        self.cfg.fault = fault;
        self
    }

    pub fn retry(mut self, retry: RetryConfig) -> Self {
        self.cfg.retry = retry;
        self
    }

    pub fn store(mut self, store: StoreConfig) -> Self {
        self.cfg.store = store;
        self
    }

    pub fn real_time(mut self, real_time: bool) -> Self {
        self.cfg.real_time = real_time;
        self
    }

    pub fn time_scale(mut self, time_scale: f64) -> Self {
        self.cfg.time_scale = time_scale;
        self
    }

    pub fn max_context(mut self, max_context: usize) -> Self {
        self.cfg.max_context = max_context;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn build(self) -> anyhow::Result<EngineConfig> {
        let c = &self.cfg;
        anyhow::ensure!(!c.preset.is_empty(), "preset must be named");
        anyhow::ensure!(c.batch >= 1, "batch must be >= 1");
        anyhow::ensure!(c.max_context >= 1, "max_context must be >= 1");
        anyhow::ensure!(c.kv.group_size >= 1, "kv.group_size must be >= 1");
        anyhow::ensure!(c.kv.n_groups >= 1, "kv.n_groups must be >= 1");
        anyhow::ensure!(c.kv.rank >= 1, "kv.rank must be >= 1");
        anyhow::ensure!(
            c.prefetch.queue_depth >= 1,
            "prefetch.queue_depth must be >= 1"
        );
        anyhow::ensure!(
            c.prefetch.dispatch_window >= 1,
            "prefetch.dispatch_window must be >= 1"
        );
        anyhow::ensure!(
            c.time_scale >= 0.0 && c.time_scale.is_finite(),
            "time_scale must be finite and >= 0"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&c.fault.rate) && c.fault.rate.is_finite(),
            "fault.rate must be a probability in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&c.fault.corruption_rate) && c.fault.corruption_rate.is_finite(),
            "fault.corruption_rate must be a probability in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&c.retry.jitter) && c.retry.jitter.is_finite(),
            "retry.jitter must be in [0, 1]"
        );
        anyhow::ensure!(
            c.retry.backoff_base_ms >= 0.0 && c.retry.backoff_base_ms.is_finite(),
            "retry.backoff_base_ms must be finite and >= 0"
        );
        anyhow::ensure!(
            c.retry.backoff_max_ms >= c.retry.backoff_base_ms && c.retry.backoff_max_ms.is_finite(),
            "retry.backoff_max_ms must be finite and >= backoff_base_ms"
        );
        anyhow::ensure!(
            c.retry.breaker_threshold >= 1,
            "retry.breaker_threshold must be >= 1"
        );
        anyhow::ensure!(
            c.retry.breaker_probe_after >= 1,
            "retry.breaker_probe_after must be >= 1"
        );
        anyhow::ensure!(
            c.store.scrub_interval_s.is_finite(),
            "store.scrub_interval_s must be finite"
        );
        if c.store.enabled {
            anyhow::ensure!(
                c.store.capacity_bytes >= 1,
                "store.capacity_bytes must be >= 1 when the store is enabled"
            );
            anyhow::ensure!(
                c.store.scrub_budget >= 1,
                "store.scrub_budget must be >= 1 when the store is enabled"
            );
        }
        let needed = c.kv.selected_entries() + c.kv.rb_slots;
        anyhow::ensure!(
            c.kv.p_sel >= needed,
            "p_sel {} below selection + rolling ({needed})",
            c.kv.p_sel
        );
        anyhow::ensure!(
            c.kv.ncap >= needed,
            "ncap {} inconsistent: below selection + rolling ({needed})",
            c.kv.ncap
        );
        Ok(self.cfg)
    }
}

/// Per-sequence engine state.
struct SeqUnit {
    kv: SeqState,
    /// Full in-memory cache (FullMemory policy and FlexGen staging).
    mem: Vec<KvLayer>,
    last_token: i32,
    /// Current context length (== position of the token being decoded).
    pos: usize,
    /// Per-layer staging for loads when the reuse buffer is off.
    staging: Vec<HashMap<u32, Vec<f32>>>,
    /// Selection in flight per layer (set when loads are issued).
    pending_sel: Vec<Vec<u32>>,
    /// Per-layer freshly generated KV awaiting the post-logits append.
    pending_kv: Vec<Option<(Vec<f32>, Vec<f32>)>>,
}

pub struct Engine {
    pub cfg: EngineConfig,
    spec: ModelSpec,
    mr: ModelRuntime,
    host: HostModel,
    manager: KvManager,
    pub disk: Arc<SimDisk>,
    clock: Clock,
    /// Per-layer prediction adapter (policy-dependent construction).
    adapters: Vec<Tensor>,
    seqs: Vec<SeqUnit>,
    /// The asynchronous preload pipeline (or its synchronous fallback).
    prefetcher: Prefetcher,
    pub breakdown: Breakdown,
    /// One tracker per (seq, layer): overlap is a per-stream statistic
    /// (paper Fig. 8 tracks a single layer across steps).
    pub overlap: Vec<Vec<OverlapTracker>>,
    ncap: usize,
    rank: usize,
    /// Layer-0 loads already in flight (issued at the end of the
    /// previous step / a previous decode() call).
    l0_inflight: bool,
    /// Cached padded K_lr tensors per layer ([b, ncap, r]), synced
    /// incrementally as groups flush — avoids rebuilding ~1 MiB/layer
    /// from scratch every predict call (EXPERIMENTS.md §Perf change 2).
    klr_cache: Vec<Tensor>,
    /// Rows of `klr_cache` already synced, per (layer, seq).
    klr_synced: Vec<Vec<usize>>,
    /// Most recent final activations [b, D] (for quality comparison).
    pub last_x: Option<Tensor>,
    decode_t0: Option<f64>,
    tokens_generated: u64,
    steps_done: u64,
    /// Layer-awaits that fell back to resident-only attention after an
    /// unrecoverable staged load (degradation rung 4).
    degraded: u64,
    /// Persistent cross-request KV store (None unless `cfg.store.enabled`
    /// or a shared store was injected via [`Engine::with_store`]).
    store: Option<Arc<PersistentStore>>,
    /// Prompt tokens warm-started from the store instead of recomputed,
    /// summed over prefill calls and all batch rows.
    reused_prefix_tokens: u64,
    /// Prefill-phase restore stalls (full blocking time, or the residual
    /// the pipelined worker failed to hide), summed over prefill calls.
    prefill_io_wait: Duration,
    /// Store device read-busy time incurred by warm-start restores.
    prefill_store_busy: Duration,
}

/// Message stream from the store-restore worker to prefill: staged
/// `(layer, chunk)` units in layer-major order, tear notices, then
/// `Done`.
enum RestoreMsg {
    Unit {
        layer: usize,
        /// Chunk index inside the warm region (token offset = `chunk *
        /// prefill_chunk`).
        chunk: usize,
        /// Per-batch-row token-major `(k_rows, v_rows)` for this range.
        per_seq: Vec<(Vec<f32>, Vec<f32>)>,
        /// Modeled device time of the reads behind this unit.
        io_time: Duration,
        issued_at: Instant,
    },
    /// Warm chunks `>= chunk` are unusable (a record stayed bad after
    /// retry); prefill degrades by recomputing from that chunk onward,
    /// keeping every chunk restored before it.
    Torn { chunk: usize },
    Done,
}

/// Engine-side handle on the pipelined warm-start restore stream.
struct RestorePipeline {
    rx: std::sync::mpsc::Receiver<RestoreMsg>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Chunks committed into the prefill caches, per layer.
    committed: Vec<usize>,
    done: bool,
}

/// Backpressure bound on staged-but-uncommitted units (each holds
/// `batch * chunk * hd * 2` floats): the worker stays a few units ahead
/// of compute without buffering the whole warm region in memory.
const RESTORE_QUEUE_DEPTH: usize = 4;

/// How many `(layer, chunk)` units the restore worker keeps *submitted*
/// on the `Warm` lane before redeeming the oldest. A window > 1 is what
/// gives the unified scheduler adjacent record extents to coalesce
/// across plans (layer-major layout makes consecutive chunks of a layer
/// — and the last chunk of layer `l` with the first of `l+1` —
/// contiguous on disk).
const RESTORE_SUBMIT_AHEAD: usize = 4;

/// Stream the warm region out of the store on a dedicated thread,
/// layer-major (all of layer 0's chunks, then layer 1's, …) to match
/// prefill's consumption order: the first computed chunk touches layers
/// in ascending order and layer `l` only needs its *own* warm chunks
/// staged, so later layers' reads overlap earlier layers' compute. The
/// worker shares only the `PersistentStore` (its backend + book-keeping
/// are thread-safe); everything runtime-bound stays on the engine
/// thread, mirroring the prefetch pool's split.
///
/// When the store is attached to the unified I/O scheduler, each unit's
/// record reads are submitted ahead on the `Warm` lane (a sliding window
/// of [`RESTORE_SUBMIT_AHEAD`] units) and redeemed in order; unattached,
/// `submit_chunk` returns `None` and the unit falls back to a direct
/// [`PersistentStore::restore_chunk`] with identical semantics.
fn spawn_restore_worker(
    store: Arc<PersistentStore>,
    matches: Vec<PrefixMatch>,
    warm_chunks: usize,
    chunk: usize,
    n_layers: usize,
) -> RestorePipeline {
    let (tx, rx) = std::sync::mpsc::sync_channel(RESTORE_QUEUE_DEPTH);
    let handle = std::thread::Builder::new()
        .name("store-restore".into())
        .spawn(move || {
            // a tear shrinks the usable region for *every* layer: chunks
            // at or past the tear are skipped, earlier ones keep flowing
            let mut limit = warm_chunks;
            // sliding submit-ahead window: (layer, chunk, issue time,
            // one optional Warm-lane ticket per batch row)
            type Inflight = (usize, usize, Instant, Vec<Option<ChunkTicket>>);
            let mut inflight: VecDeque<Inflight> = VecDeque::new();
            let total = n_layers * warm_chunks;
            let mut next = 0usize; // unit index = layer * warm_chunks + c
            loop {
                while inflight.len() < RESTORE_SUBMIT_AHEAD && next < total {
                    let (layer, c) = (next / warm_chunks, next % warm_chunks);
                    next += 1;
                    if c >= limit {
                        continue; // past a tear: never issued
                    }
                    let issued_at = Instant::now();
                    let tickets: Vec<Option<ChunkTicket>> = matches
                        .iter()
                        .map(|m| store.submit_chunk(m, layer, c * chunk, chunk))
                        .collect();
                    inflight.push_back((layer, c, issued_at, tickets));
                }
                let Some((layer, c, issued_at, tickets)) = inflight.pop_front() else {
                    break; // everything issued and drained
                };
                if c >= limit {
                    continue; // torn after issue: dropped tickets abandon
                }
                let mut per_seq = Vec::with_capacity(matches.len());
                let mut io_time = Duration::ZERO;
                let mut torn = false;
                for (m, t) in matches.iter().zip(tickets) {
                    let restored = match t {
                        Some(t) => store.complete_chunk(t),
                        None => store.restore_chunk(m, layer, c * chunk, chunk),
                    };
                    match restored {
                        Ok(r) => {
                            io_time += r.io_time;
                            per_seq.push((r.k_rows, r.v_rows));
                        }
                        Err(e) => {
                            crate::log_debug!(
                                "pipelined restore tore at layer {layer} chunk {c}: {e}"
                            );
                            torn = true;
                            break;
                        }
                    }
                }
                if torn {
                    limit = c;
                    if tx.send(RestoreMsg::Torn { chunk: c }).is_err() {
                        return; // engine gone
                    }
                    if limit == 0 {
                        break;
                    }
                    continue;
                }
                let unit = RestoreMsg::Unit { layer, chunk: c, per_seq, io_time, issued_at };
                if tx.send(unit).is_err() {
                    return;
                }
            }
            let _ = tx.send(RestoreMsg::Done);
        })
        .expect("spawn store-restore worker");
    RestorePipeline {
        rx,
        handle: Some(handle),
        committed: vec![0; n_layers],
        done: false,
    }
}

/// Scatter token-major `(k_rows, v_rows)` into one layer's
/// `[b, hkv, ncap, d]` prefill caches at token offset `t0`.
#[allow(clippy::too_many_arguments)]
fn scatter_chunk(
    k_cache: &mut Tensor,
    v_cache: &mut Tensor,
    bi: usize,
    hkv: usize,
    d: usize,
    hd: usize,
    t0: usize,
    n_tokens: usize,
    k_rows: &[f32],
    v_rows: &[f32],
) {
    for t in 0..n_tokens {
        for g in 0..hkv {
            for dd in 0..d {
                *k_cache.at_mut(&[bi, g, t0 + t, dd]) = k_rows[t * hd + g * d + dd];
                *v_cache.at_mut(&[bi, g, t0 + t, dd]) = v_rows[t * hd + g * d + dd];
            }
        }
    }
}

impl Engine {
    pub fn new(rt: Rc<PjrtRuntime>, cfg: EngineConfig) -> anyhow::Result<Engine> {
        Engine::with_store(rt, cfg, None)
    }

    /// Build an engine sharing an already-open persistent store. The
    /// router uses this to keep one store alive across per-wave engines;
    /// `None` with `cfg.store.enabled` opens a fresh store from the
    /// engine's own layout (the single source of slot-geometry truth).
    pub fn with_store(
        rt: Rc<PjrtRuntime>,
        cfg: EngineConfig,
        store: Option<Arc<PersistentStore>>,
    ) -> anyhow::Result<Engine> {
        let info = rt
            .manifest
            .presets
            .get(&cfg.preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {}", cfg.preset))?
            .clone();
        let spec = info.spec.clone();
        let mr = ModelRuntime::new(rt.clone(), &cfg.preset, cfg.batch)?;
        let host = HostModel::new(spec.clone(), rt.host_weights(&cfg.preset)?);

        // policy-specific group granularity on disk
        let (g_layout, rank) = match &cfg.policy {
            Policy::KvSwap | Policy::FlexGen | Policy::FullMemory => {
                (cfg.kv.group_size, cfg.kv.rank)
            }
            Policy::InfiniGen { .. } | Policy::Loki => (1, cfg.kv.rank),
            Policy::ShadowKv { chunk, rank } => (*chunk, *rank),
        };
        // clamp to the nearest exported adapter rank (small/med presets
        // only export rank 16); everything downstream (manager, K_lr,
        // predict artifact, adapters) uses the effective rank
        let rank = if info.ranks.contains(&rank) {
            rank
        } else {
            let eff = *info
                .ranks
                .iter()
                .min_by_key(|&&a| (a as i64 - rank as i64).unsigned_abs())
                .ok_or_else(|| anyhow::anyhow!("no adapter ranks for {}", cfg.preset))?;
            crate::log_debug!(
                "preset {} has no rank-{rank} adapter; using {eff}",
                cfg.preset
            );
            eff
        };
        // predict artifact variant: smallest compiled ncap covering the
        // provisioned context *that exists for this rank* (rank sweeps
        // are only compiled at some ncaps)
        let mut ncaps = info.ncaps.clone();
        ncaps.sort_unstable();
        let ncap = if matches!(cfg.policy, Policy::KvSwap) {
            *ncaps
                .iter()
                .filter(|&&n| {
                    rt.manifest
                        .has(&cfg.preset, cfg.batch, &format!("predict_n{n}_r{rank}"))
                })
                .find(|&&n| n >= cfg.max_context)
                .or_else(|| {
                    ncaps.iter().rev().find(|&&n| {
                        rt.manifest
                            .has(&cfg.preset, cfg.batch, &format!("predict_n{n}_r{rank}"))
                    })
                })
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no predict artifact for rank {rank} in {}/b{}",
                        cfg.preset,
                        cfg.batch
                    )
                })?
        } else {
            *ncaps
                .iter()
                .find(|&&n| n >= cfg.max_context)
                .unwrap_or(ncaps.last().expect("no ncaps"))
        };

        let page_align = match &cfg.policy {
            // KVSwap aligns group records to the device granule (§3.3)
            Policy::KvSwap => cfg.disk.page_bytes.min(4096),
            // token-granular baselines pack records (fragmented reads)
            Policy::InfiniGen { .. } | Policy::Loki => 0,
            _ => 4096,
        };
        let layout = DiskLayout::new(
            spec.kv_flat_dim(),
            g_layout,
            cfg.max_context + 1024,
            spec.n_layers,
            page_align,
        );
        let store = match store {
            Some(s) => {
                anyhow::ensure!(
                    *s.layout() == layout,
                    "shared store layout does not match this engine's"
                );
                Some(s)
            }
            None if cfg.store.enabled => Some(Arc::new(PersistentStore::open(
                &cfg.store,
                cfg.disk.clone(),
                &cfg.fault,
                layout.clone(),
            )?)),
            None => None,
        };

        let clock = if cfg.real_time {
            Clock::real_scaled(cfg.time_scale)
        } else {
            Clock::virtual_()
        };
        let pacing = if cfg.real_time { Some(clock.clone()) } else { None };
        let backend = cfg.storage.open()?;
        let backend: Arc<dyn Backend> = if cfg.fault.enabled() {
            Arc::new(FaultBackend::new(backend, cfg.fault.clone()))
        } else {
            backend
        };
        let disk = Arc::new(SimDisk::new(cfg.disk.clone(), backend, pacing));
        // the prefetch workers share only the SimDisk (Backend + stats);
        // everything runtime-bound stays on this thread
        let prefetcher = if cfg.prefetch.unified_io {
            // one scheduler serves every read stream through priority
            // lanes: decode preloads (Critical), store warm restores
            // (Warm), scrub verification (Background)
            let sched = Arc::new(IoScheduler::new(
                &cfg.prefetch,
                RetryPolicy::new(cfg.retry.clone()),
            ));
            if let Some(s) = &store {
                s.attach_scheduler(&sched);
            }
            Prefetcher::with_scheduler(sched, disk.clone())
        } else {
            // separate-pools mode: a shared store attached by an earlier
            // unified engine must stop routing through that scheduler
            if let Some(s) = &store {
                s.detach_scheduler();
            }
            Prefetcher::spawn_with(
                disk.clone(),
                &cfg.prefetch,
                RetryPolicy::new(cfg.retry.clone()),
            )
        };

        let sel_entries = cfg.kv.selected_entries();
        let sel_region = (sel_entries / g_layout) * g_layout;
        let mgr_cfg = ManagerConfig {
            group: g_layout,
            rank,
            reuse_slots: if cfg.policy.uses_reuse() && cfg.kv.use_reuse {
                // C slots hold groups; token-granular policies hold tokens
                cfg.kv.reuse_slots * cfg.kv.group_size / g_layout
            } else {
                0
            },
            rb_visible: cfg.kv.rb_slots,
            sel_region,
            p: cfg.kv.p_sel,
            cache_flushed: true,
            expose_rolling: cfg.kv.use_rolling,
        };
        let manager = KvManager::new(layout, disk.clone(), mgr_cfg);

        // prediction adapters
        let weights = rt.host_weights(&cfg.preset)?;
        let adapters: Vec<Tensor> = (0..spec.n_layers)
            .map(|l| match &cfg.policy {
                Policy::InfiniGen { .. } => {
                    // index selection: one-hot on the top-|wk column| dims
                    let wk = &weights[&format!("layer{l}.wk")];
                    let hd = spec.kv_flat_dim();
                    let mut norms = vec![0.0f32; hd];
                    for i in 0..spec.d_model {
                        for j in 0..hd {
                            norms[j] += wk.data[i * hd + j] * wk.data[i * hd + j];
                        }
                    }
                    let top = mathx::top_k_indices(&norms, rank);
                    let mut a = Tensor::zeros(&[hd, rank]);
                    for (col, &dim) in top.iter().enumerate() {
                        *a.at_mut(&[dim, col]) = 1.0;
                    }
                    a
                }
                _ => weights
                    .get(&format!("layer{l}.A{rank}"))
                    .unwrap_or_else(|| panic!("no adapter A{rank} for layer {l}"))
                    .clone(),
            })
            .collect();

        let batch = cfg.batch;
        let n_layers = spec.n_layers;
        let mut seqs = Vec::with_capacity(batch);
        for i in 0..batch {
            seqs.push(SeqUnit {
                kv: manager.new_seq(i),
                mem: (0..n_layers).map(|_| KvLayer::new(spec.kv_flat_dim())).collect(),
                last_token: 0,
                pos: 0,
                staging: (0..n_layers).map(|_| HashMap::new()).collect(),
                pending_sel: vec![Vec::new(); n_layers],
                pending_kv: (0..n_layers).map(|_| None).collect(),
            });
        }

        Ok(Engine {
            cfg,
            spec,
            mr,
            host,
            manager,
            disk,
            clock,
            adapters,
            seqs,
            prefetcher,
            breakdown: Breakdown::default(),
            overlap: (0..batch)
                .map(|_| vec![OverlapTracker::default(); n_layers])
                .collect(),
            ncap,
            rank,
            l0_inflight: false,
            klr_cache: (0..n_layers)
                .map(|_| Tensor::zeros(&[batch, ncap, rank]))
                .collect(),
            klr_synced: (0..n_layers).map(|_| vec![0; batch]).collect(),
            last_x: None,
            decode_t0: None,
            tokens_generated: 0,
            steps_done: 0,
            degraded: 0,
            store,
            reused_prefix_tokens: 0,
            prefill_io_wait: Duration::ZERO,
            prefill_store_busy: Duration::ZERO,
        })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn ncap(&self) -> usize {
        self.ncap
    }

    /// Cumulative per-lane scheduler counters since engine construction.
    /// Unlike [`PrefetchSummary`](crate::disk::PrefetchSummary)'s
    /// window-scoped lane fields (reset with the decode counters), these
    /// never reset — benches assert on whole-run totals such as
    /// `cross_plan_merges`.
    pub fn lane_summary(&self) -> LaneSummary {
        self.prefetcher.scheduler().lane_summary()
    }

    /// Mean selection-overlap ratio across (seq, layer) streams (§3.4.2).
    pub fn mean_overlap(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0;
        for per_seq in &self.overlap {
            for t in per_seq {
                if !t.ratios.is_empty() {
                    sum += t.mean_overlap();
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Fraction of device read time hidden behind compute over the last
    /// decode run: `1 - IoWait / read_busy`. The synchronous pipeline
    /// tends toward 0 (every read is a stall); the threaded prefetcher
    /// toward 1 (reads overlap compute).
    pub fn io_overlap_ratio(&self) -> f64 {
        let busy = self.disk.stats().snapshot().read_busy.as_secs_f64();
        if busy <= 0.0 {
            return 0.0;
        }
        let wait = self.breakdown.get(Phase::IoWait).as_secs_f64();
        (1.0 - wait / busy).clamp(0.0, 1.0)
    }

    /// Fraction of the persistent store's device read time hidden behind
    /// prefill compute across this engine's warm starts: `1 - stall /
    /// read_busy`. `None` until a warm-start restore has run; a blocking
    /// restore reports `Some(0.0)` (nothing hides it), the pipelined
    /// restore worker pushes it toward 1.
    pub fn prefill_io_overlap_ratio(&self) -> Option<f64> {
        let busy = self.prefill_store_busy.as_secs_f64();
        if busy <= 0.0 {
            return None;
        }
        let wait = self.prefill_io_wait.as_secs_f64();
        Some((1.0 - wait / busy).clamp(0.0, 1.0))
    }

    /// The engine's persistent store handle, if one is open (the router
    /// caches this across waves so the store outlives any one engine).
    pub fn store(&self) -> Option<Arc<PersistentStore>> {
        self.store.clone()
    }

    /// Prompt tokens warm-started from the store instead of recomputed.
    pub fn reused_prefix_tokens(&self) -> u64 {
        self.reused_prefix_tokens
    }

    /// Current circuit-breaker state of the prefetch pipeline.
    pub fn breaker_state(&self) -> BreakerState {
        self.prefetcher.breaker_state()
    }

    /// Total in-memory KV management bytes across sequences (Fig. 3a).
    pub fn management_bytes(&self) -> u64 {
        if self.cfg.policy.memory_resident() {
            return self
                .seqs
                .iter()
                .map(|s| s.mem.iter().map(|l| (l.k.len() + l.v.len()) as u64 * 4).sum::<u64>())
                .sum();
        }
        self.seqs.iter().map(|s| self.manager.management_bytes(&s.kv)).sum()
    }

    // -----------------------------------------------------------------
    // ingestion

    /// Materialize synthetic KV state for decode benches: `contexts[i]`
    /// tokens for sequence i (DESIGN.md §2 substitution — decode speed
    /// does not depend on KV content).
    pub fn ingest_synthetic(&mut self, contexts: &[usize]) -> anyhow::Result<()> {
        anyhow::ensure!(contexts.len() == self.cfg.batch);
        let hd = self.spec.kv_flat_dim();
        let mut rng = Rng::new(self.cfg.seed ^ 0x5EED);
        for (i, &ctx) in contexts.iter().enumerate() {
            anyhow::ensure!(ctx <= self.cfg.max_context, "context {ctx} over max");
            for layer in 0..self.spec.n_layers {
                let (k, v) =
                    synthetic_kv_rows(ctx, hd, self.cfg.seed ^ ((i as u64) << 20) ^ layer as u64);
                self.ingest_layer_rows(i, layer, &k, &v)?;
            }
            self.seqs[i].pos = ctx;
            self.seqs[i].kv.n_tokens = ctx;
            self.seqs[i].last_token = rng.below(self.spec.vocab) as i32;
        }
        Ok(())
    }

    fn ingest_layer_rows(
        &mut self,
        seq_idx: usize,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> anyhow::Result<()> {
        let hd = self.spec.kv_flat_dim();
        let su = &mut self.seqs[seq_idx];
        if self.cfg.policy.memory_resident() {
            let n = k_rows.len() / hd;
            for t in 0..n {
                su.mem[layer].push(
                    &k_rows[t * hd..(t + 1) * hd],
                    &v_rows[t * hd..(t + 1) * hd],
                );
            }
            return Ok(());
        }
        self.manager
            .ingest_prefill(&mut su.kv, layer, k_rows, v_rows, &self.adapters[layer])
    }

    /// Real chunked prefill through the AOT artifacts (quality path and
    /// serving example). All prompts must share a length ≤ prefill_ncap.
    /// Returns the first generated token per sequence.
    pub fn prefill(&mut self, prompts: &[Vec<i32>]) -> anyhow::Result<Vec<i32>> {
        let limits: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        self.prefill_with_save_limits(prompts, &limits)
    }

    /// Prefill with per-row store-save limits: row `bi` persists only
    /// `prompts[bi][..save_limits[bi]]` (the unpadded request prefix),
    /// and a limit of `0` marks a batch-padding row that must never
    /// reach the store. The router pads ragged waves with zeros; saving
    /// those verbatim would fill the store with pad-polluted keys that
    /// evict real prefixes and can never match unpadded traffic.
    pub fn prefill_with_save_limits(
        &mut self,
        prompts: &[Vec<i32>],
        save_limits: &[usize],
    ) -> anyhow::Result<Vec<i32>> {
        anyhow::ensure!(prompts.len() == self.cfg.batch);
        anyhow::ensure!(save_limits.len() == prompts.len(), "one save limit per prompt");
        let s_len = prompts[0].len();
        anyhow::ensure!(prompts.iter().all(|p| p.len() == s_len), "ragged prompts");
        let info = &self.mr.rt.manifest.presets[&self.cfg.preset].clone();
        let (chunk, pncap) = (info.prefill_chunk, info.prefill_ncap);
        anyhow::ensure!(s_len % chunk == 0, "prompt length must be a multiple of {chunk}");
        anyhow::ensure!(s_len <= pncap, "prompt too long for prefill artifact");
        let (b, hkv, d) = (self.cfg.batch, self.spec.n_kv_heads, self.spec.head_dim);
        let hd = self.spec.kv_flat_dim();

        let mut k_caches: Vec<Tensor> =
            (0..self.spec.n_layers).map(|_| Tensor::zeros(&[b, hkv, pncap, d])).collect();
        let mut v_caches: Vec<Tensor> =
            (0..self.spec.n_layers).map(|_| Tensor::zeros(&[b, hkv, pncap, d])).collect();

        // ---- warm start: restore the longest stored shared prefix ----
        // Chunks run batch-wide, so the warm region is the *batch
        // minimum* stored prefix, floored to the chunk size. The final
        // chunk is always recomputed — prefill must produce the last
        // activations for the first sampled token. Restored bytes are
        // the exact f32 records a cold run would have placed in the
        // caches, so every recomputed chunk is bit-identical.
        //
        // With `store.pipelined_restore` (the default) the restore does
        // not block up front: a dedicated worker streams `(layer, chunk)`
        // units while compute runs, and only the residual the compute
        // failed to hide is charged as `Phase::IoWait`. A torn chunk
        // degrades at *chunk* granularity — recompute restarts from the
        // tear, keeping everything restored before it.
        let store = self.store.clone();
        let mut reused = 0usize;
        let mut pinned: Vec<u64> = Vec::new();
        let mut pipeline: Option<RestorePipeline> = None;
        let store_io0 = store.as_ref().map(|s| s.io_snapshot());
        let mut warm_attempted = false;
        let mut prefill_wait = Duration::ZERO;
        if let Some(store) = &store {
            let mut matches = Vec::with_capacity(b);
            let mut min_len = usize::MAX;
            for p in prompts {
                let Some(m) = store.lookup(p) else {
                    min_len = 0;
                    break;
                };
                min_len = min_len.min(m.tokens);
                matches.push(m);
            }
            let mut l = if matches.len() == b {
                (min_len / chunk) * chunk
            } else {
                0
            };
            if l >= s_len {
                l -= chunk;
            }
            if l > 0 {
                for m in &matches {
                    store.pin(m.entry);
                    pinned.push(m.entry);
                }
                warm_attempted = true;
                if self.cfg.store.pipelined_restore {
                    pipeline = Some(spawn_restore_worker(
                        store.clone(),
                        matches,
                        l / chunk,
                        chunk,
                        self.spec.n_layers,
                    ));
                    reused = l;
                } else {
                    let io0 = store.io_snapshot();
                    let mut rows: Vec<Vec<(Vec<f32>, Vec<f32>)>> = Vec::with_capacity(b);
                    for m in &matches {
                        match store.restore(m, l) {
                            Ok(r) => rows.push(r),
                            Err(e) => {
                                // rung 4: a torn blocking restore degrades
                                // to cold prefill — correctness never
                                // depends on it
                                crate::log_debug!("store restore failed ({e}); cold prefill");
                                rows.clear();
                                break;
                            }
                        }
                    }
                    if rows.len() == b {
                        for (bi, layers) in rows.iter().enumerate() {
                            for (layer, (k_rows, v_rows)) in layers.iter().enumerate() {
                                scatter_chunk(
                                    &mut k_caches[layer],
                                    &mut v_caches[layer],
                                    bi,
                                    hkv,
                                    d,
                                    hd,
                                    0,
                                    l,
                                    k_rows,
                                    v_rows,
                                );
                            }
                        }
                        reused = l;
                    }
                    // nothing hides a blocking restore: the whole modeled
                    // device delta is a prefill stall
                    let stall = store.io_snapshot().read_busy_since(&io0);
                    self.breakdown.add(Phase::IoWait, stall);
                    if !self.cfg.real_time {
                        self.clock.advance(stall);
                    }
                    prefill_wait += stall;
                }
            }
        }
        let pipelined_warm = pipeline.is_some();

        let mut x_last = Tensor::zeros(&[b, self.spec.d_model]);
        'restart: loop {
            let warm_chunks = reused / chunk;
            let mut first_chunk = true;
            let mut c0 = reused;
            while c0 < s_len {
                let mut toks = Vec::with_capacity(b * chunk);
                for p in prompts {
                    toks.extend_from_slice(&p[c0..c0 + chunk]);
                }
                let mut x = self
                    .mr
                    .embed_chunk(&TensorI32::from_vec(&[b, chunk], toks), chunk)?;
                let start = vec![c0 as i32; b];
                for layer in 0..self.spec.n_layers {
                    if first_chunk {
                        if let Some(pl) = pipeline.as_mut() {
                            // this chunk attends over [0, c0) of this
                            // layer only: block until the layer's warm
                            // chunks are committed (later layers keep
                            // streaming while earlier layers compute)
                            while pl.committed[layer] < warm_chunks && !pl.done {
                                let t_wait = Instant::now();
                                let Ok(msg) = pl.rx.recv() else {
                                    pl.done = true;
                                    break;
                                };
                                if self.cfg.real_time {
                                    // real mode: the stall is the wall
                                    // time spent blocked on the worker
                                    let w = t_wait.elapsed();
                                    self.breakdown.add(Phase::IoWait, w);
                                    prefill_wait += w;
                                }
                                let tear = self.commit_restore_msg(
                                    pl,
                                    msg,
                                    chunk,
                                    &mut k_caches,
                                    &mut v_caches,
                                    &mut prefill_wait,
                                );
                                if let Some(tc) = tear {
                                    if tc * chunk < reused {
                                        reused = tc * chunk;
                                        continue 'restart;
                                    }
                                }
                            }
                            if pl.committed[layer] < warm_chunks {
                                // worker died mid-stream without a tear
                                // notice: degrade to what every layer
                                // actually committed
                                let have = pl.committed.iter().copied().min().unwrap_or(0);
                                reused = (have * chunk).min(reused);
                                continue 'restart;
                            }
                        }
                    }
                    let (x1, k_chunk, v_chunk) = self.mr.prefill_block(
                        layer,
                        chunk,
                        pncap,
                        x,
                        k_caches[layer].clone(),
                        v_caches[layer].clone(),
                        &start,
                    )?;
                    x = x1;
                    for bi in 0..b {
                        for g in 0..hkv {
                            for t in 0..chunk {
                                for dd in 0..d {
                                    *k_caches[layer].at_mut(&[bi, g, c0 + t, dd]) =
                                        k_chunk.at(&[bi, g, t, dd]);
                                    *v_caches[layer].at_mut(&[bi, g, c0 + t, dd]) =
                                        v_chunk.at(&[bi, g, t, dd]);
                                }
                            }
                        }
                    }
                    // drain staged units opportunistically so later
                    // layers' blocking waits shrink toward zero
                    if let Some(pl) = pipeline.as_mut() {
                        while let Ok(msg) = pl.rx.try_recv() {
                            let tear = self.commit_restore_msg(
                                pl,
                                msg,
                                chunk,
                                &mut k_caches,
                                &mut v_caches,
                                &mut prefill_wait,
                            );
                            if let Some(tc) = tear {
                                if tc * chunk < reused {
                                    reused = tc * chunk;
                                    continue 'restart;
                                }
                            }
                        }
                    }
                }
                if c0 + chunk == s_len {
                    for bi in 0..b {
                        x_last.row_mut(&[bi]).copy_from_slice(x.row(&[bi, chunk - 1]));
                    }
                }
                first_chunk = false;
                c0 += chunk;
            }
            break;
        }

        // drain the stream to completion and reap the worker; any late
        // units are bit-identical to what compute already produced, so
        // committing them only settles the stall accounting
        if let Some(mut pl) = pipeline.take() {
            while !pl.done {
                let Ok(msg) = pl.rx.recv() else { break };
                let _ = self.commit_restore_msg(
                    &mut pl,
                    msg,
                    chunk,
                    &mut k_caches,
                    &mut v_caches,
                    &mut prefill_wait,
                );
            }
            if let Some(h) = pl.handle.take() {
                let _ = h.join();
            }
        }

        // prefill-phase overlap accounting: how much of the store's
        // modeled device read time did compute hide?
        if warm_attempted {
            if let (Some(store), Some(io0)) = (&store, &store_io0) {
                self.prefill_store_busy += store.io_snapshot().read_busy_since(io0);
                self.prefill_io_wait += prefill_wait;
            }
        }

        // ingest caches as token-major rows; with a store open, keep the
        // rows to persist this prompt (only its unpadded prefix) for
        // future cross-request reuse
        for bi in 0..b {
            let save_n = save_limits[bi].min(s_len);
            let mut layer_rows: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
            for layer in 0..self.spec.n_layers {
                let mut k_rows = vec![0.0f32; s_len * hd];
                let mut v_rows = vec![0.0f32; s_len * hd];
                for t in 0..s_len {
                    for g in 0..hkv {
                        for dd in 0..d {
                            k_rows[t * hd + g * d + dd] = k_caches[layer].at(&[bi, g, t, dd]);
                            v_rows[t * hd + g * d + dd] = v_caches[layer].at(&[bi, g, t, dd]);
                        }
                    }
                }
                self.ingest_layer_rows(bi, layer, &k_rows, &v_rows)?;
                if store.is_some() && save_n > 0 {
                    layer_rows.push((k_rows, v_rows));
                }
            }
            if let Some(store) = &store {
                if save_n == 0 {
                    // all-zero batch-padding row: never persist it
                    store.note_pad_skip();
                } else if let Err(e) = store.save(&prompts[bi][..save_n], &layer_rows) {
                    // a failed save is a lost optimization, not an error
                    crate::log_debug!("store save failed for seq {bi}: {e}");
                }
            }
            self.seqs[bi].pos = s_len;
            self.seqs[bi].kv.n_tokens = s_len;
        }
        if let Some(store) = &store {
            for key in pinned {
                store.unpin(key);
            }
            if pipelined_warm {
                // blocking restores credit inside `restore`; the
                // pipelined path credits only the region that survived
                // any tear and was actually committed
                store.credit_restored(reused * b);
            }
        }
        self.reused_prefix_tokens += (reused * b) as u64;
        let (first, _) = self.mr.logits_argmax(x_last)?;
        for (bi, &t) in first.iter().enumerate() {
            self.seqs[bi].last_token = t;
        }
        Ok(first)
    }

    /// Apply one restore-worker message: commit a staged `(layer, chunk)`
    /// unit into the prefill caches — charging the virtual-clock residual
    /// stall compute failed to hide, mirroring `await_loads` — or
    /// surface a tear. Returns the torn chunk index so the caller can
    /// degrade at chunk granularity.
    fn commit_restore_msg(
        &mut self,
        pl: &mut RestorePipeline,
        msg: RestoreMsg,
        chunk: usize,
        k_caches: &mut [Tensor],
        v_caches: &mut [Tensor],
        prefill_wait: &mut Duration,
    ) -> Option<usize> {
        match msg {
            RestoreMsg::Unit { layer, chunk: c, per_seq, io_time, issued_at } => {
                if !self.cfg.real_time {
                    // virtual-threaded accounting: only the residual the
                    // worker has not already spent in wall time
                    let stall = io_time.saturating_sub(issued_at.elapsed());
                    self.breakdown.add(Phase::IoWait, stall);
                    self.clock.advance(stall);
                    *prefill_wait += stall;
                }
                let (hkv, d) = (self.spec.n_kv_heads, self.spec.head_dim);
                let hd = self.spec.kv_flat_dim();
                for (bi, (k_rows, v_rows)) in per_seq.iter().enumerate() {
                    scatter_chunk(
                        &mut k_caches[layer],
                        &mut v_caches[layer],
                        bi,
                        hkv,
                        d,
                        hd,
                        c * chunk,
                        chunk,
                        k_rows,
                        v_rows,
                    );
                }
                pl.committed[layer] = pl.committed[layer].max(c + 1);
                None
            }
            RestoreMsg::Torn { chunk: c } => Some(c),
            RestoreMsg::Done => {
                pl.done = true;
                None
            }
        }
    }

    /// Working-cache counterpart of the store scrub: re-verify every
    /// sequence's flushed KV groups against the integrity map via
    /// [`KvManager::scrub`]. The router drives this from the same idle
    /// ticks as `store.maintain()`. Returns `(clean_records,
    /// unreadable_seqs)`.
    pub fn scrub_working(&self) -> (usize, usize) {
        if self.cfg.policy.memory_resident() {
            return (0, 0); // nothing on disk to verify
        }
        let mut clean = 0usize;
        let mut failed = 0usize;
        for s in &self.seqs {
            match self.manager.scrub(&s.kv) {
                Ok(n) => clean += n,
                Err(e) => {
                    crate::log_debug!("working-cache scrub: sequence unreadable ({e})");
                    failed += 1;
                }
            }
        }
        (clean, failed)
    }

    /// Persist every sequence's flushed KV groups into the store under a
    /// deterministic pseudo-prompt derived from `(seed, slot)` — the
    /// synthetic-ingest analogue of a prefill save, so `run`-style
    /// workloads exercise the persistence path (and a later process with
    /// the same seed restores them). Returns sequences saved.
    pub fn persist_synthetic(&mut self) -> anyhow::Result<usize> {
        let Some(store) = self.store.clone() else {
            return Ok(0);
        };
        if self.cfg.policy.memory_resident() {
            return Ok(0); // nothing on disk to read back
        }
        let g = self.manager.cfg.group;
        let hd = self.spec.kv_flat_dim();
        let payload = self.manager.layout.group_payload_bytes() as usize;
        let vocab = self.spec.vocab;
        let mut saved = 0usize;
        'seqs: for i in 0..self.seqs.len() {
            let groups = (0..self.spec.n_layers)
                .map(|l| self.manager.n_groups(&self.seqs[i].kv, l))
                .min()
                .unwrap_or(0);
            let n = groups * g;
            if n == 0 {
                continue;
            }
            let mut rng = Rng::new(self.cfg.seed ^ ((i as u64) << 20) ^ 0x5704E);
            let tokens: Vec<i32> = (0..n).map(|_| rng.below(vocab) as i32).collect();
            let mut layer_rows = Vec::with_capacity(self.spec.n_layers);
            for layer in 0..self.spec.n_layers {
                let mut k_rows = Vec::with_capacity(n * hd);
                let mut v_rows = Vec::with_capacity(n * hd);
                for gi in 0..groups {
                    let off = self
                        .manager
                        .layout
                        .offset(self.seqs[i].kv.seq_slot, layer, gi);
                    let mut buf = vec![0u8; payload];
                    if let Err(e) = self.disk.read(off, &mut buf) {
                        crate::log_debug!(
                            "persist: seq {i} layer {layer} group {gi} unreadable ({e}); skipping"
                        );
                        continue 'seqs;
                    }
                    let (k, v) = self.manager.layout.decode_group(&buf);
                    k_rows.extend_from_slice(&k);
                    v_rows.extend_from_slice(&v);
                }
                layer_rows.push((k_rows, v_rows));
            }
            if store.save(&tokens, &layer_rows)? > 0 {
                saved += 1;
            }
        }
        Ok(saved)
    }

    /// Overwrite the KV entry at `token_pos` in every layer (NIAH
    /// planting): patches disk records, the compressed K cache, the
    /// in-memory cache, and invalidates any stale reuse-buffer copy.
    pub fn plant_needle(
        &mut self,
        seq_idx: usize,
        token_pos: usize,
        keys: &[Vec<f32>],
        values: &[Vec<f32>],
    ) -> anyhow::Result<()> {
        let hd = self.spec.kv_flat_dim();
        let g = self.manager.cfg.group;
        for layer in 0..self.spec.n_layers {
            let key = &keys[layer];
            let val = &values[layer];
            let su = &mut self.seqs[seq_idx];
            if self.cfg.policy.memory_resident() {
                su.mem[layer].k[token_pos * hd..(token_pos + 1) * hd].copy_from_slice(key);
                su.mem[layer].v[token_pos * hd..(token_pos + 1) * hd].copy_from_slice(val);
                continue;
            }
            let (gid, member) = self.manager.layout.locate(token_pos);
            anyhow::ensure!(
                token_pos < su.kv.layers[layer].klr.len(),
                "needle must land in flushed region"
            );
            // read-modify-write the disk record
            let off = self.manager.layout.offset(su.kv.seq_slot, layer, gid);
            let len = self.manager.layout.group_payload_bytes() as usize;
            let mut buf = vec![0u8; len];
            self.disk.read(off, &mut buf)?;
            let (mut k_rows, mut v_rows) = self.manager.layout.decode_group(&buf);
            k_rows[member * hd..(member + 1) * hd].copy_from_slice(key);
            v_rows[member * hd..(member + 1) * hd].copy_from_slice(val);
            let rec = self.manager.layout.encode_group(&k_rows, &v_rows);
            self.disk.write(off, &rec)?;
            // patch the compressed row: K_lr[pos] = key @ A
            let compressed = self.host.compress_k(&self.adapters[layer], key);
            let st = &mut su.kv.layers[layer];
            st.klr.patch_row(token_pos, &compressed);
            st.reuse.invalidate(gid as u32);
            // force the K_lr tensor cache to re-sync past the patch
            self.klr_synced[layer][seq_idx] = self.klr_synced[layer][seq_idx].min(token_pos);
        }
        let _ = g;
        Ok(())
    }

    // -----------------------------------------------------------------
    // decode

    /// Decode `steps` tokens for every sequence; returns (stats, final
    /// activations per step if `collect_x`, sampled tokens per step).
    ///
    /// `forced`: teacher-forcing — override the sampled token of step j
    /// with `forced[j]` (used by the quality harness so that a method and
    /// the Full-KV oracle stay on the same trajectory and per-step
    /// activation fidelity is well defined).
    pub fn decode(
        &mut self,
        steps: usize,
        collect_x: bool,
        forced: Option<&[Vec<i32>]>,
    ) -> anyhow::Result<(DecodeStats, Vec<Tensor>, Vec<Vec<i32>>)> {
        self.warmup()?;
        self.disk.stats().reset();
        self.prefetcher.reset_counters();
        self.degraded = 0;
        self.breakdown = Breakdown::default();
        self.decode_t0 = Some(self.clock.now_secs());
        let mut xs = Vec::new();
        let mut token_hist = Vec::new();

        // cold start: issue loads for layer 0 of the first step (unless
        // a previous decode() call left them in flight)
        if !self.cfg.policy.memory_resident() && !self.l0_inflight {
            let x0 = self.timed_embed()?;
            self.predict_and_issue(0, &x0)?;
            self.l0_inflight = true;
        }

        for j in 0..steps {
            let force = forced.and_then(|f| f.get(j)).map(|v| v.as_slice());
            let (x_final, toks) = self.step(force)?;
            token_hist.push(toks);
            if collect_x {
                xs.push(x_final.clone());
            }
            self.last_x = Some(x_final);
        }

        let elapsed = self.clock.now_secs() - self.decode_t0.unwrap();
        let snap = self.disk.stats().snapshot();
        let reuse_rate = if self.manager.cfg.reuse_slots > 0 {
            let mut rates = Vec::new();
            for s in &self.seqs {
                for l in &s.kv.layers {
                    let (h, m) = l.reuse.counters();
                    if h + m > 0 {
                        rates.push(h as f64 / (h + m) as f64);
                    }
                }
            }
            if rates.is_empty() {
                None
            } else {
                Some(rates.iter().sum::<f64>() / rates.len() as f64)
            }
        } else {
            None
        };
        let mut bd = self.breakdown.clone();
        bd.steps = self.steps_done;
        Ok((
            DecodeStats {
                tokens: self.tokens_generated,
                steps: self.steps_done,
                seconds: elapsed,
                breakdown: bd,
                reuse_rate,
                io_utilization: snap.io_utilization(self.cfg.disk.read_bw),
                bytes_loaded: snap.logical_read_bytes,
                mean_overlap: self.mean_overlap(),
                prefetch: self.prefetcher.summary(),
                degraded_steps: self.degraded,
                reused_prefix_tokens: self.reused_prefix_tokens,
                prefill_io_overlap: self.prefill_io_overlap_ratio(),
            },
            xs,
            token_hist,
        ))
    }

    /// Pre-compile every executable the decode loop will touch so that
    /// lazy compilation never pollutes measured step timings.
    pub fn warmup(&mut self) -> anyhow::Result<()> {
        let rt = self.mr.rt.clone();
        let (preset, b) = (self.cfg.preset.clone(), self.cfg.batch);
        rt.warm_weights(&preset)?;
        rt.executable(&preset, b, "embed")?;
        rt.executable(&preset, b, "logits_argmax")?;
        match self.cfg.policy {
            Policy::FlexGen | Policy::FullMemory => {
                let n = self.full_ncap()?;
                rt.executable(&preset, b, &format!("decode_full_n{n}"))?;
            }
            _ => {
                rt.executable(&preset, b, &format!("decode_p{}", self.manager.cfg.p))?;
            }
        }
        if matches!(self.cfg.policy, Policy::KvSwap) {
            rt.executable(
                &preset,
                b,
                &format!("predict_n{}_r{}", self.ncap, self.rank),
            )?;
        }
        Ok(())
    }

    fn timed_embed(&mut self) -> anyhow::Result<Tensor> {
        let t = Instant::now();
        let toks: Vec<i32> = self.seqs.iter().map(|s| s.last_token).collect();
        let x = self.mr.embed(&toks)?;
        self.charge(Phase::Embed, t.elapsed());
        Ok(x)
    }

    fn charge(&mut self, phase: Phase, d: Duration) {
        self.breakdown.add(phase, d);
        self.clock.absorb_measured(d);
    }

    /// One decode step across the batch; returns the final activations
    /// and the tokens committed (sampled, or forced if provided).
    fn step(&mut self, forced: Option<&[i32]>) -> anyhow::Result<(Tensor, Vec<i32>)> {
        let n_layers = self.spec.n_layers;
        let mut x = self.timed_embed()?;

        if self.cfg.policy.memory_resident() {
            for layer in 0..n_layers {
                x = self.route_layer(layer, x)?;
            }
        } else {
            for layer in 0..n_layers {
                // 1. complete this layer's loads
                self.await_loads(layer)?;
                // 2. overlap: predict + issue loads for layer l+1
                if layer + 1 < n_layers {
                    let x_snapshot = x.clone();
                    self.predict_and_issue(layer + 1, &x_snapshot)?;
                }
                // 3. gather + attention for layer l
                x = self.route_layer(layer, x)?;
            }
        }

        // logits + sampling (teacher forcing overrides the argmax)
        let t = Instant::now();
        let (mut toks, _) = self.mr.logits_argmax(x.clone())?;
        if let Some(f) = forced {
            anyhow::ensure!(f.len() == toks.len(), "forced token batch mismatch");
            toks.copy_from_slice(f);
        }
        self.charge(Phase::Logits, t.elapsed());

        // append KV generated during this step (decode_block returned the
        // per-layer k_new/v_new which compute_layer cached in pending_kv)
        let t = Instant::now();
        self.append_step_kv()?;
        self.charge(Phase::KvAppend, t.elapsed());

        for (s, &tok) in self.seqs.iter_mut().zip(&toks) {
            s.last_token = tok;
            s.pos += 1;
            s.kv.n_tokens += 1;
        }
        self.tokens_generated += self.cfg.batch as u64;
        self.steps_done += 1;
        self.breakdown.steps = self.steps_done;

        // issue layer-0 loads for the NEXT step using the new embedding
        if !self.cfg.policy.memory_resident() {
            let x0 = self.timed_embed()?;
            self.predict_and_issue(0, &x0)?;
            self.l0_inflight = true;
        }
        Ok((x, toks))
    }

    // pending per-step new KV rows: [layer][seq] -> (k_row, v_row)
    fn append_step_kv(&mut self) -> anyhow::Result<()> {
        let n_layers = self.spec.n_layers;
        for layer in 0..n_layers {
            for i in 0..self.seqs.len() {
                let Some((k_row, v_row)) = self.seqs[i].pending_kv_take(layer) else {
                    continue;
                };
                if self.cfg.policy.memory_resident() {
                    self.seqs[i].mem[layer].push(&k_row, &v_row);
                } else {
                    let adapter = self.adapters[layer].clone();
                    self.manager.append_token(
                        &mut self.seqs[i].kv,
                        layer,
                        k_row,
                        v_row,
                        &adapter,
                    )?;
                }
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // prediction + I/O issue

    /// Predict layer `layer`'s critical entries from activations `x`
    /// (the §3.3 online prediction), select, diff, and send loads.
    fn predict_and_issue(&mut self, layer: usize, x: &Tensor) -> anyhow::Result<()> {
        if matches!(self.cfg.policy, Policy::FlexGen) {
            // no prediction: load everything
            let t = Instant::now();
            let mut per_seq = Vec::new();
            for (i, su) in self.seqs.iter_mut().enumerate() {
                let n_groups = su.kv.layers[layer].klr.len() / self.manager.cfg.group.max(1);
                // one sequential extent covering all groups
                let first = self.manager.layout.offset(su.kv.seq_slot, layer, 0);
                let len = (n_groups as u64 * self.manager.layout.group_stride()) as usize;
                if len > 0 {
                    per_seq.push((
                        i,
                        vec![PlannedExtent {
                            tag: u32::MAX,
                            offset: first,
                            len,
                        }],
                    ));
                }
                su.pending_sel[layer].clear();
            }
            self.charge(Phase::Select, t.elapsed());
            self.send_loads(layer, per_seq)?;
            return Ok(());
        }

        // ---- scores -----------------------------------------------------
        let t = Instant::now();
        let scores: Vec<Vec<f32>> = match &self.cfg.policy {
            Policy::KvSwap => {
                // the real path: HLO predict artifact over the compressed
                // cache; the padded tensor is cached and synced
                // incrementally (only freshly flushed rows are copied)
                let b = self.cfg.batch;
                let rank = self.rank;
                let ncap = self.ncap;
                let mut lens = Vec::with_capacity(b);
                let mut pos = Vec::with_capacity(b);
                for (i, su) in self.seqs.iter().enumerate() {
                    let st = &su.kv.layers[layer];
                    let n = st.klr.len().min(ncap);
                    let synced = self.klr_synced[layer][i].min(n);
                    if n > synced {
                        let dst = self.klr_cache[layer].row_mut(&[i]);
                        for row in synced..n {
                            dst[row * rank..(row + 1) * rank]
                                .copy_from_slice(st.klr.row(row));
                        }
                        self.klr_synced[layer][i] = n;
                    }
                    lens.push(n as i32);
                    pos.push(su.pos as i32);
                }
                let k_lr = self.klr_cache[layer].clone();
                let out = self.mr.predict_scores(
                    layer,
                    self.ncap,
                    self.rank,
                    x.clone(),
                    k_lr,
                    &lens,
                    &pos,
                )?;
                (0..b).map(|i| out.row(&[i]).to_vec()).collect()
            }
            Policy::InfiniGen { .. } | Policy::Loki | Policy::ShadowKv { .. } => {
                // baseline predictors score host-side with their adapter
                self.seqs
                    .iter()
                    .enumerate()
                    .map(|(i, su)| {
                        let st = &su.kv.layers[layer];
                        let rows: Vec<&[f32]> =
                            (0..st.klr.len()).map(|n| st.klr.row(n)).collect();
                        self.host.predict_scores(
                            layer,
                            x.row(&[i]),
                            &self.adapters[layer],
                            &rows,
                            su.pos as i32,
                        )
                    })
                    .collect()
            }
            Policy::FlexGen | Policy::FullMemory => unreachable!(),
        };
        self.charge(Phase::Predict, t.elapsed());

        // ---- selection ---------------------------------------------------
        let t = Instant::now();
        let g = self.manager.cfg.group;
        let m_groups = self.manager.cfg.sel_region / g;
        let mut per_seq_loads = Vec::new();
        for (i, sc) in scores.iter().enumerate() {
            let n_flushed = self.seqs[i].kv.layers[layer].klr.len();
            let selection: Vec<u32> = match &self.cfg.policy {
                Policy::InfiniGen {
                    head_agg: false, ..
                } => {
                    // per-head selection: split the score budget per head
                    // (scores here are head-summed; emulate per-head noise
                    // by scoring each head separately on the host)
                    let su = &self.seqs[i];
                    let st = &su.kv.layers[layer];
                    let rows: Vec<&[f32]> = (0..st.klr.len()).map(|n| st.klr.row(n)).collect();
                    let head_scores = self.host.predict_scores_per_head(
                        layer,
                        x.row(&[i]),
                        &self.adapters[layer],
                        &rows,
                        su.pos as i32,
                    );
                    let per_head =
                        (self.manager.cfg.sel_region / self.spec.n_q_heads).max(1);
                    let mut sel = predictor::select_tokens_per_head(
                        &head_scores,
                        n_flushed,
                        per_head,
                    );
                    sel.truncate(m_groups);
                    sel
                }
                _ => predictor::select_groups(sc, n_flushed, g, m_groups),
            };
            self.overlap[i][layer].record(&selection);

            let loads = self.manager.plan_loads(&mut self.seqs[i].kv, layer, &selection);
            let extents: Vec<PlannedExtent> = match &self.cfg.policy {
                Policy::ShadowKv { .. } => loads
                    .iter()
                    .map(|l| PlannedExtent {
                        // V half only: K is reconstructed from memory
                        tag: l.gid,
                        offset: l.offset + (g * self.spec.kv_flat_dim() * 4) as u64,
                        len: g * self.spec.kv_flat_dim() * 4,
                    })
                    .collect(),
                _ => loads
                    .iter()
                    .map(|l| PlannedExtent {
                        tag: l.gid,
                        offset: l.offset,
                        len: l.len,
                    })
                    .collect(),
            };
            self.seqs[i].pending_sel[layer] = selection;
            per_seq_loads.push((i, extents));
        }
        self.charge(Phase::Select, t.elapsed());
        self.send_loads(layer, per_seq_loads)?;
        Ok(())
    }

    fn send_loads(
        &mut self,
        layer: usize,
        per_seq: Vec<(usize, Vec<PlannedExtent>)>,
    ) -> anyhow::Result<()> {
        // threaded mode: workers start the reads immediately and `submit`
        // only blocks at the queue-depth bound (backpressure); sync mode
        // just queues the plan
        self.prefetcher.submit(PreloadPlan { layer, per_seq })?;
        Ok(())
    }

    /// Block until layer `layer`'s staged bytes are ready, then commit
    /// them into the cache structures. `Phase::IoWait` charges only the
    /// *residual* wait — the portion of device time compute did not hide.
    fn await_loads(&mut self, layer: usize) -> anyhow::Result<()> {
        let wait_t = Instant::now();
        let staged = match self.prefetcher.recv() {
            Ok(staged) => staged,
            // rung 4 of the degradation ladder: the load failed past
            // every retry — run this layer's attention over what is
            // already resident (reuse buffer + rolling tail) instead of
            // aborting the decode, and record the degraded step
            Err(e) if e.is_retryable() => {
                crate::log_debug!("layer {layer} staging failed ({e}); degrading");
                self.degrade_layer(layer);
                if self.cfg.real_time {
                    self.breakdown.add(Phase::IoWait, wait_t.elapsed());
                }
                return Ok(());
            }
            // OutOfBounds / QueueClosed are logic or shutdown errors —
            // degrading would hide a real bug
            Err(e) => return Err(e.into()),
        };
        anyhow::ensure!(staged.layer == layer, "prefetch pipeline out of order");
        if layer == 0 {
            self.l0_inflight = false;
        }
        if self.cfg.real_time {
            // physical overlap: blocked time is the true residual stall
            // (in sync mode the read itself runs inside recv, so the
            // whole read latency is — correctly — charged here)
            self.breakdown.add(Phase::IoWait, wait_t.elapsed());
        } else if self.prefetcher.is_synchronous() {
            // no pipeline: nothing hides the modeled device time
            self.breakdown.add(Phase::IoWait, staged.io_time);
            self.clock.advance(staged.io_time);
        } else {
            // virtual overlap accounting (Appendix A.4): stall is the
            // modeled I/O time not hidden by compute since issue
            let stall = staged.io_time.saturating_sub(staged.issued_at.elapsed());
            self.breakdown.add(Phase::IoWait, stall);
            self.clock.advance(stall);
        }
        // commit payloads
        let t = Instant::now();
        for (seq_idx, results) in staged.per_seq {
            let mut plain: Vec<(u32, Vec<u8>)> = Vec::new();
            for (tag, bytes) in results {
                if tag == u32::MAX {
                    // FlexGen whole-layer read: stage groups 0..n
                    let stride = self.manager.layout.group_stride() as usize;
                    let n = bytes.len() / stride;
                    let su = &mut self.seqs[seq_idx];
                    su.staging[layer].clear();
                    for gi in 0..n {
                        let rec = &bytes[gi * stride..gi * stride
                            + self.manager.layout.group_payload_bytes() as usize];
                        let (k, v) = self.manager.layout.decode_group(rec);
                        let mut payload = k;
                        payload.extend_from_slice(&v);
                        su.staging[layer].insert(gi as u32, payload);
                    }
                } else if matches!(self.cfg.policy, Policy::ShadowKv { .. }) {
                    // V-only payload: reconstruct K from the compressed cache
                    let g = self.manager.cfg.group;
                    let hd = self.spec.kv_flat_dim();
                    let su = &mut self.seqs[seq_idx];
                    let st = &mut su.kv.layers[layer];
                    let mut payload = vec![0.0f32; 2 * g * hd];
                    // K half: reconstruct rows tag*g..tag*g+g
                    for m in 0..g {
                        let tok = tag as usize * g + m;
                        let klr_row = st.klr.row(tok).to_vec();
                        let a = &self.adapters[layer];
                        // k̂ = k_lr @ A^T
                        for dim in 0..hd {
                            let arow = &a.data[dim * a.shape[1]..(dim + 1) * a.shape[1]];
                            payload[m * hd + dim] = mathx::dot(&klr_row, arow);
                        }
                    }
                    // V half from disk
                    for (j, c) in bytes.chunks_exact(4).enumerate() {
                        payload[g * hd + j] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    }
                    if self.manager.cfg.reuse_slots == 0
                        || st.reuse.insert(tag, &payload).is_none()
                    {
                        su.staging[layer].insert(tag, payload);
                    }
                } else {
                    plain.push((tag, bytes));
                }
            }
            if !plain.is_empty() {
                let su = &mut self.seqs[seq_idx];
                let staging = &mut su.staging[layer];
                self.manager.commit_staged(&mut su.kv, layer, plain, staging);
            }
        }
        self.charge(Phase::ReuseMgmt, t.elapsed());
        Ok(())
    }

    /// Fall back to resident-only attention for `layer` after its staged
    /// load was lost: drop the (never-arrived) staging and shrink the
    /// selection to groups the reuse buffer already holds, so `assemble`
    /// never reaches for bytes that did not arrive. The rolling tail —
    /// the most recent tokens — is always resident, so the step stays
    /// causal; it just attends over a smaller critical set.
    fn degrade_layer(&mut self, layer: usize) {
        self.degraded += 1;
        if layer == 0 {
            self.l0_inflight = false;
        }
        for su in &mut self.seqs {
            su.staging[layer].clear();
            let reuse = &su.kv.layers[layer].reuse;
            let mut sel = std::mem::take(&mut su.pending_sel[layer]);
            sel.retain(|gid| reuse.get(*gid).is_some());
            su.pending_sel[layer] = sel;
        }
    }

    // -----------------------------------------------------------------
    // per-layer compute

    fn compute_layer(&mut self, layer: usize, x: Tensor) -> anyhow::Result<Tensor> {
        let (b, hkv, d, p) = (
            self.cfg.batch,
            self.spec.n_kv_heads,
            self.spec.head_dim,
            self.manager.cfg.p,
        );
        // gather into contiguous attention inputs via the mapping table
        let t = Instant::now();
        let mut k_sel = Tensor::zeros(&[b, hkv, p, d]);
        let mut v_sel = Tensor::zeros(&[b, hkv, p, d]);
        let mut mask = Tensor::zeros(&[b, p]);
        for i in 0..b {
            let selection = self.seqs[i].pending_sel[layer].clone();
            let sm = self.manager.slot_map(&self.seqs[i].kv, layer, &selection);
            let su = &mut self.seqs[i];
            let staging = std::mem::take(&mut su.staging[layer]);
            self.manager.assemble(
                &mut su.kv,
                layer,
                &sm,
                hkv,
                d,
                &staging,
                k_sel.row_mut(&[i]),
                v_sel.row_mut(&[i]),
                mask.row_mut(&[i]),
            );
            if self.manager.cfg.reuse_slots == 0 {
                // keep staging for potential reuse ablation semantics:
                // without a reuse buffer, staging is dropped every step
            }
        }
        self.charge(Phase::Gather, t.elapsed());

        let t = Instant::now();
        let pos: Vec<i32> = self.seqs.iter().map(|s| s.pos as i32).collect();
        let artifact = format!("decode_p{p}");
        let (x_next, k_new, v_new) =
            self.mr
                .decode_block(&artifact, layer, x, k_sel, v_sel, mask, &pos)?;
        self.charge(Phase::Attention, t.elapsed());

        // stash new KV for the post-logits append
        let hd = self.spec.kv_flat_dim();
        for i in 0..b {
            let mut k_row = vec![0.0f32; hd];
            let mut v_row = vec![0.0f32; hd];
            for g in 0..hkv {
                k_row[g * d..(g + 1) * d].copy_from_slice(k_new.row(&[i, g]));
                v_row[g * d..(g + 1) * d].copy_from_slice(v_new.row(&[i, g]));
            }
            self.seqs[i].pending_kv_put(layer, k_row, v_row);
        }
        Ok(x_next)
    }

    fn full_attention_layer(
        &mut self,
        layer: usize,
        x: Tensor,
        from_mem: bool,
    ) -> anyhow::Result<Tensor> {
        let (b, hkv, d) = (self.cfg.batch, self.spec.n_kv_heads, self.spec.head_dim);
        let hd = self.spec.kv_flat_dim();
        let ncap_full = self.full_ncap()?;
        let t = Instant::now();
        let mut k_sel = Tensor::zeros(&[b, hkv, ncap_full, d]);
        let mut v_sel = Tensor::zeros(&[b, hkv, ncap_full, d]);
        let mut mask = Tensor::full(&[b, ncap_full], -1e9);
        for i in 0..b {
            let su = &mut self.seqs[i];
            let n = su.pos.min(ncap_full);
            if from_mem && self.cfg.policy.memory_resident() {
                for tkn in 0..n {
                    let krow = su.mem[layer].k_row(tkn).to_vec();
                    let vrow = su.mem[layer].v_row(tkn).to_vec();
                    for g in 0..hkv {
                        let dst = g * ncap_full * d + tkn * d;
                        k_sel.row_mut(&[i])[dst..dst + d]
                            .copy_from_slice(&krow[g * d..(g + 1) * d]);
                        v_sel.row_mut(&[i])[dst..dst + d]
                            .copy_from_slice(&vrow[g * d..(g + 1) * d]);
                    }
                    mask.row_mut(&[i])[tkn] = 0.0;
                }
            } else {
                // FlexGen: staged whole-layer disk image + rolling tail
                let g_sz = self.manager.cfg.group;
                let staging = &su.staging[layer];
                let n_flushed = su.kv.layers[layer].klr.len();
                for tkn in 0..n_flushed {
                    let (gid, member) = self.manager.layout.locate(tkn);
                    let Some(payload) = staging.get(&(gid as u32)) else {
                        continue;
                    };
                    let krow = &payload[member * hd..(member + 1) * hd];
                    let vrow = &payload[g_sz * hd + member * hd..g_sz * hd + (member + 1) * hd];
                    for g in 0..hkv {
                        let dst = g * ncap_full * d + tkn * d;
                        k_sel.row_mut(&[i])[dst..dst + d]
                            .copy_from_slice(&krow[g * d..(g + 1) * d]);
                        v_sel.row_mut(&[i])[dst..dst + d]
                            .copy_from_slice(&vrow[g * d..(g + 1) * d]);
                    }
                    mask.row_mut(&[i])[tkn] = 0.0;
                }
                let entries: Vec<(usize, Vec<f32>, Vec<f32>)> = su.kv.layers[layer]
                    .rolling
                    .visible_entries()
                    .map(|(tp, k, v)| (tp, k.to_vec(), v.to_vec()))
                    .collect();
                for (tok_pos, krow, vrow) in entries {
                    if tok_pos >= n_flushed && tok_pos < ncap_full {
                        for g in 0..hkv {
                            let dst = g * ncap_full * d + tok_pos * d;
                            k_sel.row_mut(&[i])[dst..dst + d]
                                .copy_from_slice(&krow[g * d..(g + 1) * d]);
                            v_sel.row_mut(&[i])[dst..dst + d]
                                .copy_from_slice(&vrow[g * d..(g + 1) * d]);
                        }
                        mask.row_mut(&[i])[tok_pos] = 0.0;
                    }
                }
                su.staging[layer].clear();
            }
        }
        self.charge(Phase::Gather, t.elapsed());

        let t = Instant::now();
        let pos: Vec<i32> = self.seqs.iter().map(|s| s.pos as i32).collect();
        let artifact = format!("decode_full_n{ncap_full}");
        let (x_next, k_new, v_new) =
            self.mr
                .decode_block(&artifact, layer, x, k_sel, v_sel, mask, &pos)?;
        self.charge(Phase::Attention, t.elapsed());

        for i in 0..b {
            let mut k_row = vec![0.0f32; hd];
            let mut v_row = vec![0.0f32; hd];
            for g in 0..hkv {
                k_row[g * d..(g + 1) * d].copy_from_slice(k_new.row(&[i, g]));
                v_row[g * d..(g + 1) * d].copy_from_slice(v_new.row(&[i, g]));
            }
            self.seqs[i].pending_kv_put(layer, k_row, v_row);
        }
        Ok(x_next)
    }

    /// The decode_full artifact variant provisioned for this context.
    fn full_ncap(&self) -> anyhow::Result<usize> {
        let names = self
            .mr
            .rt
            .manifest
            .artifact_names(&self.cfg.preset, self.cfg.batch);
        let mut best: Option<usize> = None;
        for n in names {
            if let Some(rest) = n.strip_prefix("decode_full_n") {
                if let Ok(v) = rest.parse::<usize>() {
                    if v >= self.cfg.max_context && best.map(|b| v < b).unwrap_or(true) {
                        best = Some(v);
                    }
                }
            }
        }
        best.ok_or_else(|| {
            anyhow::anyhow!(
                "no decode_full artifact covers context {} for {}/b{}",
                self.cfg.max_context,
                self.cfg.preset,
                self.cfg.batch
            )
        })
    }
}

impl SeqUnit {
    fn pending_kv_put(&mut self, layer: usize, k: Vec<f32>, v: Vec<f32>) {
        if self.pending_kv.len() <= layer {
            self.pending_kv.resize_with(layer + 1, || None);
        }
        self.pending_kv[layer] = Some((k, v));
    }

    fn pending_kv_take(&mut self, layer: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        self.pending_kv.get_mut(layer).and_then(|s| s.take())
    }
}

// ---------------------------------------------------------------------
// layer routing

impl Engine {
    /// Route a layer's compute through the right attention shape.
    fn route_layer(&mut self, layer: usize, x: Tensor) -> anyhow::Result<Tensor> {
        match self.cfg.policy {
            Policy::FlexGen => self.full_attention_layer(layer, x, false),
            Policy::FullMemory => self.full_attention_layer(layer, x, true),
            _ => self.compute_layer(layer, x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accepts_sound_configs() {
        let cfg = EngineConfig::builder()
            .preset("nano")
            .batch(2)
            .policy(Policy::KvSwap)
            .max_context(1024)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(cfg.batch, 2);
        assert_eq!(cfg.max_context, 1024);
        assert_eq!(cfg.prefetch, PrefetchConfig::default());
        // the synchronous-baseline variant is valid too
        assert!(EngineConfig::builder()
            .prefetch(PrefetchConfig::synchronous())
            .build()
            .is_ok());
    }

    #[test]
    fn builder_rejects_zero_group_size() {
        let kv = KvSwapConfig {
            group_size: 0,
            ..KvSwapConfig::default()
        };
        assert!(EngineConfig::builder().kv(kv).build().is_err());
    }

    #[test]
    fn builder_rejects_zero_queue_depth() {
        let p = PrefetchConfig {
            queue_depth: 0,
            ..PrefetchConfig::default()
        };
        assert!(EngineConfig::builder().prefetch(p).build().is_err());
    }

    #[test]
    fn builder_rejects_inconsistent_ncap_and_attention_width() {
        // ncap smaller than what selection + rolling buffer must hold
        let kv = KvSwapConfig {
            ncap: 100,
            ..KvSwapConfig::default()
        };
        assert!(EngineConfig::builder().kv(kv).build().is_err());
        let kv = KvSwapConfig {
            p_sel: 64,
            ..KvSwapConfig::default()
        };
        assert!(EngineConfig::builder().kv(kv).build().is_err());
    }

    #[test]
    fn builder_rejects_bad_fault_and_retry_knobs() {
        let f = FaultConfig {
            rate: 1.5,
            ..FaultConfig::default()
        };
        assert!(EngineConfig::builder().fault(f).build().is_err());
        let f = FaultConfig {
            corruption_rate: -0.1,
            ..FaultConfig::default()
        };
        assert!(EngineConfig::builder().fault(f).build().is_err());
        let r = RetryConfig {
            jitter: 2.0,
            ..RetryConfig::default()
        };
        assert!(EngineConfig::builder().retry(r).build().is_err());
        let r = RetryConfig {
            backoff_base_ms: 10.0,
            backoff_max_ms: 1.0,
            ..RetryConfig::default()
        };
        assert!(EngineConfig::builder().retry(r).build().is_err());
        let r = RetryConfig {
            breaker_threshold: 0,
            ..RetryConfig::default()
        };
        assert!(EngineConfig::builder().retry(r).build().is_err());
        let r = RetryConfig {
            breaker_probe_after: 0,
            ..RetryConfig::default()
        };
        assert!(EngineConfig::builder().retry(r).build().is_err());
        // a sound fault matrix passes and flips `enabled()`
        let cfg = EngineConfig::builder()
            .fault(FaultConfig {
                rate: 0.05,
                seed: 7,
                ..FaultConfig::default()
            })
            .retry(RetryConfig {
                max_retries: 5,
                ..RetryConfig::default()
            })
            .build()
            .unwrap();
        assert!(cfg.fault.enabled());
        assert_eq!(cfg.retry.max_retries, 5);
        assert!(!EngineConfig::default().fault.enabled());
    }

    #[test]
    fn builder_validates_store_knobs() {
        // disabled store: knobs are ignored (defaults must keep passing)
        assert!(EngineConfig::builder().build().is_ok());
        let s = StoreConfig {
            enabled: true,
            capacity_bytes: 0,
            ..StoreConfig::default()
        };
        assert!(EngineConfig::builder().store(s).build().is_err());
        let s = StoreConfig {
            enabled: true,
            scrub_budget: 0,
            ..StoreConfig::default()
        };
        assert!(EngineConfig::builder().store(s).build().is_err());
        let s = StoreConfig {
            scrub_interval_s: f64::NAN,
            ..StoreConfig::default()
        };
        assert!(EngineConfig::builder().store(s).build().is_err());
        // a sound enabled store passes
        let s = StoreConfig {
            enabled: true,
            ..StoreConfig::default()
        };
        let cfg = EngineConfig::builder().store(s).build().unwrap();
        assert!(cfg.store.enabled);
    }

    #[test]
    fn builder_rejects_degenerate_shapes() {
        assert!(EngineConfig::builder().batch(0).build().is_err());
        assert!(EngineConfig::builder().preset("").build().is_err());
        assert!(EngineConfig::builder().max_context(0).build().is_err());
        assert!(EngineConfig::builder().time_scale(-1.0).build().is_err());
    }

    #[test]
    fn default_remains_available_for_tests() {
        // `Default` must stay a valid escape hatch
        let d = EngineConfig::default();
        let validated = EngineConfig::builder().build().unwrap();
        assert_eq!(d.preset, validated.preset);
        assert_eq!(d.kv, validated.kv);
    }
}
