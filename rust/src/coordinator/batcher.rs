//! Dynamic batcher: groups queued requests into the batch sizes the AOT
//! artifacts were compiled for. Shapes are static per executable, so the
//! batcher picks the largest compiled batch that the queue can fill
//! (padding the last wave), subject to a linger deadline — the standard
//! serving trade-off between batching efficiency and queueing delay.

use std::collections::VecDeque;

use crate::workload::tracegen::Request;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Batch sizes with compiled artifacts, ascending.
    pub supported: Vec<usize>,
    /// Max time a request may wait for co-batching (seconds).
    pub linger_s: f64,
    /// Max context the engine is provisioned for.
    pub max_context: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            supported: vec![1, 2, 4, 8],
            linger_s: 0.05,
            max_context: 2048,
        }
    }
}

#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<(Request, f64)>, // (request, enqueue time)
    pub rejected: u64,
}

/// A wave of requests to run as one engine batch. `pad` rows are added
/// by the caller to reach `batch` (engine artifacts need exact shapes).
#[derive(Debug, Clone, PartialEq)]
pub struct Wave {
    pub batch: usize,
    pub requests: Vec<Request>,
}

impl Wave {
    pub fn padding(&self) -> usize {
        self.batch - self.requests.len()
    }
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(!cfg.supported.is_empty());
        let mut cfg = cfg;
        cfg.supported.sort_unstable();
        Batcher {
            cfg,
            queue: VecDeque::new(),
            rejected: 0,
        }
    }

    pub fn push(&mut self, req: Request, now_s: f64) -> bool {
        if req.context > self.cfg.max_context {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back((req, now_s));
        true
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Form the next wave if batching policy allows:
    /// * queue fills the largest supported batch → dispatch immediately;
    /// * else, the oldest request exceeded the linger deadline → dispatch
    ///   the largest supported batch ≤ queue length (padding if queue is
    ///   smaller than the smallest supported batch).
    pub fn next_wave(&mut self, now_s: f64) -> Option<Wave> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len();
        let max_b = *self.cfg.supported.last().unwrap();
        let oldest_wait = now_s - self.queue.front().unwrap().1;
        let deadline = oldest_wait >= self.cfg.linger_s;
        if n < max_b && !deadline {
            return None;
        }
        let batch = self
            .cfg
            .supported
            .iter()
            .rev()
            .find(|&&b| b <= n)
            .copied()
            .unwrap_or(*self.cfg.supported.first().unwrap());
        let take = batch.min(n);
        let requests: Vec<Request> = self.queue.drain(..take).map(|(r, _)| r).collect();
        Some(Wave { batch, requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn req(id: u64, context: usize) -> Request {
        Request {
            id,
            context,
            decode: 8,
            arrival_s: 0.0,
            seed: id,
            tokens: None,
        }
    }

    #[test]
    fn dispatches_full_batch_immediately() {
        let mut b = Batcher::new(BatcherConfig::default());
        for i in 0..8 {
            assert!(b.push(req(i, 512), 0.0));
        }
        let w = b.next_wave(0.0).unwrap();
        assert_eq!(w.batch, 8);
        assert_eq!(w.requests.len(), 8);
        assert_eq!(w.padding(), 0);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn lingers_before_dispatching_partial() {
        let mut b = Batcher::new(BatcherConfig::default());
        for i in 0..3 {
            b.push(req(i, 512), 0.0);
        }
        assert!(b.next_wave(0.01).is_none()); // still lingering
        let w = b.next_wave(0.06).unwrap(); // deadline passed
        assert_eq!(w.batch, 2); // largest supported <= 3
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn single_request_pads_to_smallest_batch() {
        let mut b = Batcher::new(BatcherConfig {
            supported: vec![2, 4],
            ..Default::default()
        });
        b.push(req(0, 512), 0.0);
        let w = b.next_wave(1.0).unwrap();
        assert_eq!(w.batch, 2);
        assert_eq!(w.requests.len(), 1);
        assert_eq!(w.padding(), 1);
    }

    #[test]
    fn rejects_oversized_contexts() {
        let mut b = Batcher::new(BatcherConfig::default());
        assert!(!b.push(req(0, 99999), 0.0));
        assert_eq!(b.rejected, 1);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn prop_waves_partition_queue_fifo() {
        proptest::check("batcher-fifo", 100, |rng| {
            let mut b = Batcher::new(BatcherConfig::default());
            let n = rng.range(1, 40);
            for i in 0..n {
                b.push(req(i as u64, 256 + rng.below(1024)), 0.0);
            }
            let mut seen = Vec::new();
            let mut t = 1.0;
            while let Some(w) = b.next_wave(t) {
                crate::prop_assert!(
                    w.requests.len() <= w.batch,
                    "wave overfilled"
                );
                seen.extend(w.requests.iter().map(|r| r.id));
                t += 1.0;
            }
            crate::prop_assert!(
                seen == (0..n as u64).collect::<Vec<_>>(),
                "requests lost or reordered: {seen:?}"
            );
            Ok(())
        });
    }
}
