//! Request router: the serving front that owns the engine thread.
//!
//! `Engine` is deliberately single-threaded (PJRT handles live on one
//! thread; the I/O thread is the engine's own). The router bridges:
//! callers submit `Request`s from any thread; a dedicated engine thread
//! batches them (Batcher), runs prefill + decode waves, and returns
//! `Completion`s. Used by the TCP server example and the serve command.
//!
//! A failed wave is contained, not fatal: its requests get error
//! completions (`Completion::error`) and the loop keeps serving — one
//! oversized or poisoned wave must never kill the session.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, BatcherConfig};
use super::engine::{Engine, EngineConfig};
use crate::disk::{Lane, LaneSummary};
use crate::metrics::DecodeStats;
use crate::runtime::{Manifest, PjrtRuntime};
use crate::store::PersistentStore;
use crate::util::json::Json;
use crate::workload::tracegen::{prompt_tokens, Request};

#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_ms: f64,
    pub batch: usize,
    /// Set when this request's wave failed: `tokens` is empty and the
    /// request was not served (the session itself keeps running).
    pub error: Option<String>,
}

enum RouterMsg {
    Submit(Request),
    Flush,
    /// Reply with a health/stats snapshot (breaker state, overlap,
    /// degradations, persistent-store counters) for the serve API.
    Stats(Sender<Json>),
    Stop,
}

/// Session-cumulative serving counters. Wave engines are short-lived,
/// so every wave folds its telemetry in here — the stats line then
/// reports one consistent scope (cumulative, like the store counters)
/// instead of mixing "last wave" with "whole session".
#[derive(Default)]
struct SessionStats {
    waves: u64,
    /// Waves that failed and were contained (error completions issued).
    wave_errors: u64,
    /// Requests the batcher refused at the door (answered with an error
    /// completion, never silently dropped).
    rejected: u64,
    reused_prefix_tokens: u64,
    degraded_steps: u64,
}

/// Snapshot the engine thread replies with on `RouterMsg::Stats`.
/// `last_wave` carries the wave-scoped health fields (breaker state,
/// overlap ratios); everything counted is session-cumulative.
fn stats_json(
    session: &SessionStats,
    last_wave: &Option<Json>,
    store: Option<&Arc<PersistentStore>>,
) -> Json {
    let mut j = match last_wave {
        Some(w) => w.clone(),
        None => Json::from_pairs(vec![
            ("breaker", "closed".into()),
            ("io_overlap_ratio", 0.0f64.into()),
            ("prefill_io_overlap_ratio", Json::Null),
            ("lanes", Json::Null),
        ]),
    };
    j.set("waves", (session.waves as usize).into());
    j.set("wave_errors", (session.wave_errors as usize).into());
    j.set("rejected", (session.rejected as usize).into());
    j.set(
        "reused_prefix_tokens",
        (session.reused_prefix_tokens as usize).into(),
    );
    j.set("degraded_steps", (session.degraded_steps as usize).into());
    match store {
        Some(s) => {
            j.set("store", s.counters().to_json());
        }
        None => {
            j.set("store", Json::Null);
        }
    }
    j
}

/// Per-lane scheduler counters for the serve `stats` line (cumulative
/// over the wave's engine lifetime).
fn lanes_json(l: &LaneSummary) -> Json {
    let lane = |ln: Lane| {
        Json::from_pairs(vec![
            ("dispatched", (l.lane_dispatched[ln.idx()] as usize).into()),
            ("wait_us", (l.lane_wait_us[ln.idx()] as usize).into()),
            ("mean_wait_us", l.mean_wait_us(ln).into()),
        ])
    };
    Json::from_pairs(vec![
        ("critical", lane(Lane::Critical)),
        ("warm", lane(Lane::Warm)),
        ("background", lane(Lane::Background)),
        ("cross_plan_merges", (l.cross_plan_merges as usize).into()),
        ("aged_promotions", (l.aged_promotions as usize).into()),
    ])
}

pub struct Router {
    tx: Sender<RouterMsg>,
    rx: Receiver<Completion>,
    handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl Router {
    /// Spawn the engine thread. `artifacts_dir` is loaded inside the
    /// thread (PJRT client must live there).
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        engine_cfg: EngineConfig,
        batcher_cfg: BatcherConfig,
    ) -> Router {
        let (tx, req_rx) = channel::<RouterMsg>();
        let (done_tx, rx) = channel::<Completion>();
        let handle = std::thread::Builder::new()
            .name("kvswap-router".into())
            .spawn(move || -> anyhow::Result<()> {
                let rt = std::rc::Rc::new(PjrtRuntime::new(Manifest::load(&artifacts_dir)?)?);
                let mut batcher = Batcher::new(batcher_cfg);
                let t0 = Instant::now();
                let mut arrivals: std::collections::HashMap<u64, Instant> =
                    std::collections::HashMap::new();
                let mut flushing = false;
                // The persistent store outlives the per-wave engines: the
                // first wave opens it (when enabled), later waves share it
                // so cross-request prefix reuse spans the whole session.
                let mut store: Option<Arc<PersistentStore>> = None;
                let mut last_wave: Option<Json> = None;
                let mut session = SessionStats::default();
                // The last successful wave's engine sticks around between
                // waves so idle ticks can scrub its working cache on the
                // same cadence as `store.maintain()`.
                let mut last_engine: Option<Engine> = None;
                let scrub_interval =
                    Duration::from_secs_f64(engine_cfg.store.scrub_interval_s.max(0.0));
                let mut next_kv_scrub = Instant::now() + scrub_interval;
                loop {
                    // drain control messages (wait with a timeout when the
                    // queue is empty so idle gaps fund store maintenance)
                    let msg = if batcher.queue_len() == 0 && !flushing {
                        match req_rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(m) => Some(m),
                            Err(RecvTimeoutError::Timeout) => {
                                // idle tick: store scrub and the
                                // working-cache scrub share the cadence
                                let now = Instant::now();
                                let store_pass =
                                    store.as_ref().is_some_and(|s| s.maintain(now).is_some());
                                if store_pass || now >= next_kv_scrub {
                                    if let Some(eng) = &last_engine {
                                        let _ = eng.scrub_working();
                                    }
                                    next_kv_scrub = now + scrub_interval;
                                }
                                continue;
                            }
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    } else {
                        req_rx.try_recv().ok()
                    };
                    match msg {
                        Some(RouterMsg::Submit(r)) => {
                            let (rid, rctx) = (r.id, r.context);
                            if batcher.push(r, t0.elapsed().as_secs_f64()) {
                                arrivals.insert(rid, Instant::now());
                            } else {
                                // refused at the door (context over the
                                // batcher's provision): answer instead of
                                // dropping it silently — a caller counting
                                // completions must never hang
                                session.rejected += 1;
                                let c = Completion {
                                    id: rid,
                                    tokens: Vec::new(),
                                    latency_ms: 0.0,
                                    batch: 0,
                                    error: Some(format!(
                                        "request context {rctx} over the batcher limit"
                                    )),
                                };
                                if done_tx.send(c).is_err() {
                                    return Ok(());
                                }
                            }
                            continue; // look for more queued submissions
                        }
                        Some(RouterMsg::Flush) => flushing = true,
                        Some(RouterMsg::Stats(reply)) => {
                            let _ = reply.send(stats_json(&session, &last_wave, store.as_ref()));
                            continue;
                        }
                        Some(RouterMsg::Stop) => break,
                        None => {}
                    }
                    let now = if flushing {
                        f64::INFINITY // dispatch whatever is queued
                    } else {
                        t0.elapsed().as_secs_f64()
                    };
                    let Some(wave) = batcher.next_wave(now) else {
                        if flushing && batcher.queue_len() == 0 {
                            flushing = false;
                        }
                        continue;
                    };

                    // Run the wave: shared context length (pad prompts to
                    // the longest, multiple of the prefill chunk). Only the
                    // unpadded request prefix may reach the store — padded
                    // tails and all-zero filler rows would pollute it.
                    session.waves += 1;
                    let wave_res = (|| -> anyhow::Result<(Engine, Vec<i32>, DecodeStats, Vec<Vec<i32>>)> {
                        let mut cfg = engine_cfg.clone();
                        cfg.batch = wave.batch;
                        let mut engine = Engine::with_store(rt.clone(), cfg, store.clone())?;
                        let chunk = rt.manifest.presets[&engine_cfg.preset].prefill_chunk;
                        let vocab = rt.manifest.presets[&engine_cfg.preset].spec.vocab;
                        let ctx_max = wave
                            .requests
                            .iter()
                            .map(|r| r.context)
                            .max()
                            .unwrap_or(chunk)
                            .div_ceil(chunk)
                            * chunk;
                        let mut prompts: Vec<Vec<i32>> = Vec::with_capacity(wave.batch);
                        let mut save_limits: Vec<usize> = Vec::with_capacity(wave.batch);
                        for r in &wave.requests {
                            let mut p = prompt_tokens(r, vocab);
                            save_limits.push(p.len());
                            p.resize(ctx_max, 0);
                            prompts.push(p);
                        }
                        while prompts.len() < wave.batch {
                            prompts.push(vec![0; ctx_max]); // padding rows
                            save_limits.push(0); // …which must never be saved
                        }
                        let first = engine.prefill_with_save_limits(&prompts, &save_limits)?;
                        let steps = wave.requests.iter().map(|r| r.decode).max().unwrap_or(1);
                        let (stats, _, tok_hist) =
                            engine.decode(steps.saturating_sub(1), false, None)?;
                        Ok((engine, first, stats, tok_hist))
                    })();

                    let (engine, first, stats, tok_hist) = match wave_res {
                        Ok(ok) => ok,
                        Err(e) => {
                            // contain the failure: error completions for
                            // this wave's requests, session keeps serving
                            session.wave_errors += 1;
                            crate::log_info!("wave failed ({e}); emitting error completions");
                            let msg = e.to_string();
                            for req in &wave.requests {
                                let latency_ms = arrivals
                                    .remove(&req.id)
                                    .map(|t| t.elapsed().as_secs_f64() * 1e3)
                                    .unwrap_or(0.0);
                                let c = Completion {
                                    id: req.id,
                                    tokens: Vec::new(),
                                    latency_ms,
                                    batch: wave.batch,
                                    error: Some(msg.clone()),
                                };
                                if done_tx.send(c).is_err() {
                                    return Ok(());
                                }
                            }
                            continue;
                        }
                    };
                    if store.is_none() {
                        store = engine.store();
                    }
                    session.reused_prefix_tokens += stats.reused_prefix_tokens;
                    session.degraded_steps += stats.degraded_steps;
                    last_wave = Some(Json::from_pairs(vec![
                        ("breaker", engine.breaker_state().name().into()),
                        ("io_overlap_ratio", engine.io_overlap_ratio().into()),
                        (
                            "prefill_io_overlap_ratio",
                            match stats.prefill_io_overlap {
                                Some(r) => r.into(),
                                None => Json::Null,
                            },
                        ),
                        ("lanes", lanes_json(&engine.lane_summary())),
                    ]));

                    for (row, req) in wave.requests.iter().enumerate() {
                        let mut tokens = vec![first[row]];
                        for step in tok_hist.iter().take(req.decode.saturating_sub(1)) {
                            tokens.push(step[row]);
                        }
                        let latency_ms = arrivals
                            .remove(&req.id)
                            .map(|t| t.elapsed().as_secs_f64() * 1e3)
                            .unwrap_or(0.0);
                        if done_tx
                            .send(Completion {
                                id: req.id,
                                tokens,
                                latency_ms,
                                batch: wave.batch,
                                error: None,
                            })
                            .is_err()
                        {
                            return Ok(());
                        }
                    }
                    last_engine = Some(engine);
                }
                Ok(())
            })
            .expect("spawn router");
        Router {
            tx,
            rx,
            handle: Some(handle),
        }
    }

    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(RouterMsg::Submit(req));
    }

    /// Dispatch all queued requests without waiting for full batches.
    pub fn flush(&self) {
        let _ = self.tx.send(RouterMsg::Flush);
    }

    /// Health/stats snapshot from the engine thread: circuit-breaker
    /// state and overlap ratios from the last wave, session-cumulative
    /// wave/error/reuse/degradation counters, and persistent-store
    /// counters (`store: null` when disabled). `None` when the engine
    /// thread is gone or busy past the timeout.
    pub fn stats(&self) -> Option<Json> {
        let (reply_tx, reply_rx) = channel::<Json>();
        self.tx.send(RouterMsg::Stats(reply_tx)).ok()?;
        reply_rx.recv_timeout(Duration::from_secs(600)).ok()
    }

    pub fn recv(&self) -> Option<Completion> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<Completion> {
        self.rx.recv_timeout(dur).ok()
    }

    pub fn stop(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(RouterMsg::Stop);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("router thread panicked"))??;
        }
        Ok(())
    }
}
