//! Request router: the serving front that owns the engine thread.
//!
//! `Engine` is deliberately single-threaded (PJRT handles live on one
//! thread; the I/O thread is the engine's own). The router bridges:
//! callers submit `Request`s from any thread; a dedicated engine thread
//! batches them (Batcher), runs prefill + decode waves, and returns
//! `Completion`s. Used by the TCP server example and the serve command.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, BatcherConfig};
use super::engine::{Engine, EngineConfig};
use crate::runtime::{Manifest, PjrtRuntime};
use crate::store::PersistentStore;
use crate::util::json::Json;
use crate::workload::tracegen::{prompt_tokens, Request};

#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_ms: f64,
    pub batch: usize,
}

enum RouterMsg {
    Submit(Request),
    Flush,
    /// Reply with a health/stats snapshot (breaker state, overlap,
    /// degradations, persistent-store counters) for the serve API.
    Stats(Sender<Json>),
    Stop,
}

/// Snapshot the engine thread replies with on `RouterMsg::Stats`.
fn stats_json(last_wave: &Option<Json>, store: Option<&Arc<PersistentStore>>) -> Json {
    let mut j = match last_wave {
        Some(w) => w.clone(),
        None => Json::from_pairs(vec![
            ("breaker", "closed".into()),
            ("io_overlap_ratio", 0.0f64.into()),
            ("degraded_steps", 0usize.into()),
            ("reused_prefix_tokens", 0usize.into()),
        ]),
    };
    match store {
        Some(s) => {
            j.set("store", s.counters().to_json());
        }
        None => {
            j.set("store", Json::Null);
        }
    }
    j
}

pub struct Router {
    tx: Sender<RouterMsg>,
    rx: Receiver<Completion>,
    handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl Router {
    /// Spawn the engine thread. `artifacts_dir` is loaded inside the
    /// thread (PJRT client must live there).
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        engine_cfg: EngineConfig,
        batcher_cfg: BatcherConfig,
    ) -> Router {
        let (tx, req_rx) = channel::<RouterMsg>();
        let (done_tx, rx) = channel::<Completion>();
        let handle = std::thread::Builder::new()
            .name("kvswap-router".into())
            .spawn(move || -> anyhow::Result<()> {
                let rt = std::rc::Rc::new(PjrtRuntime::new(Manifest::load(&artifacts_dir)?)?);
                let mut batcher = Batcher::new(batcher_cfg);
                let t0 = Instant::now();
                let mut arrivals: std::collections::HashMap<u64, Instant> =
                    std::collections::HashMap::new();
                let mut flushing = false;
                // The persistent store outlives the per-wave engines: the
                // first wave opens it (when enabled), later waves share it
                // so cross-request prefix reuse spans the whole session.
                let mut store: Option<Arc<PersistentStore>> = None;
                let mut last_wave: Option<Json> = None;
                loop {
                    // drain control messages (wait with a timeout when the
                    // queue is empty so idle gaps fund store maintenance)
                    let msg = if batcher.queue_len() == 0 && !flushing {
                        match req_rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(m) => Some(m),
                            Err(RecvTimeoutError::Timeout) => {
                                if let Some(s) = &store {
                                    s.maintain(Instant::now());
                                }
                                continue;
                            }
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    } else {
                        req_rx.try_recv().ok()
                    };
                    match msg {
                        Some(RouterMsg::Submit(r)) => {
                            arrivals.insert(r.id, Instant::now());
                            batcher.push(r, t0.elapsed().as_secs_f64());
                            continue; // look for more queued submissions
                        }
                        Some(RouterMsg::Flush) => flushing = true,
                        Some(RouterMsg::Stats(reply)) => {
                            let _ = reply.send(stats_json(&last_wave, store.as_ref()));
                            continue;
                        }
                        Some(RouterMsg::Stop) => break,
                        None => {}
                    }
                    let now = if flushing {
                        f64::INFINITY // dispatch whatever is queued
                    } else {
                        t0.elapsed().as_secs_f64()
                    };
                    let Some(wave) = batcher.next_wave(now) else {
                        if flushing && batcher.queue_len() == 0 {
                            flushing = false;
                        }
                        continue;
                    };

                    // run the wave: shared context length (pad prompts to
                    // the longest, multiple of the prefill chunk)
                    let mut cfg = engine_cfg.clone();
                    cfg.batch = wave.batch;
                    let mut engine = Engine::with_store(rt.clone(), cfg, store.clone())?;
                    let chunk = rt.manifest.presets[&engine_cfg.preset].prefill_chunk;
                    let vocab = rt.manifest.presets[&engine_cfg.preset].spec.vocab;
                    let ctx_max = wave
                        .requests
                        .iter()
                        .map(|r| r.context)
                        .max()
                        .unwrap_or(chunk)
                        .div_ceil(chunk)
                        * chunk;
                    let mut prompts: Vec<Vec<i32>> = wave
                        .requests
                        .iter()
                        .map(|r| {
                            let mut p = prompt_tokens(r, vocab);
                            p.resize(ctx_max, 0);
                            p
                        })
                        .collect();
                    while prompts.len() < wave.batch {
                        prompts.push(vec![0; ctx_max]); // padding rows
                    }
                    let first = engine.prefill(&prompts)?;
                    let steps = wave.requests.iter().map(|r| r.decode).max().unwrap_or(1);
                    let (stats, _, tok_hist) = engine.decode(steps.saturating_sub(1), false, None)?;
                    if store.is_none() {
                        store = engine.store();
                    }
                    last_wave = Some(Json::from_pairs(vec![
                        ("breaker", engine.breaker_state().name().into()),
                        ("io_overlap_ratio", engine.io_overlap_ratio().into()),
                        ("degraded_steps", (stats.degraded_steps as usize).into()),
                        (
                            "reused_prefix_tokens",
                            (stats.reused_prefix_tokens as usize).into(),
                        ),
                    ]));

                    for (row, req) in wave.requests.iter().enumerate() {
                        let mut tokens = vec![first[row]];
                        for step in tok_hist.iter().take(req.decode.saturating_sub(1)) {
                            tokens.push(step[row]);
                        }
                        let latency_ms = arrivals
                            .remove(&req.id)
                            .map(|t| t.elapsed().as_secs_f64() * 1e3)
                            .unwrap_or(0.0);
                        if done_tx
                            .send(Completion {
                                id: req.id,
                                tokens,
                                latency_ms,
                                batch: wave.batch,
                            })
                            .is_err()
                        {
                            return Ok(());
                        }
                    }
                }
                Ok(())
            })
            .expect("spawn router");
        Router {
            tx,
            rx,
            handle: Some(handle),
        }
    }

    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(RouterMsg::Submit(req));
    }

    /// Dispatch all queued requests without waiting for full batches.
    pub fn flush(&self) {
        let _ = self.tx.send(RouterMsg::Flush);
    }

    /// Health/stats snapshot from the engine thread: circuit-breaker
    /// state, I/O overlap ratio, degraded steps, reused prefix tokens,
    /// and persistent-store counters (`store: null` when disabled).
    /// `None` when the engine thread is gone or busy past the timeout.
    pub fn stats(&self) -> Option<Json> {
        let (reply_tx, reply_rx) = channel::<Json>();
        self.tx.send(RouterMsg::Stats(reply_tx)).ok()?;
        reply_rx.recv_timeout(Duration::from_secs(600)).ok()
    }

    pub fn recv(&self) -> Option<Completion> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<Completion> {
        self.rx.recv_timeout(dur).ok()
    }

    pub fn stop(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(RouterMsg::Stop);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("router thread panicked"))??;
        }
        Ok(())
    }
}
