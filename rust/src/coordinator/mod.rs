//! Layer-3 coordinator: the decode engine (layer-pipelined, I/O-
//! overlapped), the offloading policies, the dynamic batcher and the
//! request router.

pub mod batcher;
pub mod engine;
pub mod policy;
pub mod router;

pub use engine::{Engine, EngineConfig};
pub use policy::Policy;
