//! Offloading policies: KVSwap and every baseline the paper compares
//! against (§4.2), expressed as variants of one decode engine so that
//! disk, predictor, metrics and attention plumbing are shared and the
//! comparisons are apples-to-apples.

/// Which offloading scheme the engine runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// The paper's system: grouped prediction, compressed K cache,
    /// rolling + reuse buffers, overlapped grouped disk loads.
    KvSwap,
    /// FlexGen [50]: full KV cache on disk, restored layer-by-layer each
    /// step; full attention; no selection.
    FlexGen,
    /// InfiniGen [36] adapted to disk: per-token selection. `head_agg`
    /// false = original per-head index selection (fragmented); true =
    /// InfiniGen* (our head aggregation); `reuse` = InfiniGen*+ru.
    InfiniGen { head_agg: bool, reuse: bool },
    /// Loki [51]: token-granular selection with *dimension-selected* keys
    /// (one-hot adapter) instead of SVD low-rank.
    Loki,
    /// ShadowKV [52] adapted to disk: conservative-rank K_lr kept in
    /// memory and K *reconstructed* from it at attention time; V loaded
    /// from disk at chunk granularity.
    ShadowKv { chunk: usize, rank: usize },
    /// vLLM-like upper bound: full KV resident in memory, no disk.
    FullMemory,
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::KvSwap => "kvswap".into(),
            Policy::FlexGen => "flexgen".into(),
            Policy::InfiniGen {
                head_agg: false, ..
            } => "infinigen".into(),
            Policy::InfiniGen {
                head_agg: true,
                reuse: false,
            } => "infinigen*".into(),
            Policy::InfiniGen {
                head_agg: true,
                reuse: true,
            } => "infinigen*+ru".into(),
            Policy::Loki => "loki".into(),
            Policy::ShadowKv { .. } => "shadowkv".into(),
            Policy::FullMemory => "vllm-like".into(),
        }
    }

    pub fn by_name(name: &str) -> Option<Policy> {
        match name {
            "kvswap" => Some(Policy::KvSwap),
            "flexgen" => Some(Policy::FlexGen),
            "infinigen" => Some(Policy::InfiniGen {
                head_agg: false,
                reuse: false,
            }),
            "infinigen*" => Some(Policy::InfiniGen {
                head_agg: true,
                reuse: false,
            }),
            "infinigen*+ru" => Some(Policy::InfiniGen {
                head_agg: true,
                reuse: true,
            }),
            "loki" => Some(Policy::Loki),
            "shadowkv" => Some(Policy::ShadowKv { chunk: 8, rank: 32 }),
            "vllm" | "vllm-like" | "full" => Some(Policy::FullMemory),
            _ => None,
        }
    }

    /// Does this policy keep the full KV cache in memory?
    pub fn memory_resident(&self) -> bool {
        matches!(self, Policy::FullMemory)
    }

    /// Does this policy use token-granular (G=1) disk access?
    pub fn token_granular(&self) -> bool {
        matches!(self, Policy::InfiniGen { .. } | Policy::Loki)
    }

    pub fn uses_reuse(&self) -> bool {
        match self {
            Policy::KvSwap => true,
            Policy::InfiniGen { reuse, .. } => *reuse,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for n in [
            "kvswap",
            "flexgen",
            "infinigen",
            "infinigen*",
            "infinigen*+ru",
            "loki",
            "shadowkv",
            "vllm-like",
        ] {
            let p = Policy::by_name(n).unwrap();
            assert_eq!(p.name(), n);
        }
        assert!(Policy::by_name("nope").is_none());
    }

    #[test]
    fn classification() {
        assert!(Policy::FullMemory.memory_resident());
        assert!(!Policy::KvSwap.memory_resident());
        assert!(Policy::Loki.token_granular());
        assert!(!Policy::KvSwap.token_granular());
        assert!(Policy::KvSwap.uses_reuse());
        assert!(!Policy::FlexGen.uses_reuse());
        assert!(Policy::by_name("infinigen*+ru").unwrap().uses_reuse());
    }
}
