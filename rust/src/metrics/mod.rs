//! Serving metrics: per-phase latency breakdown (the paper's Fig. 13a),
//! throughput accounting, and report tables.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::disk::PrefetchSummary;
use crate::util::mathx;

/// Decode phases instrumented by the engine (paper Fig. 13a breakdown).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Embed,
    Predict,
    Select,
    /// Residual I/O stall: the portion of device read time compute did
    /// NOT hide (with the threaded prefetcher this is a remainder, not
    /// the full read latency).
    IoWait,
    Gather,
    Attention,
    ReuseMgmt,
    KvAppend,
    Logits,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Embed => "embed",
            Phase::Predict => "predict",
            Phase::Select => "select",
            Phase::IoWait => "io_wait",
            Phase::Gather => "gather",
            Phase::Attention => "attention",
            Phase::ReuseMgmt => "reuse_mgmt",
            Phase::KvAppend => "kv_append",
            Phase::Logits => "logits",
        }
    }

    pub fn all() -> [Phase; 9] {
        [
            Phase::Embed,
            Phase::Predict,
            Phase::Select,
            Phase::IoWait,
            Phase::Gather,
            Phase::Attention,
            Phase::ReuseMgmt,
            Phase::KvAppend,
            Phase::Logits,
        ]
    }
}

/// Accumulates phase durations across decode steps.
#[derive(Debug, Default, Clone)]
pub struct Breakdown {
    totals: BTreeMap<Phase, Duration>,
    pub steps: u64,
}

impl Breakdown {
    pub fn add(&mut self, phase: Phase, d: Duration) {
        *self.totals.entry(phase).or_insert(Duration::ZERO) += d;
    }

    pub fn get(&self, phase: Phase) -> Duration {
        self.totals.get(&phase).cloned().unwrap_or(Duration::ZERO)
    }

    pub fn total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// Per-step mean duration of a phase, in milliseconds.
    pub fn per_step_ms(&self, phase: Phase) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.get(phase).as_secs_f64() * 1e3 / self.steps as f64
    }

    /// I/O : compute ratio — the paper's Fig. 3b statistic.
    pub fn io_compute_ratio(&self) -> f64 {
        let io = self.get(Phase::IoWait).as_secs_f64();
        let compute = self.get(Phase::Attention).as_secs_f64()
            + self.get(Phase::Predict).as_secs_f64()
            + self.get(Phase::Embed).as_secs_f64()
            + self.get(Phase::Logits).as_secs_f64();
        if compute <= 0.0 {
            return 0.0;
        }
        io / compute
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for p in Phase::all() {
            let d = self.get(p);
            if d > Duration::ZERO {
                s.push_str(&format!("  {:<11} {:>9.3} ms/step\n", p.name(), self.per_step_ms(p)));
            }
        }
        s
    }
}

/// End-of-run decode statistics.
#[derive(Debug, Clone)]
pub struct DecodeStats {
    /// Generated tokens (batch * steps).
    pub tokens: u64,
    pub steps: u64,
    /// Wall (or virtual) seconds spent decoding.
    pub seconds: f64,
    pub breakdown: Breakdown,
    /// Mean reuse-buffer hit rate across layers/seqs (None = reuse off).
    pub reuse_rate: Option<f64>,
    /// Disk I/O utilization vs peak bandwidth during decode.
    pub io_utilization: f64,
    pub bytes_loaded: u64,
    pub mean_overlap: f64,
    /// What the prefetch pipeline did (plans, extents→runs coalescing,
    /// staged bytes) over this run.
    pub prefetch: PrefetchSummary,
    /// Layer-awaits that fell back to attention over resident state
    /// because their staged load was unrecoverable (degradation rung 4 —
    /// see `disk` module docs). 0 on a healthy device.
    pub degraded_steps: u64,
    /// Prompt tokens restored from the persistent KV store instead of
    /// recomputed during prefill (summed over batch rows). 0 when the
    /// store is disabled or no request shared a stored prefix.
    pub reused_prefix_tokens: u64,
    /// Fraction of the persistent store's device read time hidden behind
    /// prefill compute by warm-start restores (`None` when no warm
    /// restore ran; blocking restores report `Some(0.0)`).
    pub prefill_io_overlap: Option<f64>,
}

impl DecodeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.seconds <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.seconds
        }
    }
}

/// Latency percentile summary for request-level metrics (server example).
#[derive(Debug, Clone)]
pub struct LatencySummary {
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub n: usize,
}

pub fn latency_summary(samples_ms: &[f64]) -> LatencySummary {
    LatencySummary {
        p50_ms: mathx::percentile(samples_ms, 50.0),
        p90_ms: mathx::percentile(samples_ms, 90.0),
        p99_ms: mathx::percentile(samples_ms, 99.0),
        mean_ms: mathx::summarize(samples_ms).mean,
        n: samples_ms.len(),
    }
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$}  ", w = w));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len() - 2)
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates_and_reports() {
        let mut b = Breakdown::default();
        b.add(Phase::Attention, Duration::from_millis(10));
        b.add(Phase::Attention, Duration::from_millis(20));
        b.add(Phase::IoWait, Duration::from_millis(60));
        b.steps = 3;
        assert_eq!(b.get(Phase::Attention), Duration::from_millis(30));
        assert!((b.per_step_ms(Phase::IoWait) - 20.0).abs() < 1e-9);
        assert!((b.io_compute_ratio() - 2.0).abs() < 1e-9);
        assert!(b.report().contains("attention"));
        assert!(!b.report().contains("gather")); // zero phases omitted
    }

    #[test]
    fn decode_stats_throughput() {
        let s = DecodeStats {
            tokens: 100,
            steps: 50,
            seconds: 4.0,
            breakdown: Breakdown::default(),
            reuse_rate: Some(0.8),
            io_utilization: 0.5,
            bytes_loaded: 1 << 20,
            mean_overlap: 0.7,
            prefetch: PrefetchSummary::default(),
            degraded_steps: 0,
            reused_prefix_tokens: 0,
            prefill_io_overlap: None,
        };
        assert!((s.tokens_per_sec() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "tok/s"]);
        t.row(vec!["kvswap".into(), "46.8".into()]);
        t.row(vec!["flexgen".into(), "0.4".into()]);
        let r = t.render();
        assert!(r.contains("method"));
        assert!(r.contains("kvswap"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn latency_summary_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = latency_summary(&samples);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.n, 100);
    }
}
