//! Sampled profiling (Appendix A.3): measure the I/O delay
//! `T_io(b, MG, G, C)` and the model delay `T_model(b, MG, C, S, σ)` on
//! the real engine over a sweep of (b, S) points, then interpolate — the
//! paper profiles one representative transformer block; we profile
//! single decode steps and divide.

use std::collections::BTreeMap;

use crate::disk::DiskProfile;

/// One measured profile point.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileSample {
    pub batch: usize,
    pub context: usize,
    pub group: usize,
    pub rank: usize,
    pub reuse_slots: usize,
    /// Mean per-layer modeled I/O time (seconds).
    pub t_io: f64,
    /// Mean per-layer compute time (seconds): attention + predict share.
    pub t_compute: f64,
}

/// Interpolating delay model over measured samples + an analytic fallback
/// for unmeasured points (the paper interpolates too, A.3).
#[derive(Debug, Default, Clone)]
pub struct DelayModel {
    /// samples keyed by (batch, context, group, rank, reuse)
    samples: BTreeMap<(usize, usize, usize, usize, usize), ProfileSample>,
}

impl DelayModel {
    pub fn add(&mut self, s: ProfileSample) {
        self.samples
            .insert((s.batch, s.context, s.group, s.rank, s.reuse_slots), s);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Analytic I/O time per layer for a config — used to extrapolate
    /// beyond measured points and by tests: `misses` groups of
    /// `group_bytes` each, read as one extent per group.
    pub fn analytic_t_io(
        disk: &DiskProfile,
        mg_entries: usize,
        group: usize,
        entry_bytes: usize,
        reuse_rate: f64,
    ) -> f64 {
        if group == 0 {
            return 0.0;
        }
        let n_groups = mg_entries / group.max(1);
        let misses = (n_groups as f64 * (1.0 - reuse_rate)).ceil() as u64;
        let group_bytes = (group * entry_bytes) as u64;
        // queue-depth-aware batch (matches the engine's I/O thread)
        let phys = misses * disk.physical_bytes(0, group_bytes);
        disk.batched_read_time(phys, misses).as_secs_f64()
    }

    /// Nearest measured sample (exact match preferred, else nearest in
    /// (batch, context) with matching group/rank), combined with analytic
    /// scaling for the I/O part.
    pub fn lookup(
        &self,
        batch: usize,
        context: usize,
        group: usize,
        rank: usize,
        reuse_slots: usize,
    ) -> Option<ProfileSample> {
        if let Some(s) = self.samples.get(&(batch, context, group, rank, reuse_slots)) {
            return Some(s.clone());
        }
        // nearest neighbour by log-distance in (batch, context)
        let mut best: Option<(f64, &ProfileSample)> = None;
        for s in self.samples.values() {
            if s.group != group || s.rank != rank {
                continue;
            }
            let d = ((s.batch as f64 / batch as f64).ln().abs())
                + ((s.context as f64 / context as f64).ln().abs())
                + ((s.reuse_slots.max(1) as f64 / reuse_slots.max(1) as f64).ln().abs()) * 0.3;
            if best.map(|(bd, _)| d < bd).unwrap_or(true) {
                best = Some((d, s));
            }
        }
        best.map(|(_, s)| {
            let mut out = s.clone();
            // compute scales ~linearly with batch; predict part with context
            let bscale = batch as f64 / s.batch as f64;
            let cscale = context as f64 / s.context as f64;
            out.batch = batch;
            out.context = context;
            out.reuse_slots = reuse_slots;
            out.t_compute *= bscale * (0.6 + 0.4 * cscale);
            out.t_io *= bscale;
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(b: usize, s: usize, io: f64, comp: f64) -> ProfileSample {
        ProfileSample {
            batch: b,
            context: s,
            group: 4,
            rank: 16,
            reuse_slots: 64,
            t_io: io,
            t_compute: comp,
        }
    }

    #[test]
    fn exact_match_returned() {
        let mut m = DelayModel::default();
        m.add(sample(2, 1024, 0.01, 0.02));
        let s = m.lookup(2, 1024, 4, 16, 64).unwrap();
        assert_eq!(s.t_io, 0.01);
        assert_eq!(s.t_compute, 0.02);
    }

    #[test]
    fn nearest_neighbour_scales_with_batch() {
        let mut m = DelayModel::default();
        m.add(sample(1, 1024, 0.01, 0.02));
        let s = m.lookup(4, 1024, 4, 16, 64).unwrap();
        assert!((s.t_io - 0.04).abs() < 1e-9);
        assert!(s.t_compute > 0.02);
        assert!(m.lookup(4, 1024, 8, 16, 64).is_none()); // group mismatch
    }

    #[test]
    fn analytic_io_decreases_with_grouping_and_reuse() {
        let d = DiskProfile::emmc();
        let t_g1 = DelayModel::analytic_t_io(&d, 256, 1, 1024, 0.0);
        let t_g8 = DelayModel::analytic_t_io(&d, 256, 8, 1024, 0.0);
        assert!(t_g1 > t_g8 * 3.0, "{t_g1} vs {t_g8}");
        let t_reuse = DelayModel::analytic_t_io(&d, 256, 8, 1024, 0.75);
        assert!(t_reuse < t_g8 * 0.35);
    }
}
