//! Offline parameter tuning (paper §3.5 + Appendix A).
//!
//! Selects runtime parameters (compression ratio σ → rank r, group size
//! G, selected groups M, reuse capacity C) under a memory budget B, by:
//!  1. building lookup tables (C → reuse rate; σ → adapter) — `tables`
//!  2. sampled profiling of T_io and T_model over (b, S) — `profiler`
//!  3. a greedy solver that first fits σ to the budget, then grows G
//!     until (1−α) of I/O hides under compute, reallocating budget to C
//!     when G_max is insufficient — `solver`

pub mod profiler;
pub mod solver;
pub mod tables;

pub use profiler::{DelayModel, ProfileSample};
pub use solver::{solve, Solution, SolverConfig};
pub use tables::ReuseTable;
