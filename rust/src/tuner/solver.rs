//! Greedy parameter solver (Appendix A.4, Fig. 1 of the appendix).
//!
//! Given user constraints (B_max, S_max, b_max), the target model spec,
//! the disk profile, a reuse table and a delay model, the solver:
//!
//!   1. picks the largest rank r (smallest σ) whose compressed K cache +
//!      fixed buffers fit the per-batch memory budget;
//!   2. searches the smallest group size G that hides (1−α) of the I/O
//!      under compute;
//!   3. if even G_max fails, reallocates budget to the reuse buffer
//!      (C += δ), shrinking σ to stay within budget, and restarts from
//!      G = 1;
//!   4. records a solution per (b, S) pair; the runtime retrieves by
//!      exact match or nearest neighbour.

use crate::config::{KvSwapConfig, ModelSpec};
use crate::disk::DiskProfile;
use crate::util::json::Json;

use super::profiler::DelayModel;
use super::tables::ReuseTable;

#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Per-batch-row KV management memory budget, bytes.
    pub budget_bytes: u64,
    pub s_max: usize,
    pub b_max: usize,
    /// MG = Const (Appendix A.2).
    pub mg_entries: usize,
    /// Relaxation factor: fraction of I/O allowed to stay unhidden.
    pub alpha: f64,
    pub g_candidates: Vec<usize>,
    pub rank_candidates: Vec<usize>,
    pub c_candidates: Vec<usize>,
    /// Reuse-capacity increment per relaxation round (δ).
    pub c_step: usize,
    pub rb_slots: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            budget_bytes: 2 << 20,
            s_max: 2048,
            b_max: 8,
            mg_entries: 256,
            alpha: 0.15,
            g_candidates: vec![1, 2, 4, 8, 16],
            rank_candidates: vec![4, 8, 16, 32],
            c_candidates: vec![0, 16, 32, 64, 96, 128],
            c_step: 32,
            rb_slots: 16,
        }
    }
}

/// Solver output for one (b, S) point.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub batch: usize,
    pub context: usize,
    pub group: usize,
    pub rank: usize,
    pub reuse_slots: usize,
    pub mg_entries: usize,
    /// Expected unhidden I/O fraction at this config.
    pub unhidden_io: f64,
    pub mgmt_bytes: u64,
    /// True if the solver met the (1−α) overlap target.
    pub feasible: bool,
}

impl Solution {
    pub fn to_kvswap_config(&self, base: &KvSwapConfig) -> KvSwapConfig {
        let mut c = base.clone();
        c.group_size = self.group;
        c.n_groups = (self.mg_entries / self.group.max(1)).max(1);
        c.rank = self.rank;
        c.reuse_slots = self.reuse_slots;
        c
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("batch", self.batch.into()),
            ("context", self.context.into()),
            ("group", self.group.into()),
            ("rank", self.rank.into()),
            ("reuse_slots", self.reuse_slots.into()),
            ("mg_entries", self.mg_entries.into()),
            ("unhidden_io", self.unhidden_io.into()),
            ("mgmt_bytes", (self.mgmt_bytes as usize).into()),
            ("feasible", self.feasible.into()),
        ])
    }
}

/// Per-row management memory of a candidate config (mirrors
/// `KvSwapConfig::management_bytes_per_seq`, f32 entries).
fn mgmt_bytes(
    spec: &ModelSpec,
    context: usize,
    rank: usize,
    reuse_slots: usize,
    group: usize,
    rb: usize,
    mg: usize,
) -> u64 {
    let entry = spec.kv_bytes_per_token_layer();
    let l = spec.n_layers as u64;
    let klr = (context * rank * 4) as u64 * l;
    let reuse = (reuse_slots * group) as u64 * entry * l;
    let rolling = rb as u64 * entry * l;
    let staging = mg as u64 * entry;
    klr + reuse + rolling + staging
}

/// Solve for one (batch, context) point.
pub fn solve_point(
    spec: &ModelSpec,
    disk: &DiskProfile,
    reuse_table: &ReuseTable,
    delays: &DelayModel,
    cfg: &SolverConfig,
    batch: usize,
    context: usize,
) -> Solution {
    let entry_bytes = spec.kv_bytes_per_token_layer() as usize;
    let rb = cfg.rb_slots;

    // budget-feasible rank (largest rank under budget with C = 0)
    let rank_for = |c_slots: usize, group: usize| -> Option<usize> {
        cfg.rank_candidates
            .iter()
            .rev()
            .find(|&&r| {
                mgmt_bytes(spec, context, r, c_slots, group, rb, cfg.mg_entries)
                    <= cfg.budget_bytes
            })
            .copied()
    };

    let mut c_slots = 0usize;
    let mut best_infeasible: Option<Solution> = None;
    loop {
        let Some(rank) = rank_for(c_slots, *cfg.g_candidates.last().unwrap()) else {
            // even the smallest rank does not fit with this C: give up on
            // growing C further
            break;
        };
        let reuse_rate = reuse_table.rate(c_slots * 4); // slots are in groups of G≈4 equiv
        for &g in &cfg.g_candidates {
            // measured compute if available; else scale a neighbour
            let t_compute = delays
                .lookup(batch, context, g, rank, c_slots)
                .map(|s| s.t_compute)
                .unwrap_or_else(|| {
                    // analytic floor: attention over MG entries + predict
                    // over context rows — normalized so relative G/σ
                    // comparisons still hold
                    1e-8 * (cfg.mg_entries as f64 * batch as f64)
                        + 2e-10 * (context as f64 * rank as f64 * batch as f64)
                });
            let t_io = DelayModel::analytic_t_io(
                disk,
                cfg.mg_entries * batch,
                g,
                entry_bytes,
                if c_slots == 0 { 0.0 } else { reuse_rate },
            );
            let unhidden = ((t_io - t_compute) / t_io.max(1e-12)).max(0.0);
            let sol = Solution {
                batch,
                context,
                group: g,
                rank,
                reuse_slots: c_slots,
                mg_entries: cfg.mg_entries,
                unhidden_io: unhidden,
                mgmt_bytes: mgmt_bytes(spec, context, rank, c_slots, g, rb, cfg.mg_entries),
                feasible: unhidden <= cfg.alpha,
            };
            if sol.feasible {
                return sol;
            }
            if best_infeasible
                .as_ref()
                .map(|b| sol.unhidden_io < b.unhidden_io)
                .unwrap_or(true)
            {
                best_infeasible = Some(sol);
            }
        }
        // G_max failed: reallocate budget to the reuse buffer (A.4)
        c_slots += cfg.c_step;
        if c_slots > *cfg.c_candidates.last().unwrap_or(&128) {
            break;
        }
    }
    best_infeasible.unwrap_or_else(|| {
        // budget is below even the minimum config: report the smallest
        // possible footprint, marked infeasible (the caller decides).
        let rank = *cfg.rank_candidates.iter().min().unwrap();
        let g = *cfg.g_candidates.iter().max().unwrap();
        Solution {
            batch,
            context,
            group: g,
            rank,
            reuse_slots: 0,
            mg_entries: cfg.mg_entries,
            unhidden_io: 1.0,
            mgmt_bytes: mgmt_bytes(spec, context, rank, 0, g, rb, cfg.mg_entries),
            feasible: false,
        }
    })
}

/// Solve the whole (b, S) grid (Appendix A.4 "Record solutions").
pub fn solve(
    spec: &ModelSpec,
    disk: &DiskProfile,
    reuse_table: &ReuseTable,
    delays: &DelayModel,
    cfg: &SolverConfig,
) -> Vec<Solution> {
    let mut out = Vec::new();
    let mut b = 1;
    while b <= cfg.b_max {
        let mut s = 512;
        while s <= cfg.s_max {
            out.push(solve_point(spec, disk, reuse_table, delays, cfg, b, s));
            s *= 2;
        }
        b *= 2;
    }
    out
}

/// Retrieve the solution for (b, S): exact match or nearest (A.4).
pub fn retrieve(solutions: &[Solution], batch: usize, context: usize) -> Option<&Solution> {
    solutions
        .iter()
        .min_by_key(|s| {
            let db = (s.batch as i64 - batch as i64).abs();
            let dc = (s.context as i64 - context as i64).abs();
            db * 10_000 + dc
        })
}

pub fn solutions_to_json(sols: &[Solution]) -> Json {
    Json::Arr(sols.iter().map(|s| s.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano() -> ModelSpec {
        ModelSpec {
            name: "nano".into(),
            n_layers: 4,
            d_model: 128,
            n_q_heads: 8,
            n_kv_heads: 4,
            head_dim: 32,
            d_ff: 256,
            vocab: 512,
            rope_base: 10000.0,
            rms_eps: 1e-5,
        }
    }

    fn table() -> ReuseTable {
        ReuseTable::from_locality_model(64, 0.77, &[0, 16, 32, 64, 128, 256, 512])
    }

    #[test]
    fn solution_always_within_budget() {
        let spec = nano();
        let cfg = SolverConfig {
            budget_bytes: 600 << 10,
            ..Default::default()
        };
        for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
            let sols = solve(&spec, &disk, &table(), &DelayModel::default(), &cfg);
            assert!(!sols.is_empty());
            for s in &sols {
                assert!(
                    s.mgmt_bytes <= cfg.budget_bytes,
                    "{disk:?} b{} s{}: {} > {}",
                    s.batch,
                    s.context,
                    s.mgmt_bytes,
                    cfg.budget_bytes
                );
            }
        }
    }

    #[test]
    fn emmc_needs_larger_groups_than_nvme() {
        // the paper's tuned result: G=4 for NVMe, G=8 for eMMC
        let spec = nano();
        let cfg = SolverConfig {
            budget_bytes: 2 << 20,
            ..Default::default()
        };
        let t = table();
        let d = DelayModel::default();
        let nvme = solve_point(&spec, &DiskProfile::nvme(), &t, &d, &cfg, 8, 2048);
        let emmc = solve_point(&spec, &DiskProfile::emmc(), &t, &d, &cfg, 8, 2048);
        assert!(
            emmc.group >= nvme.group,
            "emmc G={} < nvme G={}",
            emmc.group,
            nvme.group
        );
    }

    #[test]
    fn tighter_budget_forces_smaller_rank() {
        let spec = nano();
        let t = table();
        let d = DelayModel::default();
        let mut cfg = SolverConfig::default();
        cfg.budget_bytes = 4 << 20;
        let relaxed = solve_point(&spec, &DiskProfile::nvme(), &t, &d, &cfg, 8, 2048);
        cfg.budget_bytes = 700 << 10;
        let tight = solve_point(&spec, &DiskProfile::nvme(), &t, &d, &cfg, 8, 2048);
        assert!(tight.rank <= relaxed.rank);
        assert!(tight.mgmt_bytes <= 700 << 10);
        // an impossible budget degrades gracefully (infeasible, no panic)
        cfg.budget_bytes = 10 << 10;
        let broke = solve_point(&spec, &DiskProfile::nvme(), &t, &d, &cfg, 8, 2048);
        assert!(!broke.feasible);
    }

    #[test]
    fn retrieve_prefers_exact_then_nearest() {
        let spec = nano();
        let cfg = SolverConfig::default();
        let sols = solve(
            &spec,
            &DiskProfile::nvme(),
            &table(),
            &DelayModel::default(),
            &cfg,
        );
        let s = retrieve(&sols, 4, 1024).unwrap();
        assert_eq!((s.batch, s.context), (4, 1024));
        let near = retrieve(&sols, 3, 900).unwrap();
        assert!(near.batch == 2 || near.batch == 4);
    }

    #[test]
    fn solution_json_shape() {
        let spec = nano();
        let cfg = SolverConfig::default();
        let s = solve_point(
            &spec,
            &DiskProfile::nvme(),
            &table(),
            &DelayModel::default(),
            &cfg,
            1,
            1024,
        );
        let j = s.to_json();
        assert!(j.get("group").is_some());
        assert!(j.get("feasible").is_some());
        let c = s.to_kvswap_config(&KvSwapConfig::default());
        assert_eq!(c.group_size, s.group);
        assert_eq!(c.group_size * c.n_groups, s.mg_entries);
    }
}
