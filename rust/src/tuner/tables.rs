//! Precomputed lookup tables (Appendix A.1): reuse-buffer capacity C →
//! expected reuse rate. The paper shows reuse rates are largely
//! input-invariant (Tab. 5, std ≤ 1.1%), which justifies storing the
//! average per C; we build the table from measured engine runs or from
//! the locality model below.

use crate::util::json::Json;

/// C (slots, group granularity) → expected reuse hit rate.
#[derive(Debug, Clone, PartialEq)]
pub struct ReuseTable {
    /// (capacity, rate) pairs, capacity-ascending.
    pub entries: Vec<(usize, f64)>,
}

impl ReuseTable {
    pub fn new(mut entries: Vec<(usize, f64)>) -> ReuseTable {
        entries.sort_by_key(|e| e.0);
        ReuseTable { entries }
    }

    /// Analytic locality model used when no measurements are available:
    /// with per-step selection overlap `rho` (paper Fig. 8: ~0.75) and M
    /// selected groups, a buffer of C slots retains roughly the last
    /// C/M selections worth of groups; the hit rate saturates at the
    /// overlap as C grows past M.
    pub fn from_locality_model(m_groups: usize, rho: f64, caps: &[usize]) -> ReuseTable {
        let entries = caps
            .iter()
            .map(|&c| {
                let depth = c as f64 / m_groups.max(1) as f64;
                // geometric retention: rate = rho * (1 - (1-depth)^+ ...)
                let rate = if depth >= 1.0 {
                    rho
                } else {
                    rho * depth
                };
                (c, rate.clamp(0.0, 1.0))
            })
            .collect();
        ReuseTable::new(entries)
    }

    /// Interpolated rate for a capacity.
    pub fn rate(&self, c: usize) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        if c <= self.entries[0].0 {
            return self.entries[0].1 * c as f64 / self.entries[0].0.max(1) as f64;
        }
        for w in self.entries.windows(2) {
            let (c0, r0) = w[0];
            let (c1, r1) = w[1];
            if c <= c1 {
                let t = (c - c0) as f64 / (c1 - c0).max(1) as f64;
                return r0 + (r1 - r0) * t;
            }
        }
        self.entries.last().unwrap().1
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|(c, r)| {
                    Json::from_pairs(vec![("c", (*c).into()), ("rate", (*r).into())])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> ReuseTable {
        ReuseTable::new(
            j.as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|e| (e.usize_or("c", 0), e.f64_or("rate", 0.0)))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_model_saturates_at_overlap() {
        let t = ReuseTable::from_locality_model(64, 0.77, &[16, 32, 64, 128, 256]);
        assert!(t.rate(16) < t.rate(64));
        assert!((t.rate(128) - 0.77).abs() < 1e-9);
        assert!((t.rate(9999) - 0.77).abs() < 1e-9);
    }

    #[test]
    fn interpolation_is_monotone() {
        let t = ReuseTable::new(vec![(10, 0.2), (100, 0.8)]);
        let mut prev = 0.0;
        for c in [1, 10, 30, 55, 100, 500] {
            let r = t.rate(c);
            assert!(r >= prev - 1e-12, "c={c}");
            prev = r;
        }
        assert!((t.rate(55) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let t = ReuseTable::new(vec![(8, 0.3), (64, 0.75)]);
        let j = t.to_json();
        let back = ReuseTable::from_json(&Json::parse(&j.to_string()).unwrap());
        assert_eq!(back, t);
    }
}
