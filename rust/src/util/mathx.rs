//! Small numeric helpers shared across the coordinator: f32 tensor ops for
//! host-side math (group reduce-max, top-k, matmul for K-cache compression,
//! softmax for quality metrics), plus summary statistics.

/// Row-major f32 matmul: a [m,k] x b [k,n] -> out [m,n].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // ikj loop order: streams b rows, vectorizes the inner j loop.
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Per-group max over `scores`, groups of `g` consecutive entries
/// (paper §3.3 ReduceMax). Tail group may be partial.
pub fn group_max(scores: &[f32], g: usize) -> Vec<f32> {
    assert!(g > 0);
    scores
        .chunks(g)
        .map(|c| c.iter().cloned().fold(f32::NEG_INFINITY, f32::max))
        .collect()
}

/// Indices of the `k` largest values (descending). Deterministic: ties
/// break toward the lower index.
pub fn top_k_indices(vals: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(vals.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        vals[b].partial_cmp(&vals[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut top = idx[..k].to_vec();
    top.sort_by(|&a, &b| {
        vals[b]
            .partial_cmp(&vals[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    top
}

/// Numerically-stable softmax in place.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn l2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity; 0 when either vector is ~zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = l2(a);
    let nb = l2(b);
    if na < 1e-12 || nb < 1e-12 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

/// Relative L2 error ||a-b|| / ||b||.
pub fn rel_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f32>()
        .sqrt();
    num / l2(b).max(1e-12)
}

/// Mean / std / min / max summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
        };
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Percentile (linear interpolation), q in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0.0; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let eye = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 12];
        matmul(&a, &eye, 4, 3, 3, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn group_max_basic_and_tail() {
        let s = [1.0, 5.0, 2.0, 8.0, 3.0];
        assert_eq!(group_max(&s, 2), vec![5.0, 8.0, 3.0]);
        assert_eq!(group_max(&s, 5), vec![8.0]);
        assert_eq!(group_max(&s, 1), s.to_vec());
    }

    #[test]
    fn top_k_orders_descending_with_stable_ties() {
        let v = [0.5, 2.0, 2.0, -1.0, 3.0];
        assert_eq!(top_k_indices(&v, 3), vec![4, 1, 2]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&v, 99).len(), 5);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut xs = [1000.0, 1001.0, 999.0];
        softmax(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(xs[1] > xs[0] && xs[0] > xs[2]);
    }

    #[test]
    fn cosine_and_rel_err() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        assert!(cosine(&a, &b).abs() < 1e-6);
        assert!((rel_err(&a, &a)).abs() < 1e-6);
        assert!(rel_err(&b, &a) > 1.0);
    }

    #[test]
    fn summary_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let s = summarize(&xs);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-9);
    }
}
