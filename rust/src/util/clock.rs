//! Clock abstraction: real time vs virtual (modeled) time.
//!
//! The disk substrate charges I/O time against a `Clock`. In **real**
//! mode, waits actually sleep (optionally scaled), so the serving example
//! behaves like a device with that storage attached. In **virtual** mode,
//! waits only advance a counter — large bench sweeps combine *measured*
//! PJRT compute time with *modeled* disk time in seconds of virtual time,
//! which is how throughput tables are produced quickly (DESIGN.md §2).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone)]
pub enum Clock {
    /// Wall-clock; `advance` sleeps for `scale * dur`.
    Real { start: Instant, scale: f64 },
    /// Virtual nanosecond counter; `advance` just adds.
    Virtual(Arc<AtomicU64>),
}

impl Clock {
    pub fn real() -> Clock {
        Clock::Real {
            start: Instant::now(),
            scale: 1.0,
        }
    }

    /// Real clock with sleep scaling (0.1 = waits run 10x faster; useful
    /// for demos on slow simulated disks).
    pub fn real_scaled(scale: f64) -> Clock {
        Clock::Real {
            start: Instant::now(),
            scale,
        }
    }

    pub fn virtual_() -> Clock {
        Clock::Virtual(Arc::new(AtomicU64::new(0)))
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }

    /// Nanoseconds since clock creation (virtual: accumulated).
    pub fn now_ns(&self) -> u64 {
        match self {
            Clock::Real { start, .. } => start.elapsed().as_nanos() as u64,
            Clock::Virtual(ns) => ns.load(Ordering::Relaxed),
        }
    }

    pub fn now_secs(&self) -> f64 {
        self.now_ns() as f64 / 1e9
    }

    /// Charge `dur` of modeled time: sleep (real) or bump counter (virtual).
    pub fn advance(&self, dur: Duration) {
        match self {
            Clock::Real { scale, .. } => {
                if *scale > 0.0 {
                    std::thread::sleep(dur.mul_f64(*scale));
                }
            }
            Clock::Virtual(ns) => {
                ns.fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Charge measured real time onto a virtual clock (no-op on real —
    /// the time already passed). Used to fold PJRT compute durations into
    /// virtual-time throughput accounting.
    pub fn absorb_measured(&self, dur: Duration) {
        if let Clock::Virtual(ns) = self {
            ns.fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// On a virtual clock: account `a` and `b` running concurrently
    /// (advance by max); the paper's compute/I-O overlap accounting.
    pub fn advance_overlapped(&self, a: Duration, b: Duration) {
        self.advance(a.max(b));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_accumulates() {
        let c = Clock::virtual_();
        assert_eq!(c.now_ns(), 0);
        c.advance(Duration::from_millis(5));
        c.advance(Duration::from_millis(7));
        assert_eq!(c.now_ns(), 12_000_000);
    }

    #[test]
    fn virtual_overlap_takes_max() {
        let c = Clock::virtual_();
        c.advance_overlapped(Duration::from_millis(10), Duration::from_millis(4));
        assert_eq!(c.now_ns(), 10_000_000);
    }

    #[test]
    fn real_clock_monotone_and_sleeps() {
        let c = Clock::real();
        let t0 = c.now_ns();
        c.advance(Duration::from_millis(2));
        assert!(c.now_ns() >= t0 + 1_500_000);
    }

    #[test]
    fn scaled_real_clock_sleeps_less() {
        let c = Clock::real_scaled(0.0);
        let t0 = Instant::now();
        c.advance(Duration::from_millis(500));
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn absorb_only_affects_virtual() {
        let v = Clock::virtual_();
        v.absorb_measured(Duration::from_millis(3));
        assert_eq!(v.now_ns(), 3_000_000);
        let r = Clock::real();
        let before = r.now_ns();
        r.absorb_measured(Duration::from_secs(100));
        assert!(r.now_ns() - before < 1_000_000_000);
    }

    #[test]
    fn clone_shares_virtual_state() {
        let c = Clock::virtual_();
        let c2 = c.clone();
        c.advance(Duration::from_millis(1));
        assert_eq!(c2.now_ns(), 1_000_000);
    }
}
