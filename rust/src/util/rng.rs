//! Deterministic PRNG (xoshiro256** + SplitMix64 seeding).
//!
//! The `rand` crate is unavailable offline; workload generation, the
//! property-test framework, and the disk simulator's jitter all use this.
//! Determinism across runs matters for reproducible benches.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipf-like heavy-tailed choice over [0, n) with exponent `a` —
    /// used by workload generators to model skewed access patterns.
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        // inverse-CDF over a truncated zeta; linear scan is fine for the
        // small n used by trace generation.
        let mut norm = 0.0;
        for i in 1..=n {
            norm += (i as f64).powf(-a);
        }
        let target = self.f64() * norm;
        let mut acc = 0.0;
        for i in 1..=n {
            acc += (i as f64).powf(-a);
            if acc >= target {
                return i - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_and_range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let x = r.range(3, 9);
            assert!((3..9).contains(&x));
        }
    }

    #[test]
    fn normal_has_right_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut u = s.clone();
            u.sort();
            u.dedup();
            assert_eq!(u.len(), 8);
        }
    }

    #[test]
    fn zipf_is_skewed_to_small_indices() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 10];
        for _ in 0..5000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[0] > counts[9] * 3);
    }
}
