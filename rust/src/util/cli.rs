//! Tiny CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! typed accessors with defaults; every binary and bench in the repo uses
//! this for its knobs.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn parse_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.options
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.options
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.options
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.options
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list: `--batches 1,2,4`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.options.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.options.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("serve --preset nano --batch 8 trace.json");
        assert_eq!(a.positional, vec!["serve", "trace.json"]);
        assert_eq!(a.str_or("preset", "x"), "nano");
        assert_eq!(a.usize_or("batch", 1), 8);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("--mode=virtual --verbose --scale=0.5");
        assert_eq!(a.str_or("mode", ""), "virtual");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.f64_or("scale", 1.0), 0.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v --c");
        assert!(a.flag("a"));
        assert_eq!(a.str_or("b", ""), "v");
        assert!(a.flag("c"));
    }

    #[test]
    fn lists() {
        let a = parse("--batches 1,2,8 --disks nvme,emmc");
        assert_eq!(a.usize_list_or("batches", &[]), vec![1, 2, 8]);
        assert_eq!(a.str_list_or("disks", &[]), vec!["nvme", "emmc"]);
        assert_eq!(a.usize_list_or("missing", &[4]), vec![4]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("x", 7), 7);
        assert_eq!(a.str_or("y", "d"), "d");
        assert_eq!(a.u64_or("seed", 3), 3);
    }
}
