//! Mini property-testing framework (`proptest` is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over many seeded RNGs;
//! on failure it retries with the same seed to print a reproducible
//! counterexample seed. Used by the kvcache / coordinator / disk invariant
//! tests (DESIGN.md §8).

use super::rng::Rng;

/// Run `prop` for `cases` random cases. The closure gets a deterministic
/// per-case RNG and returns `Err(msg)` (or panics) on violation.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = seed_from_env();
    for case in 0..cases {
        let seed = base.wrapping_add(case).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}; \
                 rerun with KVSWAP_PROP_SEED={base}): {msg}"
            );
        }
    }
}

/// Like `check` but the property panics instead of returning Err.
pub fn check_panics<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    check(name, cases, |rng| {
        prop(rng);
        Ok(())
    });
}

fn seed_from_env() -> u64 {
    std::env::var("KVSWAP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Assert helper that formats a failure message for `check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 50, |rng| {
            n += 1;
            let x = rng.below(10);
            if x < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn per_case_rng_is_deterministic() {
        let mut seen_a = Vec::new();
        check("collect", 5, |rng| {
            seen_a.push(rng.next_u64());
            Ok(())
        });
        let mut seen_b = Vec::new();
        check("collect", 5, |rng| {
            seen_b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
