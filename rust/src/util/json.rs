//! Minimal JSON parser/serializer.
//!
//! `serde`/`serde_json` are not available in this offline environment
//! (DESIGN.md §2 substitution table), so the manifest, tuner output and
//! server protocol use this hand-rolled implementation. It supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) and preserves object insertion order.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (key order is stable for diffable output).
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            if let Some(e) = m.iter_mut().find(|(k, _)| k == key) {
                e.1 = val;
            } else {
                m.push((key.to_string(), val));
            }
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    // ----- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    /// Array of usizes helper (shape vectors in the manifest).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected json array"))?;
        arr.iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected usize")))
            .collect()
    }

    // ----- parse / serialize ---------------------------------------------
    pub fn parse(src: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind + 1));
                        v.write(out, Some(ind + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap()));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap()));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 5;
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 5; // loop adds 1 more below
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad cp"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                self.pos += 4;
                                char::from_u32(cp).ok_or_else(|| self.err("bad cp"))?
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(items));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            items.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(items));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_i64(), Some(1));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.str_or("c", ""), "x");
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" \\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \\ A 😀");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"x": 1, "y": [true, null, "s\n"], "z": {"w": -2.25}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn object_set_get_and_order() {
        let mut o = Json::obj();
        o.set("b", 1.0.into());
        o.set("a", 2.0.into());
        o.set("b", 3.0.into()); // overwrite keeps position
        assert_eq!(o.get("b").unwrap().as_f64(), Some(3.0));
        let keys: Vec<_> = o.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["b", "a"]);
    }

    #[test]
    fn usize_vec_helper() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Json::parse("[1, \"x\"]").unwrap().usize_vec().is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let v = Json::parse(&src).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}
