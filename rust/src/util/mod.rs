//! Infrastructure substrates: JSON, RNG, clocks, CLI parsing, logging,
//! numeric helpers and a mini property-test framework. These replace the
//! crates (`serde`, `rand`, `clap`, `criterion`, `proptest`) that the
//! offline registry does not provide — see DESIGN.md §2.

pub mod cli;
pub mod clock;
pub mod json;
pub mod mathx;
pub mod proptest;
pub mod rng;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

/// `info!`-style logging macro; level 1.
#[macro_export]
macro_rules! log_info {
    ($($fmt:tt)*) => {
        if $crate::util::log_enabled(1) {
            eprintln!("[kvswap] {}", format!($($fmt)*));
        }
    };
}

/// Verbose diagnostics; level 2 (enable with --verbose).
#[macro_export]
macro_rules! log_debug {
    ($($fmt:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[kvswap:debug] {}", format!($($fmt)*));
        }
    };
}

/// Pretty byte counts for reports.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0 MiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.0 GiB");
    }

    #[test]
    fn log_level_gating() {
        set_log_level(0);
        assert!(!log_enabled(1));
        set_log_level(2);
        assert!(log_enabled(1) && log_enabled(2));
        set_log_level(1);
    }
}
