//! Capacity eviction for the persistent KV store: LRU with pinning.
//!
//! Recency is tracked with a logical clock rather than wall time so the
//! order survives a manifest round-trip exactly (wall clocks go backwards;
//! a u64 counter does not). Entries restoring into an in-flight prefill
//! are *pinned*: the engine holds a pin from lookup until its save
//! completes, and a pinned entry is never nominated as a victim — evicting
//! it mid-restore would tear the bytes out from under the reader.

use std::collections::HashMap;

#[derive(Debug, Clone, Copy)]
struct Slot {
    last_used: u64,
    pins: u32,
}

/// LRU book-keeping over entry keys. Pure in-memory policy: the store
/// owns the mapping from victim key to disk extents.
#[derive(Debug, Default)]
pub struct Lru {
    slots: HashMap<u64, Slot>,
    clock: u64,
}

impl Lru {
    pub fn new() -> Lru {
        Lru::default()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Register a new entry as most-recently-used; returns its clock
    /// stamp (persisted into the manifest as `last_used`).
    pub fn insert(&mut self, key: u64) -> u64 {
        let t = self.tick();
        self.slots.insert(key, Slot { last_used: t, pins: 0 });
        t
    }

    /// Re-register an entry loaded from a manifest with its persisted
    /// recency, without advancing the clock past `last_used`.
    pub fn restore(&mut self, key: u64, last_used: u64) {
        self.clock = self.clock.max(last_used);
        self.slots.insert(
            key,
            Slot {
                last_used,
                pins: 0,
            },
        );
    }

    /// Fast-forward the clock to a persisted high-water mark (manifest
    /// clocks can run ahead of any surviving entry's `last_used`).
    pub fn restore_clock(&mut self, clock: u64) {
        self.clock = self.clock.max(clock);
    }

    /// Mark `key` most-recently-used; returns the new stamp (or a fresh
    /// insert's stamp if the key was unknown).
    pub fn touch(&mut self, key: u64) -> u64 {
        let t = self.tick();
        self.slots
            .entry(key)
            .and_modify(|s| s.last_used = t)
            .or_insert(Slot { last_used: t, pins: 0 });
        t
    }

    pub fn pin(&mut self, key: u64) {
        if let Some(s) = self.slots.get_mut(&key) {
            s.pins = s.pins.saturating_add(1);
        }
    }

    pub fn unpin(&mut self, key: u64) {
        if let Some(s) = self.slots.get_mut(&key) {
            s.pins = s.pins.saturating_sub(1);
        }
    }

    pub fn is_pinned(&self, key: u64) -> bool {
        self.slots.get(&key).is_some_and(|s| s.pins > 0)
    }

    pub fn remove(&mut self, key: u64) {
        self.slots.remove(&key);
    }

    /// Least-recently-used unpinned entry, if any. Ties (possible only
    /// via manifest restore) break toward the smaller key for
    /// determinism.
    pub fn victim(&self) -> Option<u64> {
        self.slots
            .iter()
            .filter(|(_, s)| s.pins == 0)
            .min_by_key(|(&k, s)| (s.last_used, k))
            .map(|(&k, _)| k)
    }

    /// Current logical time (stamped onto corruption-log records).
    pub fn clock(&self) -> u64 {
        self.clock
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_order_and_touch() {
        let mut lru = Lru::new();
        lru.insert(1);
        lru.insert(2);
        lru.insert(3);
        assert_eq!(lru.victim(), Some(1));
        lru.touch(1); // now 2 is the oldest
        assert_eq!(lru.victim(), Some(2));
        lru.remove(2);
        assert_eq!(lru.victim(), Some(3));
    }

    #[test]
    fn pins_shield_victims() {
        let mut lru = Lru::new();
        lru.insert(1);
        lru.insert(2);
        lru.pin(1);
        assert!(lru.is_pinned(1));
        assert_eq!(lru.victim(), Some(2));
        lru.pin(2);
        assert_eq!(lru.victim(), None, "everything pinned: no victim");
        // pins are counted, not boolean
        lru.pin(1);
        lru.unpin(1);
        assert!(lru.is_pinned(1));
        lru.unpin(1);
        lru.unpin(2);
        assert_eq!(lru.victim(), Some(1));
        // unpin of an unknown key is a no-op, not a panic
        lru.unpin(99);
    }

    #[test]
    fn restore_preserves_persisted_recency() {
        let mut lru = Lru::new();
        lru.restore(10, 7);
        lru.restore(11, 3);
        assert_eq!(lru.clock(), 7);
        assert_eq!(lru.victim(), Some(11));
        // new inserts stamp past the restored clock
        let t = lru.insert(12);
        assert!(t > 7);
        assert_eq!(lru.victim(), Some(11));
    }
}
