//! Maintenance scheduling for the persistent KV store.
//!
//! Scrubs re-read stored records and check them against the manifest
//! checksums *before* a request depends on them — turning silent rot
//! into a scheduled, bounded repair instead of a mid-prefill failure.
//! The [`Maintainer`] decides *when* (a deadline interval, checked on
//! the engine thread's idle ticks) and *how much* (a per-pass entry
//! budget with a rotating cursor, so a large store is scanned
//! incrementally without ever starving the serving path).
//!
//! What a scrub finds is persisted: every confirmed-bad record becomes a
//! [`CorruptionSite`] in the manifest's corruption log, surviving
//! restarts for post-mortem analysis of a flaky device.
//!
//! Scrub *reads* are maintenance traffic: when the store is attached to
//! the engine's unified I/O scheduler they submit through the
//! `Background` lane, queueing behind decode-critical preloads and warm
//! restores (dispatched only when idle or aged past the starvation
//! bound). Heal retries stay direct — a record already suspected bad
//! should be re-verified immediately, not sit in a queue.

use std::time::Instant;

use crate::util::json::Json;

/// One confirmed-bad record, persisted in the manifest for post-mortem.
#[derive(Debug, Clone, PartialEq)]
pub struct CorruptionSite {
    /// Chain-hash key of the entry that held the record.
    pub entry: u64,
    pub layer: usize,
    pub group: usize,
    /// Byte offset of the record in the store's data file.
    pub offset: u64,
    /// Display form of the read error that confirmed the corruption.
    pub detail: String,
    /// Store logical clock when the site was recorded (orders sites
    /// across restarts; wall time is not crash-stable).
    pub at: u64,
}

impl CorruptionSite {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            // hex: Json numbers are f64 and cannot hold all u64 keys
            ("entry", format!("{:016x}", self.entry).into()),
            ("layer", self.layer.into()),
            ("group", self.group.into()),
            ("offset", (self.offset as usize).into()),
            ("detail", self.detail.clone().into()),
            ("at", (self.at as usize).into()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CorruptionSite> {
        let entry_hex = j
            .get("entry")
            .and_then(|e| e.as_str())
            .ok_or_else(|| anyhow::anyhow!("corruption site: missing entry"))?;
        Ok(CorruptionSite {
            entry: u64::from_str_radix(entry_hex, 16)
                .map_err(|e| anyhow::anyhow!("corruption site: bad entry hex: {e}"))?,
            layer: j.usize_or("layer", 0),
            group: j.usize_or("group", 0),
            offset: j.usize_or("offset", 0) as u64,
            detail: j.str_or("detail", "").to_string(),
            at: j.usize_or("at", 0) as u64,
        })
    }
}

/// Outcome of one scrub pass (also the `run` CLI's printout).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ScrubReport {
    /// Entries visited this pass (bounded by the budget).
    pub entries_scanned: usize,
    /// Records that read back clean (including after a heal retry).
    pub records_clean: usize,
    /// Records that failed verification even after the retry.
    pub corruptions: usize,
    /// Records whose first read failed but whose retry came back clean.
    pub healed: usize,
    /// Entries removed from the store because a record stayed bad.
    pub quarantined: usize,
}

/// Deadline/budget scheduler state. Owns no entries — the store hands it
/// the sorted key list and it answers "which slice, and is it time yet".
#[derive(Debug)]
pub struct Maintainer {
    interval_s: f64,
    budget: usize,
    last: Option<Instant>,
    cursor: u64,
}

impl Maintainer {
    pub fn new(interval_s: f64, budget: usize) -> Maintainer {
        Maintainer {
            interval_s,
            budget: budget.max(1),
            last: None,
            cursor: 0,
        }
    }

    /// Whether a scrub pass is due at `now`. The first call is always
    /// due (a fresh open should verify soon, not an interval later); a
    /// non-positive interval means "every idle tick".
    pub fn due(&self, now: Instant) -> bool {
        match self.last {
            None => true,
            Some(last) => {
                self.interval_s <= 0.0 || now.duration_since(last).as_secs_f64() >= self.interval_s
            }
        }
    }

    /// Mark a pass as started at `now` (resets the deadline).
    pub fn begin(&mut self, now: Instant) {
        self.last = Some(now);
    }

    /// The next budget-sized batch of keys, rotating through `sorted`
    /// across passes so every entry is eventually visited even when the
    /// budget is smaller than the store.
    pub fn next_batch(&mut self, sorted: &[u64]) -> Vec<u64> {
        if sorted.is_empty() {
            return Vec::new();
        }
        let n = sorted.len();
        let take = self.budget.min(n);
        let start = (self.cursor as usize) % n;
        let batch: Vec<u64> = (0..take).map(|i| sorted[(start + i) % n]).collect();
        self.cursor = self.cursor.wrapping_add(take as u64);
        batch
    }

    pub fn budget(&self) -> usize {
        self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn first_pass_due_then_deadline_gates() {
        let mut m = Maintainer::new(10.0, 4);
        let t0 = Instant::now();
        assert!(m.due(t0), "fresh maintainer scrubs immediately");
        m.begin(t0);
        assert!(!m.due(t0 + Duration::from_secs(5)));
        assert!(m.due(t0 + Duration::from_secs(10)));
        // non-positive interval: always due
        let mut eager = Maintainer::new(0.0, 1);
        eager.begin(t0);
        assert!(eager.due(t0));
    }

    #[test]
    fn budget_rotates_through_all_keys() {
        let mut m = Maintainer::new(1.0, 2);
        let keys = [10u64, 20, 30];
        let mut seen = Vec::new();
        for _ in 0..3 {
            seen.extend(m.next_batch(&keys));
        }
        // 3 passes x budget 2 = 6 visits, each key exactly twice
        for k in keys {
            assert_eq!(seen.iter().filter(|&&x| x == k).count(), 2, "key {k}");
        }
        // budget larger than the store clamps, not wraps-duplicates
        let mut big = Maintainer::new(1.0, 16);
        assert_eq!(big.next_batch(&keys), vec![10, 20, 30]);
        assert!(big.next_batch(&[]).is_empty());
    }

    #[test]
    fn corruption_site_json_roundtrip() {
        let site = CorruptionSite {
            entry: 0xdead_beef_dead_beef,
            layer: 3,
            group: 7,
            offset: 123_456,
            detail: "checksum mismatch".to_string(),
            at: 42,
        };
        let back = CorruptionSite::from_json(&site.to_json()).unwrap();
        assert_eq!(back, site);
        // entry keys above 2^53 survive (hex string, not an f64 number)
        assert!(site.entry > (1u64 << 53));
    }
}
