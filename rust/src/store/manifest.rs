//! Versioned, atomically-persisted manifest for the KV store.
//!
//! The manifest is the store's single source of truth: which prompts are
//! stored, which disk slot each occupies, the per-record checksums that
//! re-arm the [`IntegrityMap`] on reopen, and the persisted corruption
//! log. It is rewritten in full on every mutation via the classic
//! temp-file + `sync_all` + `rename` dance, so a crash at any byte
//! leaves either the old manifest or the new one — never a torn file.
//! Conversely, a leftover `manifest.json.tmp` on open is *by definition*
//! an unpublished partial write and is discarded.
//!
//! Loading is lenient where it must be (an unreadable or mismatched
//! manifest starts the store clean rather than wedging the engine) and
//! strict where it matters (entry keys are **recomputed** from the
//! stored tokens, never trusted from the file; geometry must match the
//! engine's [`DiskLayout`] exactly or every slot arithmetic would lie).
//!
//! [`IntegrityMap`]: crate::disk::IntegrityMap

use std::collections::HashMap;
use std::io::Write as _;
use std::path::Path;

use super::index::chain_hash;
use super::maintain::CorruptionSite;
use crate::kvcache::DiskLayout;
use crate::util::json::Json;

pub const MANIFEST_VERSION: u64 = 1;
pub const MANIFEST_FILE: &str = "manifest.json";
pub const MANIFEST_TMP: &str = "manifest.json.tmp";
/// Backing data file living next to the manifest in the store dir.
pub const DATA_FILE: &str = "store.bin";

/// One stored prompt: its tokens (always a whole number of groups), the
/// disk slot its records occupy, and the write-time checksum of every
/// record, layer-major (`layer * n_groups + group`).
#[derive(Debug, Clone, PartialEq)]
pub struct StoreEntry {
    pub tokens: Vec<i32>,
    pub slot: usize,
    pub last_used: u64,
    pub checksums: Vec<u64>,
}

impl StoreEntry {
    pub fn n_groups(&self, group: usize) -> usize {
        self.tokens.len() / group
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct StoreManifest {
    pub version: u64,
    /// Geometry fingerprint — must equal the engine layout on open.
    pub hd: usize,
    pub group: usize,
    pub n_layers: usize,
    pub page_align: usize,
    /// LRU logical clock high-water mark (see `evict::Lru`).
    pub clock: u64,
    /// entry key (= `chain_hash(tokens)`) → entry.
    pub entries: HashMap<u64, StoreEntry>,
    /// Confirmed-bad records, persisted for post-mortem.
    pub corruption_log: Vec<CorruptionSite>,
}

impl StoreManifest {
    pub fn new(layout: &DiskLayout) -> StoreManifest {
        StoreManifest {
            version: MANIFEST_VERSION,
            hd: layout.hd,
            group: layout.group,
            n_layers: layout.n_layers,
            page_align: layout.page_align,
            clock: 0,
            entries: HashMap::new(),
            corruption_log: Vec::new(),
        }
    }

    /// Whether the persisted geometry matches the engine's layout. A
    /// mismatch (model change, layout refactor) invalidates every slot
    /// offset and checksum, so the caller must start clean.
    pub fn matches(&self, layout: &DiskLayout) -> bool {
        self.hd == layout.hd
            && self.group == layout.group
            && self.n_layers == layout.n_layers
            && self.page_align == layout.page_align
    }

    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(&u64, &StoreEntry)> = self.entries.iter().collect();
        entries.sort_by_key(|e| *e.0); // stable output for diffing
        let entries = entries
            .into_iter()
            .map(|(&key, e)| {
                Json::from_pairs(vec![
                    // debugging aid only; load recomputes from tokens
                    ("hash", format!("{key:016x}").into()),
                    ("slot", e.slot.into()),
                    ("last_used", (e.last_used as usize).into()),
                    (
                        "tokens",
                        Json::Arr(e.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                    ),
                    (
                        // hex: record checksums use the full u64 range,
                        // which a JSON (f64) number cannot hold exactly
                        "checksums",
                        Json::Arr(
                            e.checksums
                                .iter()
                                .map(|&c| format!("{c:016x}").into())
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("version", (self.version as usize).into()),
            (
                "geometry",
                Json::from_pairs(vec![
                    ("hd", self.hd.into()),
                    ("group", self.group.into()),
                    ("n_layers", self.n_layers.into()),
                    ("page_align", self.page_align.into()),
                ]),
            ),
            ("clock", (self.clock as usize).into()),
            ("entries", Json::Arr(entries)),
            (
                "corruption_log",
                Json::Arr(self.corruption_log.iter().map(|s| s.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<StoreManifest> {
        let geo = j
            .get("geometry")
            .ok_or_else(|| anyhow::anyhow!("manifest: missing geometry"))?;
        let group = geo.usize_or("group", 0);
        anyhow::ensure!(group > 0, "manifest: geometry.group must be positive");
        let mut entries = HashMap::new();
        for ej in j.get("entries").and_then(|e| e.as_arr()).unwrap_or(&[]) {
            let tokens_j = ej
                .get("tokens")
                .and_then(|t| t.as_arr())
                .ok_or_else(|| anyhow::anyhow!("manifest entry: missing tokens"))?;
            let mut tokens = Vec::with_capacity(tokens_j.len());
            for t in tokens_j {
                let n = t
                    .as_i64()
                    .ok_or_else(|| anyhow::anyhow!("manifest entry: non-integer token"))?;
                tokens.push(n as i32);
            }
            let mut checksums = Vec::new();
            for c in ej.get("checksums").and_then(|c| c.as_arr()).unwrap_or(&[]) {
                let hex = c
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("manifest entry: checksum not a hex string"))?;
                checksums.push(
                    u64::from_str_radix(hex, 16)
                        .map_err(|e| anyhow::anyhow!("manifest entry: bad checksum hex: {e}"))?,
                );
            }
            // the key is derived, not trusted: a tampered or bit-rotted
            // "hash" field cannot alias one prompt's KV onto another
            let key = chain_hash(&tokens);
            let entry = StoreEntry {
                tokens,
                slot: ej.usize_or("slot", 0),
                last_used: ej.usize_or("last_used", 0) as u64,
                checksums,
            };
            anyhow::ensure!(
                entries.insert(key, entry).is_none(),
                "manifest: duplicate entry for key {key:016x}"
            );
        }
        let mut corruption_log = Vec::new();
        for sj in j
            .get("corruption_log")
            .and_then(|c| c.as_arr())
            .unwrap_or(&[])
        {
            corruption_log.push(CorruptionSite::from_json(sj)?);
        }
        Ok(StoreManifest {
            version: j.usize_or("version", 0) as u64,
            hd: geo.usize_or("hd", 0),
            group,
            n_layers: geo.usize_or("n_layers", 0),
            page_align: geo.usize_or("page_align", 0),
            clock: j.usize_or("clock", 0) as u64,
            entries,
            corruption_log,
        })
    }

    /// Atomically publish the manifest into `dir`: write the temp file,
    /// fsync it, then rename over the live file. A crash anywhere in the
    /// sequence leaves a consistent manifest on disk.
    pub fn persist(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join(MANIFEST_TMP);
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(self.to_json().to_string_pretty().as_bytes())?;
            f.write_all(b"\n")?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, dir.join(MANIFEST_FILE))?;
        Ok(())
    }

    /// Load the manifest from `dir`, or a clean one when `dir` holds
    /// nothing usable. Leftover temp files (crash mid-persist) are
    /// discarded first — their contents were never published.
    pub fn load(dir: &Path, layout: &DiskLayout) -> StoreManifest {
        let tmp = dir.join(MANIFEST_TMP);
        if tmp.exists() {
            crate::log_info!("store: discarding partial manifest write {}", tmp.display());
            let _ = std::fs::remove_file(&tmp);
        }
        let path = dir.join(MANIFEST_FILE);
        let Ok(src) = std::fs::read_to_string(&path) else {
            return StoreManifest::new(layout);
        };
        let parsed = Json::parse(&src)
            .ok()
            .and_then(|j| StoreManifest::from_json(&j).ok());
        match parsed {
            Some(m) if m.version == MANIFEST_VERSION && m.matches(layout) => m,
            Some(_) => {
                crate::log_info!("store: manifest version/geometry mismatch; starting clean");
                StoreManifest::new(layout)
            }
            None => {
                crate::log_info!("store: unreadable manifest; starting clean");
                StoreManifest::new(layout)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> DiskLayout {
        DiskLayout::new(8, 4, 64, 2, 0)
    }

    fn sample(layout: &DiskLayout) -> StoreManifest {
        let mut m = StoreManifest::new(layout);
        let tokens: Vec<i32> = (0..8).collect();
        m.clock = 9;
        m.entries.insert(
            chain_hash(&tokens),
            StoreEntry {
                tokens,
                slot: 2,
                last_used: 9,
                checksums: vec![u64::MAX - 1, 0xfeed_f00d_dead_beef, 3, 4],
            },
        );
        m.corruption_log.push(CorruptionSite {
            entry: 0xabcd,
            layer: 1,
            group: 0,
            offset: 256,
            detail: "io".into(),
            at: 5,
        });
        m
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let m = sample(&layout());
        let back = StoreManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // checksums near u64::MAX survive the hex path bit-exactly
        let e = back.entries.values().next().unwrap();
        assert_eq!(e.checksums[0], u64::MAX - 1);
    }

    #[test]
    fn persist_load_atomicity() {
        let dir = std::env::temp_dir().join(format!("kvswap-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let la = layout();
        let m = sample(&la);
        m.persist(&dir).unwrap();
        assert_eq!(StoreManifest::load(&dir, &la), m);
        assert!(!dir.join(MANIFEST_TMP).exists());

        // leftover temp file = crash mid-persist: discarded, live intact
        std::fs::write(dir.join(MANIFEST_TMP), b"{\"version\": 1, \"entr").unwrap();
        assert_eq!(StoreManifest::load(&dir, &la), m);
        assert!(!dir.join(MANIFEST_TMP).exists());

        // garbage live manifest: start clean, don't panic
        std::fs::write(dir.join(MANIFEST_FILE), b"not json at all").unwrap();
        assert!(StoreManifest::load(&dir, &la).entries.is_empty());

        // geometry mismatch: start clean
        m.persist(&dir).unwrap();
        let other = DiskLayout::new(16, 4, 64, 2, 0);
        assert!(StoreManifest::load(&dir, &other).entries.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
