//! Token-prefix hash-chain index for the persistent KV store.
//!
//! The store keys entries by an incremental FNV-1a hash over the prompt
//! tokens (the *chain hash*). Because K/V rows at position `t` depend
//! only on tokens `<= t`, a stored entry of `N` tokens can serve any
//! request that shares a group-aligned prefix with it — so the index
//! registers the chain hash at **every full-group boundary** of each
//! entry, and a lookup walks the request's own boundary hashes from the
//! longest down until one is registered.
//!
//! Hashes are 64-bit and non-cryptographic, so the index only *nominates*
//! candidates; the store confirms each one by comparing the actual token
//! prefix before restoring bytes (a collision must never replay someone
//! else's KV).

use std::collections::HashMap;

/// Incremental FNV-1a over token little-endian bytes. Feeding tokens one
/// at a time yields exactly `fnv1a64(concat(token.to_le_bytes()))`, so a
/// lookup can hash the request prompt once, capturing the running state
/// at every group boundary for free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainHasher {
    state: u64,
}

impl Default for ChainHasher {
    fn default() -> ChainHasher {
        ChainHasher::new()
    }
}

impl ChainHasher {
    pub fn new() -> ChainHasher {
        ChainHasher {
            state: 0xcbf2_9ce4_8422_2325,
        }
    }

    pub fn push(&mut self, token: i32) {
        for b in token.to_le_bytes() {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// Chain hash of a whole token slice (the store's entry key).
pub fn chain_hash(tokens: &[i32]) -> u64 {
    let mut h = ChainHasher::new();
    for &t in tokens {
        h.push(t);
    }
    h.finish()
}

/// boundary hash → entries whose prefix reaches that boundary, as
/// `(entry_key, prefix_len)` pairs.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    by_boundary: HashMap<u64, Vec<(u64, usize)>>,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Register `entry` (keyed by `key = chain_hash(tokens)`) under the
    /// chain hash of every full-group boundary of `tokens`.
    pub fn insert(&mut self, key: u64, tokens: &[i32], group: usize) {
        assert!(group > 0, "group size must be positive");
        let mut h = ChainHasher::new();
        for (i, &t) in tokens.iter().enumerate() {
            h.push(t);
            if (i + 1) % group == 0 {
                self.by_boundary
                    .entry(h.finish())
                    .or_default()
                    .push((key, i + 1));
            }
        }
    }

    /// Remove every boundary registration of `entry` (mirror of
    /// [`PrefixIndex::insert`] — must be called with the same tokens).
    pub fn remove(&mut self, key: u64, tokens: &[i32], group: usize) {
        let mut h = ChainHasher::new();
        for (i, &t) in tokens.iter().enumerate() {
            h.push(t);
            if (i + 1) % group == 0 {
                let boundary = h.finish();
                if let Some(v) = self.by_boundary.get_mut(&boundary) {
                    v.retain(|&(k, l)| !(k == key && l == i + 1));
                    if v.is_empty() {
                        self.by_boundary.remove(&boundary);
                    }
                }
            }
        }
    }

    /// Candidate `(entry_key, prefix_len)` pairs for the longest stored
    /// group-aligned prefix of `tokens`, longest first. The caller must
    /// confirm each candidate against the entry's actual tokens.
    pub fn candidates(&self, tokens: &[i32], group: usize) -> Vec<(u64, usize)> {
        assert!(group > 0, "group size must be positive");
        let mut boundaries = Vec::new();
        let mut h = ChainHasher::new();
        for (i, &t) in tokens.iter().enumerate() {
            h.push(t);
            if (i + 1) % group == 0 {
                boundaries.push((h.finish(), i + 1));
            }
        }
        let mut out = Vec::new();
        for &(boundary, len) in boundaries.iter().rev() {
            if let Some(v) = self.by_boundary.get(&boundary) {
                out.extend(v.iter().filter(|&&(_, l)| l == len).map(|&(k, _)| (k, len)));
            }
        }
        out
    }

    /// Number of registered boundaries (diagnostics/tests).
    pub fn len(&self) -> usize {
        self.by_boundary.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.by_boundary.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::disk::fnv1a64;

    #[test]
    fn chain_hash_matches_flat_fnv_over_le_bytes() {
        let tokens = [3i32, -7, 65536, 0];
        let mut flat = Vec::new();
        for t in tokens {
            flat.extend_from_slice(&t.to_le_bytes());
        }
        assert_eq!(chain_hash(&tokens), fnv1a64(&flat));
        // incremental == one-shot
        let mut h = ChainHasher::new();
        for t in tokens {
            h.push(t);
        }
        assert_eq!(h.finish(), chain_hash(&tokens));
    }

    #[test]
    fn longest_boundary_match_wins() {
        let mut idx = PrefixIndex::new();
        let stored: Vec<i32> = (0..16).collect();
        let key = chain_hash(&stored);
        idx.insert(key, &stored, 4);
        assert_eq!(idx.len(), 4); // boundaries at 4, 8, 12, 16

        // identical prompt: full-length candidate first
        let c = idx.candidates(&stored, 4);
        assert_eq!(c.first(), Some(&(key, 16)));

        // diverges after 8 tokens: best candidate is the 8-boundary
        let mut fork = stored.clone();
        fork[9] = 99;
        let c = idx.candidates(&fork, 4);
        assert_eq!(c.first(), Some(&(key, 8)));

        // longer prompt sharing the whole entry: capped at entry length
        let mut long: Vec<i32> = stored.clone();
        long.extend(100..108);
        let c = idx.candidates(&long, 4);
        assert_eq!(c.first(), Some(&(key, 16)));

        // disjoint prompt: nothing
        let other: Vec<i32> = (100..116).collect();
        assert!(idx.candidates(&other, 4).is_empty());
    }

    #[test]
    fn remove_unregisters_all_boundaries() {
        let mut idx = PrefixIndex::new();
        let a: Vec<i32> = (0..8).collect();
        let b: Vec<i32> = (0..12).collect(); // shares a's boundaries at 4 and 8
        idx.insert(chain_hash(&a), &a, 4);
        idx.insert(chain_hash(&b), &b, 4);
        assert_eq!(idx.len(), 5);
        idx.remove(chain_hash(&a), &a, 4);
        assert_eq!(idx.len(), 3);
        // b still resolves through the shared boundaries
        let c = idx.candidates(&a, 4);
        assert_eq!(c.first(), Some(&(chain_hash(&b), 8)));
        idx.remove(chain_hash(&b), &b, 4);
        assert!(idx.is_empty());
    }
}
