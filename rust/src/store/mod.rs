//! Persistent KV store: cross-request, cross-restart prefix reuse.
//!
//! KVSwap keeps the *working* KV cache on disk but it still dies with
//! the process; every request re-runs prefill even when its prompt
//! shares a long prefix with earlier traffic. This subsystem persists
//! prefill results keyed by token-prefix hash chains so a later request
//! — in this process or the next — restores the shared prefix from disk
//! and starts prefill at the divergence point. Warm restores are
//! **bit-identical** to recompute: records are raw f32 little-endian
//! group encodings, exactly what the engine would have written.
//!
//! The pieces:
//! - [`manifest`] — versioned, atomically-persisted source of truth
//!   (temp + fsync + rename; leftover temp files are discarded as
//!   unpublished partial writes);
//! - [`index`] — boundary hash-chain index nominating the longest
//!   stored group-aligned prefix, confirmed against actual tokens;
//! - [`evict`] — capacity-bounded LRU with pinning for in-flight
//!   restores;
//! - [`maintain`] — deadline/idle-budget scrub scheduler with a
//!   persisted corruption log.
//!
//! ## Scheduler lanes
//!
//! When [`PersistentStore::attach_scheduler`] wires the store to the
//! engine's unified [`IoScheduler`](crate::disk::IoScheduler), its two
//! read streams route through priority lanes instead of hitting the
//! device directly: pipelined warm restores submit as `Warm`
//! ([`PersistentStore::submit_chunk`] / `complete_chunk`), and scrub
//! verification reads submit as `Background` — so maintenance queues
//! behind decode-critical preloads and only runs when aged past the
//! starvation bound, never by preempting them. Unattached (standalone
//! stores, tests), both paths fall back to direct device reads with
//! identical semantics.
//!
//! ## Compaction
//!
//! Eviction and quarantine free *slots* but never shrink the data file;
//! a long-lived store churns toward a file full of holes. When the
//! freed-slot fraction exceeds `StoreConfig::compact_free_frac` after a
//! scrub pass, `maintain()` rewrites live records contiguously into the
//! lowest slots and truncates the tail
//! ([`PersistentStore::compact_now`]). The move is crash-safe through
//! the same manifest commit point as every other mutation: bytes move
//! first, the remapped manifest publishes via temp+fsync+rename, then
//! the file is cut — a crash in between leaves checksummed-detectable
//! (never silently wrong) stale entries.
//!
//! ## Failure model & degradation ladder
//!
//! Mirrors the disk pipeline (`disk/mod.rs`), adapted to data that must
//! outlive the process:
//!
//! 1. **Detect** — every record's FNV-1a checksum is persisted in the
//!    manifest and re-armed into the store's [`IntegrityMap`] on open,
//!    so bytes that rotted *while the process was down* still fail
//!    verification on first read. Entry keys are recomputed from
//!    tokens, never trusted from the file.
//! 2. **Retry** — a failed record read (restore or scrub) is re-issued
//!    once: transient device faults heal; deterministic corruption
//!    does not.
//! 3. **Contain** — a record that stays bad quarantines its whole entry
//!    (removed from index + LRU, slot recycled) and appends a
//!    [`CorruptionSite`](maintain::CorruptionSite) to the manifest's
//!    persisted corruption log for post-mortem. One poisoned prompt
//!    never blocks the store.
//! 4. **Degrade** — a failed restore falls back to recompute, and the
//!    fallback is *chunk-granular*: the pipelined warm-start path
//!    ([`PersistentStore::restore_chunk`]) streams `(layer, chunk)`
//!    units into prefill, so a torn record only discards the warm
//!    region from that chunk onward — prefill recomputes from the tear
//!    instead of throwing away every chunk restored before it. A fully
//!    blocking restore that fails degrades to cold prefill (correctness
//!    never depends on the store); a failed save logs and skips (the
//!    store is an accelerator, not a durability contract); an
//!    over-capacity save with everything pinned skips rather than
//!    evicting under a reader.
//!
//! [`IntegrityMap`]: crate::disk::IntegrityMap

pub mod evict;
pub mod index;
pub mod maintain;
pub mod manifest;

use std::path::PathBuf;
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::config::{FaultConfig, StoreConfig};
use crate::disk::prefetch::PrefetchCounters;
use crate::disk::{
    relock, Backend, DiskError, DiskProfile, DiskSnapshot, FaultBackend, FileBackend, IoRequest,
    IoScheduler, Lane, MemBackend, SimDisk, Ticket,
};
use crate::kvcache::DiskLayout;
use crate::util::json::Json;

pub use evict::Lru;
pub use index::{chain_hash, ChainHasher, PrefixIndex};
pub use maintain::{CorruptionSite, Maintainer, ScrubReport};
pub use manifest::{StoreEntry, StoreManifest, DATA_FILE, MANIFEST_FILE, MANIFEST_TMP};

/// One restored `(layer, token-range)` slice of a stored entry — the
/// unit the pipelined warm-start path streams into prefill while
/// compute runs.
#[derive(Debug, Clone)]
pub struct RestoredChunk {
    pub layer: usize,
    /// First token of the range (group-aligned).
    pub start: usize,
    pub tokens: usize,
    /// Token-major flat rows, `tokens * hd` floats each — bit-identical
    /// to what was saved.
    pub k_rows: Vec<f32>,
    pub v_rows: Vec<f32>,
    /// Modeled device time of the records read for this slice; the
    /// engine charges only the residual that compute failed to hide.
    pub io_time: Duration,
}

/// An in-flight `Warm`-lane restore chunk: the scheduler ticket plus the
/// geometry needed to decode the staged records (and to attribute a
/// corruption site if the read ultimately fails). Redeem with
/// [`PersistentStore::complete_chunk`].
pub struct ChunkTicket {
    sched: Arc<IoScheduler>,
    ticket: Ticket,
    entry: u64,
    slot: usize,
    layer: usize,
    start: usize,
    tokens: usize,
}

/// A confirmed stored prefix for an incoming prompt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixMatch {
    /// Entry key (pass to [`PersistentStore::pin`] /
    /// [`PersistentStore::unpin`] around the restore).
    pub entry: u64,
    /// Number of prompt tokens covered (a multiple of the group size).
    pub tokens: usize,
}

/// Monotonic event counters, surfaced over the serve `stats` line.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StoreCounters {
    pub hits: u64,
    pub misses: u64,
    pub restored_tokens: u64,
    pub saves: u64,
    pub save_skips: u64,
    /// Serving-batch padding rows whose save was skipped outright
    /// (all-zero filler must never pollute the store).
    pub pad_skips: u64,
    pub evictions: u64,
    pub corruptions: u64,
    pub healed: u64,
    pub quarantined: u64,
    pub scrub_passes: u64,
    pub records_scrubbed: u64,
    /// Data-file compactions run by `maintain()` (live records rewritten
    /// contiguously, tail truncated).
    pub compactions: u64,
    /// Bytes cut off the data file by compaction, cumulative.
    pub reclaimed_bytes: u64,
}

impl StoreCounters {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("hits", (self.hits as usize).into()),
            ("misses", (self.misses as usize).into()),
            ("restored_tokens", (self.restored_tokens as usize).into()),
            ("saves", (self.saves as usize).into()),
            ("save_skips", (self.save_skips as usize).into()),
            ("pad_skips", (self.pad_skips as usize).into()),
            ("evictions", (self.evictions as usize).into()),
            ("corruptions", (self.corruptions as usize).into()),
            ("healed", (self.healed as usize).into()),
            ("quarantined", (self.quarantined as usize).into()),
            ("scrub_passes", (self.scrub_passes as usize).into()),
            ("records_scrubbed", (self.records_scrubbed as usize).into()),
            ("compactions", (self.compactions as usize).into()),
            ("reclaimed_bytes", (self.reclaimed_bytes as usize).into()),
        ])
    }
}

struct Inner {
    manifest: StoreManifest,
    index: PrefixIndex,
    lru: Lru,
    free_slots: Vec<usize>,
    next_slot: usize,
    stored_bytes: u64,
    maintainer: Maintainer,
    counters: StoreCounters,
}

/// The store proper: one backing device (its own [`SimDisk`], distinct
/// from the engine's working cache), the geometry shared with the
/// engine, and mutex-guarded book-keeping. Thread-safe so the router can
/// share one instance across engine waves and run maintenance on idle
/// ticks.
pub struct PersistentStore {
    disk: Arc<SimDisk>,
    layout: DiskLayout,
    dir: Option<PathBuf>,
    capacity_bytes: u64,
    /// Freed-slot fraction above which `maintain()` compacts the data
    /// file (`>= 1.0` disables).
    compact_free_frac: f64,
    /// Shared I/O scheduler, when attached: restore chunks go out on the
    /// `Warm` lane and scrub reads on `Background` instead of hitting the
    /// device directly. `Weak` because the engine owns the scheduler.
    sched: Mutex<Option<Weak<IoScheduler>>>,
    /// Client counter block for scheduler submissions (the store's
    /// staging traffic, kept apart from the decode prefetcher's).
    io_counters: Arc<PrefetchCounters>,
    inner: Mutex<Inner>,
}

impl PersistentStore {
    /// Open (or create) the store described by `cfg`. With a directory,
    /// records live in `dir/store.bin` next to `dir/manifest.json`;
    /// without one the store is memory-backed (reuse within the process
    /// only). The fault profile is inherited from the engine so injected
    /// campaigns also exercise the persistence path.
    pub fn open(
        cfg: &StoreConfig,
        profile: DiskProfile,
        fault: &FaultConfig,
        layout: DiskLayout,
    ) -> anyhow::Result<PersistentStore> {
        let backend: Arc<dyn Backend> = match &cfg.dir {
            Some(dir) => {
                std::fs::create_dir_all(dir)?;
                Arc::new(FileBackend::open(dir.join(DATA_FILE))?)
            }
            None => Arc::new(MemBackend::new()),
        };
        let backend: Arc<dyn Backend> = if fault.enabled() {
            // decorrelate from the engine disk's fault stream
            let mut fcfg = fault.clone();
            fcfg.seed ^= 0x5704_E5E5;
            Arc::new(FaultBackend::new(backend, fcfg))
        } else {
            backend
        };
        Self::open_with_backend(cfg, profile, layout, backend)
    }

    /// Open over an explicit backend (tests inject `FaultBackend` or a
    /// shared `MemBackend` here). `cfg.dir` still controls where the
    /// manifest lives.
    pub fn open_with_backend(
        cfg: &StoreConfig,
        profile: DiskProfile,
        layout: DiskLayout,
        backend: Arc<dyn Backend>,
    ) -> anyhow::Result<PersistentStore> {
        anyhow::ensure!(cfg.capacity_bytes > 0, "store capacity must be positive");
        // the store paces nothing: restores are timed by the engine's
        // prefill clock, and scrubs run on idle budget
        let disk = Arc::new(SimDisk::new(profile, backend, None));
        let mut manifest = match &cfg.dir {
            Some(dir) => StoreManifest::load(dir, &layout),
            None => StoreManifest::new(&layout),
        };

        // Validate entries against the layout and the actual data-file
        // length; drop anything inconsistent (a clean miss beats a panic
        // deep in slot arithmetic).
        let disk_len = disk.len();
        let group = layout.group;
        let mut dropped = 0usize;
        manifest.entries.retain(|key, e| {
            let n_groups = e.tokens.len() / group;
            let ok = !e.tokens.is_empty()
                && e.tokens.len() % group == 0
                && n_groups <= layout.max_groups
                && e.checksums.len() == layout.n_layers * n_groups
                && layout.offset(e.slot, layout.n_layers - 1, n_groups - 1)
                    + layout.group_stride()
                    <= disk_len;
            if !ok {
                crate::log_info!("store: dropping inconsistent entry {key:016x}");
                dropped += 1;
            }
            ok
        });

        // Re-arm integrity from the persisted checksums so the first
        // read of every record verifies against its historical write.
        let payload = layout.group_payload_bytes() as usize;
        let mut index = PrefixIndex::new();
        let mut lru = Lru::new();
        let mut stored_bytes = 0u64;
        let mut used_slots: Vec<usize> = Vec::new();
        for (&key, e) in &manifest.entries {
            let n_groups = e.n_groups(group);
            for layer in 0..layout.n_layers {
                for gi in 0..n_groups {
                    disk.integrity().stamp_sum(
                        layout.offset(e.slot, layer, gi),
                        payload,
                        e.checksums[layer * n_groups + gi],
                    );
                }
            }
            index.insert(key, &e.tokens, group);
            lru.restore(key, e.last_used);
            stored_bytes += entry_bytes(&layout, n_groups);
            used_slots.push(e.slot);
        }
        lru.restore_clock(manifest.clock);
        used_slots.sort_unstable();
        let next_slot = used_slots.last().map_or(0, |&s| s + 1);
        let free_slots: Vec<usize> = (0..next_slot)
            .filter(|s| used_slots.binary_search(s).is_err())
            .collect();

        let store = PersistentStore {
            disk,
            layout,
            dir: cfg.dir.clone(),
            capacity_bytes: cfg.capacity_bytes,
            compact_free_frac: cfg.compact_free_frac,
            sched: Mutex::new(None),
            io_counters: Arc::new(PrefetchCounters::default()),
            inner: Mutex::new(Inner {
                manifest,
                index,
                lru,
                free_slots,
                next_slot,
                stored_bytes,
                maintainer: Maintainer::new(cfg.scrub_interval_s, cfg.scrub_budget),
                counters: StoreCounters::default(),
            }),
        };
        if dropped > 0 {
            let inner = relock(&store.inner);
            let _ = store.persist_locked(&inner);
        }
        Ok(store)
    }

    /// Longest stored group-aligned prefix of `tokens`, confirmed
    /// token-by-token (hashes only nominate). Counts a hit or miss and
    /// freshens the entry's recency.
    pub fn lookup(&self, tokens: &[i32]) -> Option<PrefixMatch> {
        let mut inner = relock(&self.inner);
        let cands = inner.index.candidates(tokens, self.layout.group);
        for (key, len) in cands {
            let confirmed = inner
                .manifest
                .entries
                .get(&key)
                .is_some_and(|e| e.tokens.len() >= len && e.tokens[..len] == tokens[..len]);
            if confirmed {
                let t = inner.lru.touch(key);
                inner.manifest.clock = t;
                if let Some(e) = inner.manifest.entries.get_mut(&key) {
                    e.last_used = t;
                }
                inner.counters.hits += 1;
                return Some(PrefixMatch { entry: key, tokens: len });
            }
        }
        inner.counters.misses += 1;
        None
    }

    /// Pin `entry` against eviction for the duration of a restore+save
    /// window. Pins are counted; every `pin` needs a matching `unpin`.
    pub fn pin(&self, entry: u64) {
        relock(&self.inner).lru.pin(entry);
    }

    pub fn unpin(&self, entry: u64) {
        relock(&self.inner).lru.unpin(entry);
    }

    /// Read back the first `n_tokens` (multiple of the group size) of a
    /// matched entry as per-layer `(k_rows, v_rows)` — bit-identical to
    /// what was saved. A record that fails after one retry records a
    /// corruption site and errors; the caller falls back to cold
    /// prefill.
    pub fn restore(
        &self,
        m: &PrefixMatch,
        n_tokens: usize,
    ) -> anyhow::Result<Vec<(Vec<f32>, Vec<f32>)>> {
        let g = self.layout.group;
        anyhow::ensure!(
            n_tokens > 0 && n_tokens % g == 0 && n_tokens <= m.tokens,
            "restore length {n_tokens} not a group multiple within the match"
        );
        let mut out = Vec::with_capacity(self.layout.n_layers);
        for layer in 0..self.layout.n_layers {
            let c = self.restore_chunk(m, layer, 0, n_tokens)?;
            out.push((c.k_rows, c.v_rows));
        }
        self.credit_restored(n_tokens);
        Ok(out)
    }

    /// Read back tokens `[start, start + n_tokens)` of one layer of a
    /// matched entry — the incremental unit of a pipelined restore. The
    /// range must be group-aligned and inside the match. Every record
    /// gets the same verify/retry ladder as a full restore; a record
    /// that stays bad records a corruption site and errors, and the
    /// caller degrades at *chunk* granularity (recompute from this
    /// chunk onward, keeping everything restored before it).
    ///
    /// Does **not** bump `restored_tokens`: pipelined callers call
    /// [`credit_restored`](Self::credit_restored) once with what
    /// actually survived into the committed warm region.
    pub fn restore_chunk(
        &self,
        m: &PrefixMatch,
        layer: usize,
        start: usize,
        n_tokens: usize,
    ) -> anyhow::Result<RestoredChunk> {
        let g = self.layout.group;
        anyhow::ensure!(
            layer < self.layout.n_layers,
            "restore layer {layer} out of range"
        );
        anyhow::ensure!(
            n_tokens > 0 && start % g == 0 && n_tokens % g == 0 && start + n_tokens <= m.tokens,
            "restore range [{start}, {}) not group-aligned within the match",
            start + n_tokens
        );
        let slot = {
            let inner = relock(&self.inner);
            inner
                .manifest
                .entries
                .get(&m.entry)
                .map(|e| e.slot)
                .ok_or_else(|| anyhow::anyhow!("store entry {:016x} vanished", m.entry))?
        };
        let payload = self.layout.group_payload_bytes() as usize;
        let hd = self.layout.hd;
        let mut k_rows = Vec::with_capacity(n_tokens * hd);
        let mut v_rows = Vec::with_capacity(n_tokens * hd);
        let mut io_time = Duration::ZERO;
        for gi in start / g..(start + n_tokens) / g {
            let off = self.layout.offset(slot, layer, gi);
            let mut buf = vec![0u8; payload];
            match self.read_record(off, &mut buf) {
                Ok(d) => io_time += d,
                Err(e) => {
                    if matches!(e, DiskError::Corrupt { .. }) {
                        self.record_corruption(m.entry, layer, gi, off, &e);
                    }
                    return Err(anyhow::anyhow!(
                        "store restore failed at entry {:016x} layer {layer} group {gi}: {e}",
                        m.entry
                    ));
                }
            }
            let (k, v) = self.layout.decode_group(&buf);
            k_rows.extend_from_slice(&k);
            v_rows.extend_from_slice(&v);
        }
        Ok(RestoredChunk {
            layer,
            start,
            tokens: n_tokens,
            k_rows,
            v_rows,
            io_time,
        })
    }

    /// Route this store's restore and scrub reads through a shared
    /// [`IoScheduler`]: restore chunks submit on the `Warm` lane (so
    /// adjacent layers' records can merge with other queued plans into
    /// sequential reads) and scrub reads on `Background` (so maintenance
    /// can never delay a decode-critical preload beyond the aging bound).
    /// Held as a `Weak` — when the engine drops the scheduler the store
    /// falls back to direct device reads.
    pub fn attach_scheduler(&self, sched: &Arc<IoScheduler>) {
        *relock(&self.sched) = Some(Arc::downgrade(sched));
    }

    /// Revert to direct device reads. Called when a separate-pools
    /// engine adopts a store that an earlier (unified) engine attached —
    /// a shared store must always route per the *current* engine's mode,
    /// not a predecessor's.
    pub fn detach_scheduler(&self) {
        *relock(&self.sched) = None;
    }

    fn scheduler(&self) -> Option<Arc<IoScheduler>> {
        relock(&self.sched).as_ref().and_then(|w| w.upgrade())
    }

    /// Submit the record reads for one `(layer, token-range)` chunk on
    /// the scheduler's `Warm` lane without waiting. Returns `None` when
    /// no scheduler is attached (or it is shutting down, or the range is
    /// invalid) — the caller then uses [`restore_chunk`](Self::restore_chunk)
    /// directly, which reports the precise error.
    pub fn submit_chunk(
        &self,
        m: &PrefixMatch,
        layer: usize,
        start: usize,
        n_tokens: usize,
    ) -> Option<ChunkTicket> {
        let sched = self.scheduler()?;
        let g = self.layout.group;
        if layer >= self.layout.n_layers
            || n_tokens == 0
            || start % g != 0
            || n_tokens % g != 0
            || start + n_tokens > m.tokens
        {
            return None;
        }
        let slot = relock(&self.inner)
            .manifest
            .entries
            .get(&m.entry)
            .map(|e| e.slot)?;
        let payload = self.layout.group_payload_bytes() as usize;
        let extents: Vec<(u64, usize)> = (start / g..(start + n_tokens) / g)
            .map(|gi| (self.layout.offset(slot, layer, gi), payload))
            .collect();
        let ticket = sched
            .submit(IoRequest {
                lane: Lane::Warm,
                disk: self.disk.clone(),
                extents,
                counters: self.io_counters.clone(),
            })
            .ok()?;
        Some(ChunkTicket {
            sched,
            ticket,
            entry: m.entry,
            slot,
            layer,
            start,
            tokens: n_tokens,
        })
    }

    /// Redeem a [`ChunkTicket`]: block for the staged records and decode
    /// them. Same contract as [`restore_chunk`](Self::restore_chunk) —
    /// bit-identical rows on success; on failure a `Corrupt` outcome
    /// records its corruption site and the caller degrades at chunk
    /// granularity. Does not bump `restored_tokens` (pipelined callers
    /// credit what actually committed).
    pub fn complete_chunk(&self, t: ChunkTicket) -> anyhow::Result<RestoredChunk> {
        let ChunkTicket {
            sched,
            ticket,
            entry,
            slot,
            layer,
            start,
            tokens,
        } = t;
        match sched.wait(ticket, Duration::from_secs(60)) {
            Ok(done) => {
                let hd = self.layout.hd;
                let mut k_rows = Vec::with_capacity(tokens * hd);
                let mut v_rows = Vec::with_capacity(tokens * hd);
                for buf in &done.chunks {
                    let (k, v) = self.layout.decode_group(buf);
                    k_rows.extend_from_slice(&k);
                    v_rows.extend_from_slice(&v);
                }
                Ok(RestoredChunk {
                    layer,
                    start,
                    tokens,
                    k_rows,
                    v_rows,
                    io_time: done.io_time,
                })
            }
            Err(e) => {
                // map the failing offset back to its group index so the
                // corruption site names the exact record
                let g = self.layout.group;
                let gi = match &e {
                    DiskError::Corrupt { offset, .. }
                    | DiskError::Io { offset, .. }
                    | DiskError::OutOfBounds { offset, .. } => (start / g..(start + tokens) / g)
                        .find(|&gi| self.layout.offset(slot, layer, gi) == *offset)
                        .unwrap_or(start / g),
                    _ => start / g,
                };
                if matches!(e, DiskError::Corrupt { .. }) {
                    let off = self.layout.offset(slot, layer, gi);
                    self.record_corruption(entry, layer, gi, off, &e);
                }
                Err(anyhow::anyhow!(
                    "store restore failed at entry {entry:016x} layer {layer} group {gi}: {e}"
                ))
            }
        }
    }

    /// Count `n_tokens` as served from the store. [`restore`](Self::restore)
    /// credits automatically; pipelined callers credit once after the
    /// warm region is actually committed, so a torn, partially-discarded
    /// restore only counts what survived.
    pub fn credit_restored(&self, n_tokens: usize) {
        relock(&self.inner).counters.restored_tokens += n_tokens as u64;
    }

    /// Count a serving-batch padding row whose save was skipped (ragged
    /// waves pad with all-zero rows; those must never reach the store).
    pub fn note_pad_skip(&self) {
        relock(&self.inner).counters.pad_skips += 1;
    }

    /// Snapshot of the store's own device counters (distinct from the
    /// engine's working disk). Prefill overlap accounting reads the
    /// read-busy delta across a warm start.
    pub fn io_snapshot(&self) -> DiskSnapshot {
        self.disk.stats().snapshot()
    }

    /// Persist one prompt's prefill output (per-layer flat `(k, v)` rows,
    /// `tokens.len() * hd` floats each). Partial trailing groups are
    /// floored away. Returns the number of tokens actually stored — `0`
    /// when the save was deduplicated, over capacity with everything
    /// pinned, or too large to ever fit.
    pub fn save(&self, tokens: &[i32], layers: &[(Vec<f32>, Vec<f32>)]) -> anyhow::Result<usize> {
        let g = self.layout.group;
        let hd = self.layout.hd;
        let full = (tokens.len() / g) * g;
        let n_groups = full / g;
        if full == 0 {
            return Ok(0);
        }
        anyhow::ensure!(
            layers.len() == self.layout.n_layers,
            "save: {} layers, layout has {}",
            layers.len(),
            self.layout.n_layers
        );
        anyhow::ensure!(
            n_groups <= self.layout.max_groups,
            "save: {n_groups} groups exceeds layout capacity {}",
            self.layout.max_groups
        );
        for (k_rows, v_rows) in layers {
            anyhow::ensure!(
                k_rows.len() >= full * hd && v_rows.len() >= full * hd,
                "save: layer rows shorter than {full} tokens"
            );
        }
        let key = chain_hash(&tokens[..full]);
        let bytes_new = entry_bytes(&self.layout, n_groups);

        let slot = {
            let mut inner = relock(&self.inner);
            // dedup: exact entry, or an existing entry covering this
            // prefix in full — just freshen the *covering* entry
            let covering = if inner.manifest.entries.contains_key(&key) {
                Some(key)
            } else {
                inner
                    .index
                    .candidates(&tokens[..full], g)
                    .into_iter()
                    .find(|&(k, len)| {
                        len == full
                            && inner
                                .manifest
                                .entries
                                .get(&k)
                                .is_some_and(|e| e.tokens[..len] == tokens[..len])
                    })
                    .map(|(k, _)| k)
            };
            if let Some(k) = covering {
                let t = inner.lru.touch(k);
                inner.manifest.clock = t;
                if let Some(e) = inner.manifest.entries.get_mut(&k) {
                    e.last_used = t;
                }
                inner.counters.save_skips += 1;
                return Ok(0);
            }
            if bytes_new > self.capacity_bytes {
                inner.counters.save_skips += 1;
                return Ok(0);
            }
            while inner.stored_bytes + bytes_new > self.capacity_bytes {
                let Some(victim) = inner.lru.victim() else {
                    // everything pinned: never evict under a reader
                    inner.counters.save_skips += 1;
                    return Ok(0);
                };
                self.evict_locked(&mut inner, victim);
            }
            let s = match inner.free_slots.pop() {
                Some(s) => s,
                None => {
                    let s = inner.next_slot;
                    inner.next_slot += 1;
                    s
                }
            };
            // Reserve the bytes at admission, while the capacity check
            // still holds: the record writes below run lock-free, and a
            // concurrent save must see this claim or racing writers all
            // pass the check and overshoot `capacity_bytes`.
            inner.stored_bytes += bytes_new;
            s
        };

        // write records lock-free (the slot is reserved; nobody else
        // writes it), collecting the manifest checksums as we go
        let mut checksums = Vec::with_capacity(self.layout.n_layers * n_groups);
        for (layer, (k_rows, v_rows)) in layers.iter().enumerate() {
            for gi in 0..n_groups {
                let span = gi * g * hd..(gi + 1) * g * hd;
                let rec = self
                    .layout
                    .encode_group(&k_rows[span.clone()], &v_rows[span]);
                let off = self.layout.offset(slot, layer, gi);
                if let Err(e) = self.disk.write(off, &rec) {
                    let mut inner = relock(&self.inner);
                    inner.free_slots.push(slot);
                    // roll the admission-time reservation back
                    inner.stored_bytes = inner.stored_bytes.saturating_sub(bytes_new);
                    inner.counters.save_skips += 1;
                    return Err(anyhow::anyhow!("store save write failed: {e}"));
                }
                checksums.push(self.layout.record_checksum(&rec));
            }
        }

        let mut inner = relock(&self.inner);
        let t = inner.lru.insert(key);
        inner.manifest.clock = t;
        inner.manifest.entries.insert(
            key,
            StoreEntry {
                tokens: tokens[..full].to_vec(),
                slot,
                last_used: t,
                checksums,
            },
        );
        inner.index.insert(key, &tokens[..full], g);
        // stored_bytes was already charged at admission
        inner.counters.saves += 1;
        self.persist_locked(&inner)?;
        Ok(full)
    }

    /// Idle-tick entry point: runs one budgeted scrub pass when the
    /// deadline has elapsed (else returns `None` immediately), then
    /// compacts the data file if eviction has left enough freed-slot
    /// space behind.
    pub fn maintain(&self, now: Instant) -> Option<ScrubReport> {
        let batch = {
            let mut inner = relock(&self.inner);
            if !inner.maintainer.due(now) {
                return None;
            }
            inner.maintainer.begin(now);
            let mut keys: Vec<u64> = inner.manifest.entries.keys().copied().collect();
            keys.sort_unstable();
            inner.maintainer.next_batch(&keys)
        };
        let rep = self.scrub_entries(&batch);
        self.compact_now();
        Some(rep)
    }

    /// Compact the data file now if the freed-slot fraction exceeds the
    /// configured threshold: live records are rewritten contiguously into
    /// the lowest slots and the tail is truncated. Returns the bytes
    /// reclaimed (`0` = not triggered, pinned readers present, or
    /// disabled).
    ///
    /// Crash safety: record moves happen first, then the manifest's new
    /// slot map commits through the existing temp+fsync+rename path, then
    /// the tail is cut. A crash between a move and the commit leaves the
    /// old manifest pointing entries at partially overwritten slots —
    /// their checksums fail on the next open/read and the entries drop as
    /// detected corruption (a clean miss), never as silently wrong bytes.
    pub fn compact_now(&self) -> u64 {
        let mut inner = relock(&self.inner);
        self.compact_locked(&mut inner)
    }

    fn compact_locked(&self, inner: &mut Inner) -> u64 {
        if self.compact_free_frac >= 1.0 || inner.next_slot == 0 || inner.free_slots.is_empty() {
            return 0;
        }
        let frac = inner.free_slots.len() as f64 / inner.next_slot as f64;
        if frac <= self.compact_free_frac {
            return 0;
        }
        // Never move records under a pinned reader: a restore in flight
        // addresses the old slot lock-free.
        if inner.manifest.entries.keys().any(|k| inner.lru.is_pinned(*k)) {
            return 0;
        }
        let g = self.layout.group;
        let payload = self.layout.group_payload_bytes() as usize;
        // Live entries ascending by slot, each assigned the next dense
        // target slot: target <= source always, so a move never lands on
        // a slot whose live record has not already been copied out.
        let mut order: Vec<(u64, usize, usize)> = inner
            .manifest
            .entries
            .iter()
            .map(|(&k, e)| (k, e.slot, e.n_groups(g)))
            .collect();
        order.sort_unstable_by_key(|&(_, slot, _)| slot);
        let mut target = 0usize;
        let mut end = 0u64;
        let mut bad_reads: Vec<(u64, usize, usize, u64, String)> = Vec::new();
        for &(key, slot, n_groups) in &order {
            let mut ok = true;
            if slot != target {
                'rec: for layer in 0..self.layout.n_layers {
                    for gi in 0..n_groups {
                        let src = self.layout.offset(slot, layer, gi);
                        let mut buf = vec![0u8; payload];
                        // verified read with one heal retry, like scrub
                        let read = self
                            .disk
                            .read(src, &mut buf)
                            .or_else(|_| self.disk.read(src, &mut buf));
                        match read {
                            Ok(_) => {
                                let dst = self.layout.offset(target, layer, gi);
                                if self.disk.write(dst, &buf).is_err() {
                                    ok = false;
                                }
                            }
                            Err(e) => {
                                bad_reads.push((key, layer, gi, src, e.to_string()));
                                ok = false;
                            }
                        }
                        if !ok {
                            break 'rec;
                        }
                    }
                }
            }
            if ok {
                if let Some(e) = inner.manifest.entries.get_mut(&key) {
                    e.slot = target;
                }
                end = end.max(
                    self.layout
                        .offset(target, self.layout.n_layers - 1, n_groups - 1)
                        + self.layout.group_stride(),
                );
                target += 1;
            } else {
                // a record that will not read clean (or a failed rewrite)
                // quarantines its entry rather than aborting the pass
                self.quarantine_locked(inner, key);
            }
        }
        for (entry, layer, group, offset, detail) in bad_reads {
            let at = inner.lru.clock();
            inner.manifest.corruption_log.push(CorruptionSite {
                entry,
                layer,
                group,
                offset,
                detail,
                at,
            });
            inner.counters.corruptions += 1;
        }
        inner.free_slots.clear();
        inner.next_slot = target;
        let reclaimed = self.disk.len().saturating_sub(end);
        // commit the new slot map before cutting the tail
        let _ = self.persist_locked(inner);
        let _ = self.disk.truncate(end);
        inner.counters.compactions += 1;
        inner.counters.reclaimed_bytes += reclaimed;
        crate::log_info!(
            "store: compacted {} live entries, reclaimed {} bytes",
            target,
            reclaimed
        );
        reclaimed
    }

    /// Scrub up to `budget` entries right now, deadline or not (CLI and
    /// tests; pass `usize::MAX` for a full sweep).
    pub fn scrub_now(&self, budget: usize) -> ScrubReport {
        let batch: Vec<u64> = {
            let inner = relock(&self.inner);
            let mut keys: Vec<u64> = inner.manifest.entries.keys().copied().collect();
            keys.sort_unstable();
            keys.truncate(budget);
            keys
        };
        self.scrub_entries(&batch)
    }

    fn scrub_entries(&self, keys: &[u64]) -> ScrubReport {
        let mut rep = ScrubReport::default();
        let g = self.layout.group;
        let payload = self.layout.group_payload_bytes() as usize;
        for &key in keys {
            let Some((slot, n_groups)) = ({
                let inner = relock(&self.inner);
                inner
                    .manifest
                    .entries
                    .get(&key)
                    .map(|e| (e.slot, e.n_groups(g)))
            }) else {
                continue; // evicted between scheduling and scan
            };
            rep.entries_scanned += 1;
            let mut bad: Option<(usize, usize, u64, String)> = None;
            'entry: for layer in 0..self.layout.n_layers {
                for gi in 0..n_groups {
                    let off = self.layout.offset(slot, layer, gi);
                    match self.scrub_read(off, payload) {
                        Ok(_) => rep.records_clean += 1,
                        // one heal attempt, direct: transient faults clear
                        Err(_) => {
                            let mut buf = vec![0u8; payload];
                            match self.disk.read(off, &mut buf) {
                                Ok(_) => {
                                    rep.healed += 1;
                                    rep.records_clean += 1;
                                    relock(&self.inner).counters.healed += 1;
                                }
                                Err(e) => {
                                    bad = Some((layer, gi, off, e.to_string()));
                                    break 'entry;
                                }
                            }
                        }
                    }
                }
            }
            if let Some((layer, gi, off, detail)) = bad {
                rep.corruptions += 1;
                rep.quarantined += 1;
                let mut inner = relock(&self.inner);
                let at = inner.lru.clock();
                inner.manifest.corruption_log.push(CorruptionSite {
                    entry: key,
                    layer,
                    group: gi,
                    offset: off,
                    detail,
                    at,
                });
                inner.counters.corruptions += 1;
                self.quarantine_locked(&mut inner, key);
                let _ = self.persist_locked(&inner);
                crate::log_info!(
                    "store: quarantined entry {key:016x} (layer {layer} group {gi})"
                );
            }
        }
        let mut inner = relock(&self.inner);
        inner.counters.scrub_passes += 1;
        inner.counters.records_scrubbed += (rep.records_clean + rep.corruptions) as u64;
        rep
    }

    /// One verification read for the scrub pass: through the scheduler's
    /// `Background` lane when attached — maintenance must queue behind
    /// (and only age past, never preempt) decode-critical work — else
    /// directly against the device.
    fn scrub_read(&self, off: u64, len: usize) -> Result<(), DiskError> {
        if let Some(sched) = self.scheduler() {
            let ticket = sched.submit(IoRequest {
                lane: Lane::Background,
                disk: self.disk.clone(),
                extents: vec![(off, len)],
                counters: self.io_counters.clone(),
            });
            if let Ok(t) = ticket {
                return sched.wait(t, Duration::from_secs(60)).map(|_| ());
            }
        }
        let mut buf = vec![0u8; len];
        self.disk.read(off, &mut buf).map(|_| ())
    }

    /// One verified record read with a single heal retry. Returns the
    /// modeled device time of the read that succeeded (a failed first
    /// attempt contributes none — it never delivered the bytes).
    fn read_record(&self, off: u64, buf: &mut [u8]) -> Result<Duration, DiskError> {
        match self.disk.read(off, buf) {
            Ok(d) => Ok(d),
            Err(e) if e.is_retryable() => match self.disk.read(off, buf) {
                Ok(d) => {
                    relock(&self.inner).counters.healed += 1;
                    Ok(d)
                }
                Err(e2) => Err(e2),
            },
            Err(e) => Err(e),
        }
    }

    fn record_corruption(&self, entry: u64, layer: usize, group: usize, off: u64, e: &DiskError) {
        let mut inner = relock(&self.inner);
        let at = inner.lru.clock();
        inner.manifest.corruption_log.push(CorruptionSite {
            entry,
            layer,
            group,
            offset: off,
            detail: e.to_string(),
            at,
        });
        inner.counters.corruptions += 1;
        let _ = self.persist_locked(&inner);
    }

    fn evict_locked(&self, inner: &mut Inner, key: u64) {
        if self.drop_entry_locked(inner, key) {
            inner.counters.evictions += 1;
        }
    }

    /// Quarantine ignores pins: poisoned bytes must not be nominated
    /// again even to the session that pinned them (its restore already
    /// failed and fell back to recompute).
    fn quarantine_locked(&self, inner: &mut Inner, key: u64) {
        if self.drop_entry_locked(inner, key) {
            inner.counters.quarantined += 1;
        }
    }

    fn drop_entry_locked(&self, inner: &mut Inner, key: u64) -> bool {
        // drop the LRU node even when the manifest entry is gone, so a
        // failed eviction can never renominate the same victim forever
        inner.lru.remove(key);
        let Some(e) = inner.manifest.entries.remove(&key) else {
            return false;
        };
        inner.index.remove(key, &e.tokens, self.layout.group);
        inner.free_slots.push(e.slot);
        inner.stored_bytes = inner
            .stored_bytes
            .saturating_sub(entry_bytes(&self.layout, e.n_groups(self.layout.group)));
        true
    }

    fn persist_locked(&self, inner: &Inner) -> anyhow::Result<()> {
        match &self.dir {
            Some(dir) => inner.manifest.persist(dir),
            None => Ok(()),
        }
    }

    pub fn counters(&self) -> StoreCounters {
        relock(&self.inner).counters
    }

    pub fn entries(&self) -> usize {
        relock(&self.inner).manifest.entries.len()
    }

    pub fn stored_bytes(&self) -> u64 {
        relock(&self.inner).stored_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn corruption_sites(&self) -> Vec<CorruptionSite> {
        relock(&self.inner).manifest.corruption_log.clone()
    }

    pub fn layout(&self) -> &DiskLayout {
        &self.layout
    }
}

fn entry_bytes(layout: &DiskLayout, n_groups: usize) -> u64 {
    n_groups as u64 * layout.group_stride() * layout.n_layers as u64
}
