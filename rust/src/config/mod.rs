//! Configuration: model specs (mirroring `python/compile/specs.py`),
//! runtime parameters (the knobs the paper's offline tuner sets), and
//! memory-budget accounting.

use crate::util::json::Json;

/// Static GQA-transformer shape description. Parsed from the artifact
/// manifest; must stay in sync with the Python `ModelSpec`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_base: f64,
    pub rms_eps: f64,
}

impl ModelSpec {
    pub fn from_json(j: &Json) -> anyhow::Result<ModelSpec> {
        Ok(ModelSpec {
            name: j.req("name")?.as_str().unwrap_or("?").to_string(),
            n_layers: j.req("n_layers")?.as_usize().unwrap(),
            d_model: j.req("d_model")?.as_usize().unwrap(),
            n_q_heads: j.req("n_q_heads")?.as_usize().unwrap(),
            n_kv_heads: j.req("n_kv_heads")?.as_usize().unwrap(),
            head_dim: j.req("head_dim")?.as_usize().unwrap(),
            d_ff: j.req("d_ff")?.as_usize().unwrap(),
            vocab: j.req("vocab")?.as_usize().unwrap(),
            rope_base: j.f64_or("rope_base", 10000.0),
            rms_eps: j.f64_or("rms_eps", 1e-5),
        })
    }

    /// H_kv * d — flattened joint-head K dimension (paper §3.2).
    pub fn kv_flat_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn q_flat_dim(&self) -> usize {
        self.n_q_heads * self.head_dim
    }

    pub fn n_rep(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }

    /// K+V bytes for one token in one layer (f32).
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        2 * self.kv_flat_dim() as u64 * 4
    }

    pub fn kv_bytes_per_token(&self) -> u64 {
        self.n_layers as u64 * self.kv_bytes_per_token_layer()
    }

    /// Full-cache bytes for (batch, context).
    pub fn kv_cache_bytes(&self, batch: usize, context: usize) -> u64 {
        batch as u64 * context as u64 * self.kv_bytes_per_token()
    }

    pub fn n_params(&self) -> u64 {
        let d = self.d_model as u64;
        let hq = self.q_flat_dim() as u64;
        let hkv = self.kv_flat_dim() as u64;
        let f = self.d_ff as u64;
        let per_layer = d + d * hq + 2 * d * hkv + hq * d + d + 2 * d * f + f * d;
        self.n_layers as u64 * per_layer + self.vocab as u64 * d + d
    }
}

/// A "paper-scale" spec used only for analytical exhibits (Fig. 1 / 3a
/// reproduce the paper's Qwen3-4B / LLaMA3-8B *numbers*, which depend only
/// on shape arithmetic, not on running the model).
pub fn paper_spec(name: &str) -> ModelSpec {
    match name {
        // Qwen3-4B: 36 layers, 8 KV heads, head 128, GQA — f16 KV.
        "qwen3-4b" => ModelSpec {
            name: "qwen3-4b".into(),
            n_layers: 36,
            d_model: 2560,
            n_q_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 9728,
            vocab: 151_936,
            rope_base: 1e6,
            rms_eps: 1e-6,
        },
        // LLaMA3-8B: 32 layers, 8 KV heads, head 128.
        "llama3-8b" => ModelSpec {
            name: "llama3-8b".into(),
            n_layers: 32,
            d_model: 4096,
            n_q_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 14336,
            vocab: 128_256,
            rope_base: 5e5,
            rms_eps: 1e-5,
        },
        _ => panic!("unknown paper spec {name}"),
    }
}

/// Runtime parameters of the KVSwap policy — exactly the knobs the paper's
/// offline tuner (§3.5, Appendix A) chooses: group size G, number of
/// selected groups M, K-cache compression rank r (sigma = Hkv*d / r),
/// reuse-buffer capacity C, plus pipeline knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct KvSwapConfig {
    /// G: consecutive KV entries per prediction/IO group.
    pub group_size: usize,
    /// M: groups selected (and loaded) per layer per step.
    pub n_groups: usize,
    /// r: low-rank K-cache rank; sigma = kv_flat_dim / r.
    pub rank: usize,
    /// C: reuse-buffer slots (each holds one KV group) per layer.
    pub reuse_slots: usize,
    /// Rolling-buffer slots exposed to attention (recent entries).
    pub rb_slots: usize,
    /// Attention width of the compiled decode artifact (>= M*G + rb).
    pub p_sel: usize,
    /// Compressed-cache capacity (max context) of the predict artifact.
    pub ncap: usize,
    /// Relaxation factor alpha (Appendix A.4): fraction of I/O that may
    /// remain un-hidden before the solver must react.
    pub alpha: f64,
    /// Enable the reuse buffer (Tab. 5 ablates this).
    pub use_reuse: bool,
    /// Enable the rolling buffer (App. Tab. 3 ablates this).
    pub use_rolling: bool,
}

impl Default for KvSwapConfig {
    fn default() -> Self {
        KvSwapConfig {
            group_size: 4,
            n_groups: 64,
            rank: 16,
            reuse_slots: 96,
            rb_slots: 16,
            p_sel: 272,
            ncap: 2048,
            alpha: 0.15,
            use_reuse: true,
            use_rolling: true,
        }
    }
}

impl KvSwapConfig {
    /// Selected entries per step (the paper's MG; default 256 ≈ MG=400
    /// scaled to our context lengths).
    pub fn selected_entries(&self) -> usize {
        self.group_size * self.n_groups
    }

    /// Per-batch-row KV *management* memory (bytes) this config costs:
    /// compressed K cache + reuse buffer + rolling buffer + preload
    /// staging, per layer summed over layers. This is the quantity the
    /// paper budgets (Tab. 1: "KV memory budget").
    pub fn management_bytes_per_seq(&self, spec: &ModelSpec, context: usize) -> u64 {
        let hd = spec.kv_flat_dim() as u64;
        let kv_entry = spec.kv_bytes_per_token_layer(); // K+V, one layer
        let l = spec.n_layers as u64;
        let klr = context as u64 * self.rank as u64 * 4 * l; // compressed K
        let reuse = self.reuse_slots as u64 * self.group_size as u64 * kv_entry * l;
        let rolling = self.rb_slots as u64 * kv_entry * l;
        // preload staging buffer is shared across layers (Appendix A.2)
        let staging = self.selected_entries() as u64 * kv_entry;
        let _ = hd;
        klr + reuse + rolling + staging
    }

    pub fn sigma(&self, spec: &ModelSpec) -> f64 {
        spec.kv_flat_dim() as f64 / self.rank as f64
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("group_size", self.group_size.into()),
            ("n_groups", self.n_groups.into()),
            ("rank", self.rank.into()),
            ("reuse_slots", self.reuse_slots.into()),
            ("rb_slots", self.rb_slots.into()),
            ("p_sel", self.p_sel.into()),
            ("ncap", self.ncap.into()),
            ("alpha", self.alpha.into()),
            ("use_reuse", self.use_reuse.into()),
            ("use_rolling", self.use_rolling.into()),
        ])
    }

    pub fn from_json(j: &Json) -> KvSwapConfig {
        let d = KvSwapConfig::default();
        KvSwapConfig {
            group_size: j.usize_or("group_size", d.group_size),
            n_groups: j.usize_or("n_groups", d.n_groups),
            rank: j.usize_or("rank", d.rank),
            reuse_slots: j.usize_or("reuse_slots", d.reuse_slots),
            rb_slots: j.usize_or("rb_slots", d.rb_slots),
            p_sel: j.usize_or("p_sel", d.p_sel),
            ncap: j.usize_or("ncap", d.ncap),
            alpha: j.f64_or("alpha", d.alpha),
            use_reuse: j.get("use_reuse").and_then(|v| v.as_bool()).unwrap_or(d.use_reuse),
            use_rolling: j
                .get("use_rolling")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.use_rolling),
        }
    }
}

/// Prefetch-pipeline knobs (paper §3.4 pipelining + §3.3 read
/// orchestration): worker pool size, in-flight plan bound, and the byte
/// gap below which adjacent group reads merge into one sequential I/O.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefetchConfig {
    /// Prefetch worker threads. `0` = synchronous mode: preload plans are
    /// executed inline when the engine waits on them (the no-overlap
    /// baseline the benches compare against).
    pub workers: usize,
    /// Max preload plans in flight (bounds both job and completion
    /// queues, hence staging memory ≈ 2×depth buffers).
    pub queue_depth: usize,
    /// Coalesce reads whose byte gap is at most this (over-reading the
    /// gap is cheaper than an extra op latency; 16 KiB default sits well
    /// under NVMe's 80 µs ≈ 144 KiB break-even).
    pub coalesce_gap: u64,
    /// Max queued plans (across lanes, same device) merged into one
    /// dispatch group when their extents are gap-close. `1` disables
    /// cross-plan coalescing.
    pub dispatch_window: usize,
    /// Starvation bound for the `Background` lane: a queued scrub read
    /// older than this is promoted past strict priority, milliseconds.
    pub aging_ms: u64,
    /// Route store restores (`Warm`) and scrub reads (`Background`)
    /// through the shared scheduler. `false` keeps the legacy
    /// separate-pools shape (each stream reads its device directly) —
    /// the baseline the benches compare against.
    pub unified_io: bool,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            workers: 2,
            queue_depth: 2,
            coalesce_gap: 16 * 1024,
            dispatch_window: 4,
            aging_ms: 50,
            unified_io: true,
        }
    }
}

impl PrefetchConfig {
    /// The synchronous baseline: no worker threads, reads happen inline.
    pub fn synchronous() -> PrefetchConfig {
        PrefetchConfig {
            workers: 0,
            ..PrefetchConfig::default()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("workers", self.workers.into()),
            ("queue_depth", self.queue_depth.into()),
            ("coalesce_gap", (self.coalesce_gap as usize).into()),
            ("dispatch_window", self.dispatch_window.into()),
            ("aging_ms", (self.aging_ms as usize).into()),
            ("unified_io", self.unified_io.into()),
        ])
    }

    pub fn from_json(j: &Json) -> PrefetchConfig {
        let d = PrefetchConfig::default();
        PrefetchConfig {
            workers: j.usize_or("workers", d.workers),
            queue_depth: j.usize_or("queue_depth", d.queue_depth),
            coalesce_gap: j.usize_or("coalesce_gap", d.coalesce_gap as usize) as u64,
            dispatch_window: j.usize_or("dispatch_window", d.dispatch_window),
            aging_ms: j.usize_or("aging_ms", d.aging_ms as usize) as u64,
            unified_io: j
                .get("unified_io")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.unified_io),
        }
    }
}

/// Fault-injection knobs for the `disk::FaultBackend` wrapper. Off by
/// default (`rate == corruption_rate == 0.0` ⇒ the backend is never
/// wrapped). Fully deterministic for a given `seed` and op sequence, so
/// fault runs are reproducible and bit-identity vs. the clean run can be
/// asserted in tests.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Per-read probability of an injected I/O fault (transient error,
    /// latency spike, or short read — or a persistent extent poison when
    /// `persistent` is set).
    pub rate: f64,
    /// Per-read probability of a *silent* bit flip in the returned bytes
    /// (caught only by the integrity checksums).
    pub corruption_rate: f64,
    /// PRNG seed for the probabilistic injector.
    pub seed: u64,
    /// When true, injected I/O faults poison the extent: every later read
    /// of overlapping bytes fails too, until `FaultBackend::heal()`.
    pub persistent: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            rate: 0.0,
            corruption_rate: 0.0,
            seed: 0,
            persistent: false,
        }
    }
}

impl FaultConfig {
    /// Whether any injection is configured (decides backend wrapping).
    pub fn enabled(&self) -> bool {
        self.rate > 0.0 || self.corruption_rate > 0.0
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("rate", self.rate.into()),
            ("corruption_rate", self.corruption_rate.into()),
            ("seed", (self.seed as usize).into()),
            ("persistent", self.persistent.into()),
        ])
    }

    pub fn from_json(j: &Json) -> FaultConfig {
        let d = FaultConfig::default();
        FaultConfig {
            rate: j.f64_or("rate", d.rate),
            corruption_rate: j.f64_or("corruption_rate", d.corruption_rate),
            seed: j.usize_or("seed", d.seed as usize) as u64,
            persistent: j
                .get("persistent")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.persistent),
        }
    }
}

/// Retry and circuit-breaker policy for the staging read path. Defaults
/// keep the clean path untouched (retries only run after a failure) while
/// absorbing transient faults: 3 re-issues with 1→50 ms jittered
/// exponential backoff, breaker trips after 4 consecutive threaded plan
/// failures, half-open probe after 8 clean synchronous plans.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryConfig {
    /// Max re-issues per preload plan (0 disables retrying).
    pub max_retries: u32,
    /// First backoff sleep, in milliseconds.
    pub backoff_base_ms: f64,
    /// Backoff ceiling, in milliseconds.
    pub backoff_max_ms: f64,
    /// Jitter fraction in [0,1]: each sleep is scaled by a uniform factor
    /// in [1-jitter, 1] to de-synchronize retry storms.
    pub jitter: f64,
    /// Consecutive threaded plan failures before the breaker opens and
    /// routes plans through the synchronous inline path.
    pub breaker_threshold: u32,
    /// Clean synchronous plans required (while open) before a half-open
    /// probe plan is sent back through the worker pool.
    pub breaker_probe_after: u32,
}

impl Default for RetryConfig {
    fn default() -> Self {
        RetryConfig {
            max_retries: 3,
            backoff_base_ms: 1.0,
            backoff_max_ms: 50.0,
            jitter: 0.5,
            breaker_threshold: 4,
            breaker_probe_after: 8,
        }
    }
}

impl RetryConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("max_retries", (self.max_retries as usize).into()),
            ("backoff_base_ms", self.backoff_base_ms.into()),
            ("backoff_max_ms", self.backoff_max_ms.into()),
            ("jitter", self.jitter.into()),
            ("breaker_threshold", (self.breaker_threshold as usize).into()),
            (
                "breaker_probe_after",
                (self.breaker_probe_after as usize).into(),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> RetryConfig {
        let d = RetryConfig::default();
        RetryConfig {
            max_retries: j.usize_or("max_retries", d.max_retries as usize) as u32,
            backoff_base_ms: j.f64_or("backoff_base_ms", d.backoff_base_ms),
            backoff_max_ms: j.f64_or("backoff_max_ms", d.backoff_max_ms),
            jitter: j.f64_or("jitter", d.jitter),
            breaker_threshold: j.usize_or("breaker_threshold", d.breaker_threshold as usize)
                as u32,
            breaker_probe_after: j.usize_or("breaker_probe_after", d.breaker_probe_after as usize)
                as u32,
        }
    }
}

/// Persistent KV store knobs (`store::PersistentStore`). Disabled by
/// default: the store costs a manifest rewrite per save, so it is opt-in
/// via `--store-dir`/`--store-mem`. With `dir == None` the store is
/// memory-backed — prefix reuse within the process, nothing on disk.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreConfig {
    pub enabled: bool,
    /// Directory for `store.bin` + `manifest.json`; `None` ⇒ in-memory.
    pub dir: Option<std::path::PathBuf>,
    /// Capacity ceiling for stored records; LRU eviction keeps under it.
    pub capacity_bytes: u64,
    /// Seconds between scheduled scrub passes (≤ 0 ⇒ every idle tick).
    pub scrub_interval_s: f64,
    /// Max entries verified per scrub pass (cursor rotates across passes).
    pub scrub_budget: usize,
    /// Stream warm-start restores into prefill chunk-by-chunk so disk
    /// reads overlap compute (`false` ⇒ restore fully before the first
    /// prefill chunk runs). Restores are bit-identical either way.
    pub pipelined_restore: bool,
    /// Compact the data file during `maintain()` once the freed-slot
    /// fraction (recycled slots ÷ allocated slots) exceeds this: live
    /// records are rewritten contiguously and the file is truncated.
    /// `>= 1.0` disables compaction.
    pub compact_free_frac: f64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            enabled: false,
            dir: None,
            capacity_bytes: 256 << 20,
            scrub_interval_s: 5.0,
            scrub_budget: 4,
            pipelined_restore: true,
            compact_free_frac: 0.35,
        }
    }
}

impl StoreConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("enabled", self.enabled.into()),
            (
                "dir",
                match &self.dir {
                    Some(d) => d.display().to_string().into(),
                    None => Json::Null,
                },
            ),
            ("capacity_bytes", (self.capacity_bytes as usize).into()),
            ("scrub_interval_s", self.scrub_interval_s.into()),
            ("scrub_budget", self.scrub_budget.into()),
            ("pipelined_restore", self.pipelined_restore.into()),
            ("compact_free_frac", self.compact_free_frac.into()),
        ])
    }

    pub fn from_json(j: &Json) -> StoreConfig {
        let d = StoreConfig::default();
        StoreConfig {
            enabled: j
                .get("enabled")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.enabled),
            dir: j
                .get("dir")
                .and_then(|v| v.as_str())
                .map(std::path::PathBuf::from),
            capacity_bytes: j.usize_or("capacity_bytes", d.capacity_bytes as usize) as u64,
            scrub_interval_s: j.f64_or("scrub_interval_s", d.scrub_interval_s),
            scrub_budget: j.usize_or("scrub_budget", d.scrub_budget),
            pipelined_restore: j
                .get("pipelined_restore")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.pipelined_restore),
            compact_free_frac: j.f64_or("compact_free_frac", d.compact_free_frac),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano() -> ModelSpec {
        ModelSpec {
            name: "nano".into(),
            n_layers: 4,
            d_model: 128,
            n_q_heads: 8,
            n_kv_heads: 4,
            head_dim: 32,
            d_ff: 256,
            vocab: 512,
            rope_base: 10000.0,
            rms_eps: 1e-5,
        }
    }

    #[test]
    fn kv_byte_arithmetic() {
        let s = nano();
        assert_eq!(s.kv_flat_dim(), 128);
        assert_eq!(s.kv_bytes_per_token_layer(), 1024);
        assert_eq!(s.kv_bytes_per_token(), 4096);
        assert_eq!(s.kv_cache_bytes(8, 8192), 8 * 8192 * 4096);
    }

    #[test]
    fn paper_spec_fig1_scale() {
        // Fig. 1: Qwen3-4B at 16K context, batch 4 -> ~9 GiB (f16).
        let q = paper_spec("qwen3-4b");
        let f16_bytes = q.kv_cache_bytes(4, 16384) / 2; // our arithmetic is f32
        let gib = f16_bytes as f64 / (1u64 << 30) as f64;
        assert!((8.0..10.0).contains(&gib), "got {gib} GiB");
        // and 32K context, batch 12 -> ~54 GiB
        let f16b = q.kv_cache_bytes(12, 32768) / 2;
        let gib2 = f16b as f64 / (1u64 << 30) as f64;
        assert!((50.0..58.0).contains(&gib2), "got {gib2} GiB");
    }

    #[test]
    fn config_json_roundtrip() {
        let mut c = KvSwapConfig::default();
        c.group_size = 8;
        c.alpha = 0.3;
        c.use_reuse = false;
        let j = c.to_json();
        let back = KvSwapConfig::from_json(&Json::parse(&j.to_string()).unwrap());
        assert_eq!(back, c);
    }

    #[test]
    fn management_memory_much_smaller_than_full_cache() {
        let s = nano();
        let c = KvSwapConfig::default();
        let full = s.kv_cache_bytes(1, 2048);
        let mgmt = c.management_bytes_per_seq(&s, 2048);
        assert!(
            (mgmt as f64) < (full as f64) * 0.55,
            "mgmt {mgmt} vs full {full}"
        );
    }

    #[test]
    fn sigma_matches_rank() {
        let s = nano();
        let mut c = KvSwapConfig::default();
        c.rank = 4;
        assert_eq!(c.sigma(&s), 32.0);
        c.rank = 16;
        assert_eq!(c.sigma(&s), 8.0);
    }

    #[test]
    fn selected_entries() {
        let c = KvSwapConfig::default();
        assert_eq!(c.selected_entries(), 256);
        assert!(c.p_sel >= c.selected_entries() + c.rb_slots);
    }

    #[test]
    fn prefetch_config_roundtrip_and_modes() {
        let d = PrefetchConfig::default();
        assert!(d.workers > 0);
        assert!(PrefetchConfig::synchronous().workers == 0);
        let c = PrefetchConfig {
            workers: 4,
            queue_depth: 3,
            coalesce_gap: 4096,
            dispatch_window: 6,
            aging_ms: 25,
            unified_io: false,
        };
        let back = PrefetchConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap());
        assert_eq!(back, c);
        assert!(d.dispatch_window >= 1, "window of 1 = no cross-plan merging");
        assert!(d.unified_io, "shared scheduler defaults on");
    }

    #[test]
    fn fault_config_roundtrip_and_enabled() {
        let d = FaultConfig::default();
        assert!(!d.enabled(), "faults must be off by default");
        let c = FaultConfig {
            rate: 0.05,
            corruption_rate: 0.01,
            seed: 7,
            persistent: true,
        };
        assert!(c.enabled());
        let back = FaultConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap());
        assert_eq!(back, c);
    }

    #[test]
    fn retry_config_roundtrip() {
        let c = RetryConfig {
            max_retries: 5,
            backoff_base_ms: 2.0,
            backoff_max_ms: 80.0,
            jitter: 0.25,
            breaker_threshold: 3,
            breaker_probe_after: 6,
        };
        let back = RetryConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap());
        assert_eq!(back, c);
        assert!(RetryConfig::default().breaker_threshold >= 1);
    }

    #[test]
    fn store_config_roundtrip() {
        let d = StoreConfig::default();
        assert!(!d.enabled, "persistent store must be opt-in");
        assert!(d.capacity_bytes > 0);
        assert!(d.pipelined_restore, "pipelined warm restores default on");
        let c = StoreConfig {
            enabled: true,
            dir: Some(std::path::PathBuf::from("/tmp/kv-store")),
            capacity_bytes: 64 << 20,
            scrub_interval_s: 0.5,
            scrub_budget: 2,
            pipelined_restore: false,
            compact_free_frac: 0.5,
        };
        let back = StoreConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap());
        assert_eq!(back, c);
        // None dir serializes as null and round-trips to None
        let back = StoreConfig::from_json(&Json::parse(&d.to_json().to_string()).unwrap());
        assert_eq!(back, d);
    }
}
