//! Bounded-retry policy for the staging read path.
//!
//! A preload plan gets a small *retry budget*; each failed coalesced run
//! (transient `Io`, checksum `Corrupt`) consumes one unit and sleeps a
//! jittered exponential backoff before re-issuing. The budget is
//! per-plan, not per-run, so a badly failing plan cannot multiply its
//! own latency unboundedly — it exhausts the budget and surfaces the
//! typed error to the circuit breaker instead.
//!
//! Whether an error is worth a retry at all is decided by
//! [`DiskError::is_retryable`](super::DiskError::is_retryable); the
//! policy here only controls *how many* and *how spaced*.

use std::sync::Mutex;
use std::time::Duration;

use super::relock;
use crate::config::RetryConfig;
use crate::util::rng::Rng;

/// Shared, thread-safe retry policy. One instance serves every prefetch
/// worker; the only shared state is the jitter PRNG behind a mutex that
/// is touched exclusively on the (cold) failure path.
#[derive(Debug)]
pub struct RetryPolicy {
    cfg: RetryConfig,
    rng: Mutex<Rng>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::new(RetryConfig::default())
    }
}

impl RetryPolicy {
    pub fn new(cfg: RetryConfig) -> RetryPolicy {
        RetryPolicy {
            rng: Mutex::new(Rng::new(0x9E37_79B9_7F4A_7C15 ^ cfg.max_retries as u64)),
            cfg,
        }
    }

    /// A policy that never retries (clean-path tests, strict benches).
    pub fn disabled() -> RetryPolicy {
        RetryPolicy::new(RetryConfig {
            max_retries: 0,
            ..RetryConfig::default()
        })
    }

    pub fn config(&self) -> &RetryConfig {
        &self.cfg
    }

    /// Fresh per-plan budget.
    pub fn budget(&self) -> RetryBudget {
        RetryBudget {
            remaining: self.cfg.max_retries,
            used: 0,
        }
    }

    /// Backoff before retry number `attempt` (0-based): exponential from
    /// `backoff_base_ms`, clamped at `backoff_max_ms`, scaled by a
    /// uniform jitter factor in `[1-jitter, 1]`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let exp = 2f64.powi(attempt.min(30) as i32);
        let ms = (self.cfg.backoff_base_ms * exp).min(self.cfg.backoff_max_ms);
        let jitter = self.cfg.jitter.clamp(0.0, 1.0);
        let factor = if jitter > 0.0 {
            let u = relock(&self.rng).f64();
            1.0 - jitter * u
        } else {
            1.0
        };
        Duration::from_micros((ms.max(0.0) * factor * 1000.0) as u64)
    }

    /// Sleep the backoff for retry `attempt` on the calling thread.
    pub fn sleep_before_retry(&self, attempt: u32) {
        let d = self.backoff(attempt);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

/// Countdown of re-issues one preload plan may still spend.
#[derive(Debug, Clone, Copy)]
pub struct RetryBudget {
    remaining: u32,
    used: u32,
}

impl RetryBudget {
    /// Spend one retry; `false` means the budget is exhausted and the
    /// error must surface.
    pub fn try_consume(&mut self) -> bool {
        if self.remaining == 0 {
            false
        } else {
            self.remaining -= 1;
            self.used += 1;
            true
        }
    }

    pub fn used(&self) -> u32 {
        self.used
    }

    pub fn remaining(&self) -> u32 {
        self.remaining
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_then_clamps() {
        let p = RetryPolicy::new(RetryConfig {
            max_retries: 8,
            backoff_base_ms: 1.0,
            backoff_max_ms: 8.0,
            jitter: 0.0, // deterministic for the shape assertion
            ..RetryConfig::default()
        });
        let d: Vec<Duration> = (0..6).map(|a| p.backoff(a)).collect();
        assert_eq!(d[0], Duration::from_millis(1));
        assert_eq!(d[1], Duration::from_millis(2));
        assert_eq!(d[2], Duration::from_millis(4));
        // clamped from attempt 3 on
        assert_eq!(d[3], Duration::from_millis(8));
        assert_eq!(d[5], Duration::from_millis(8));
    }

    #[test]
    fn jitter_stays_in_band() {
        let p = RetryPolicy::new(RetryConfig {
            backoff_base_ms: 10.0,
            backoff_max_ms: 10.0,
            jitter: 0.5,
            ..RetryConfig::default()
        });
        for _ in 0..64 {
            let d = p.backoff(0);
            assert!(
                d >= Duration::from_millis(5) && d <= Duration::from_millis(10),
                "jittered backoff {d:?} outside [5ms, 10ms]"
            );
        }
    }

    #[test]
    fn budget_counts_down_and_stops() {
        let p = RetryPolicy::new(RetryConfig {
            max_retries: 2,
            ..RetryConfig::default()
        });
        let mut b = p.budget();
        assert!(b.try_consume());
        assert!(b.try_consume());
        assert!(!b.try_consume(), "third retry must be refused");
        assert_eq!(b.used(), 2);
        assert_eq!(b.remaining(), 0);

        let mut none = RetryPolicy::disabled().budget();
        assert!(!none.try_consume());
    }
}
