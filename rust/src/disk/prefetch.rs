//! Decode prefetch pipeline — the paper's overlap of prediction-driven
//! preloads with compute, on *real* storage.
//!
//! [`Prefetcher`] is a thin, lane-tagged client of the unified
//! [`IoScheduler`](super::sched::IoScheduler): every per-layer
//! [`PreloadPlan`] is flattened into one `Critical`-lane request, and
//! `recv` redeems tickets in submission order, so the engine always
//! receives layer *l*'s staging before layer *l+1*'s regardless of
//! worker scheduling. The scheduler owns the worker pool, the staging
//! [`BufferPool`], the retry budget, and the circuit breaker; this
//! module owns only plan bookkeeping (shapes, tags, ordering) and the
//! per-client counters reported in `DecodeStats`.
//!
//! Backpressure is end-to-end: the `Critical` lane admits at most
//! `queue_depth` queued plans, so a stalled engine stops the workers and
//! a slow disk stalls `submit` — staged bytes never pile up beyond
//! roughly queue-depth + worker buffers.
//!
//! `PrefetchConfig { workers: 0 }` degrades to a *synchronous* pipeline:
//! `submit` only issues an inline ticket and `recv` executes it on the
//! caller's thread. That mode is the baseline the benches compare
//! against, and the bit-identical reference for the integration tests —
//! both modes run byte-for-byte the same reads, only the threading
//! differs.
//!
//! ## Failure handling
//!
//! The ladder (see [`super#failure-model--degradation-ladder`]) lives in
//! the scheduler; what this client guarantees on top:
//!
//! * a plan whose staging ultimately failed yields its typed error from
//!   `recv` — the ticket is consumed either way, so later plans still
//!   deliver;
//! * a `recv` timeout abandons only that ticket (the late completion is
//!   dropped with its reply channel);
//! * `shutdown` bounds its drain/join by a grace period and leaves the
//!   pipeline returning `QueueClosed` instead of hanging on a wedged
//!   worker.
//!
//! The scheduler's workers touch only [`Backend`](super::Backend) +
//! staging memory; nothing device- or runtime-bound (`Rc<PjrtRuntime>`
//! etc.) crosses a thread boundary.
//!
//! This lane overlaps *decode* I/O with compute. Prefill's store-restore
//! stream rides the same scheduler on the `Warm` lane (see
//! `coordinator::engine`) with the same residual `Phase::IoWait`
//! accounting convention — only the stall compute failed to hide is
//! charged.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::error::{DiskError, DiskResult};
use super::relock;
use super::retry::RetryPolicy;
use super::sched::{self, BreakerState, IoRequest, IoScheduler, Lane, LaneSummary, Ticket, N_LANES};
use super::sim::SimDisk;
use crate::config::PrefetchConfig;

/// Retained staging buffers above this capacity are dropped instead of
/// pooled: one giant coalesced run must not pin memory for the rest of
/// the session.
pub const BUF_HIGH_WATER: usize = 4 << 20;

/// One planned group read, tagged so the engine can route the staged
/// bytes to the right cache slot (`tag` is policy-defined: group id,
/// `u32::MAX` for whole-layer staging, …).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedExtent {
    pub tag: u32,
    pub offset: u64,
    pub len: usize,
}

/// The preload work for one layer of one decode step, across the batch.
#[derive(Debug, Clone)]
pub struct PreloadPlan {
    pub layer: usize,
    /// `(sequence index, extents to stage for it)`.
    pub per_seq: Vec<(usize, Vec<PlannedExtent>)>,
}

/// A completed plan: staged bytes per sequence, ready to commit.
#[derive(Debug)]
pub struct StagedLoad {
    pub layer: usize,
    /// `(sequence index, [(tag, bytes)])` in plan order.
    pub per_seq: Vec<(usize, Vec<(u32, Vec<u8>)>)>,
    /// Modeled device time for this plan's share of its dispatch group
    /// (virtual-clock accounting).
    pub io_time: Duration,
    /// When the plan was submitted — residual wait = how much of
    /// `io_time` was *not* hidden behind compute since this instant.
    pub issued_at: Instant,
}

/// Recycled staging buffers, bounded in count *and* in retained
/// capacity. Locks recover from poisoning: a panicking worker must not
/// take the pool (and with it the engine thread) down with it.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    max: usize,
    high_water: usize,
}

impl BufferPool {
    pub fn new(max: usize) -> BufferPool {
        BufferPool::with_high_water(max, BUF_HIGH_WATER)
    }

    /// Pool with an explicit retained-capacity bound per buffer.
    pub fn with_high_water(max: usize, high_water: usize) -> BufferPool {
        BufferPool {
            bufs: Mutex::new(Vec::new()),
            max,
            high_water,
        }
    }

    pub fn take(&self) -> Vec<u8> {
        relock(&self.bufs).pop().unwrap_or_default()
    }

    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() > self.high_water {
            return; // oversized one-off: let the allocator reclaim it
        }
        buf.clear();
        let mut bufs = relock(&self.bufs);
        if bufs.len() < self.max {
            bufs.push(buf);
        }
    }
}

/// Per-client staging counters (lives in [`read_coalesced`]'s signature,
/// so it is public; construct with `Default` when calling that
/// directly). Pool-level events (panics, respawns, breaker trips, lane
/// stats) are counted by the scheduler and merged into
/// [`PrefetchSummary`] by [`Prefetcher::summary`].
#[derive(Default)]
pub struct PrefetchCounters {
    plans_submitted: AtomicU64,
    plans_completed: AtomicU64,
    plans_failed: AtomicU64,
    extents_requested: AtomicU64,
    runs_issued: AtomicU64,
    bytes_staged: AtomicU64,
    io_retries: AtomicU64,
    corrupt_detected: AtomicU64,
}

impl PrefetchCounters {
    pub fn summary(&self) -> PrefetchSummary {
        PrefetchSummary {
            plans: self.plans_completed.load(Ordering::Relaxed),
            plans_failed: self.plans_failed.load(Ordering::Relaxed),
            extents: self.extents_requested.load(Ordering::Relaxed),
            runs: self.runs_issued.load(Ordering::Relaxed),
            bytes_staged: self.bytes_staged.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
            corrupt_detected: self.corrupt_detected.load(Ordering::Relaxed),
            ..PrefetchSummary::default()
        }
    }

    fn reset(&self) {
        self.plans_submitted.store(0, Ordering::Relaxed);
        self.plans_completed.store(0, Ordering::Relaxed);
        self.plans_failed.store(0, Ordering::Relaxed);
        self.extents_requested.store(0, Ordering::Relaxed);
        self.runs_issued.store(0, Ordering::Relaxed);
        self.bytes_staged.store(0, Ordering::Relaxed);
        self.io_retries.store(0, Ordering::Relaxed);
        self.corrupt_detected.store(0, Ordering::Relaxed);
    }

    pub(crate) fn add_extents(&self, n: u64) {
        self.extents_requested.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn add_runs(&self, n: u64) {
        self.runs_issued.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn add_bytes(&self, n: u64) {
        self.bytes_staged.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn add_retry(&self) {
        self.io_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn add_corrupt(&self) {
        self.corrupt_detected.fetch_add(1, Ordering::Relaxed);
    }
}

/// What the pipeline did over a decode run (reported in `DecodeStats`):
/// this client's staging counters plus the scheduler's service counters
/// over the same window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefetchSummary {
    pub plans: u64,
    /// Plans that ultimately failed (retry budget exhausted / timeout /
    /// contained worker panic) and were reported to the engine as errors.
    pub plans_failed: u64,
    pub extents: u64,
    pub runs: u64,
    pub bytes_staged: u64,
    /// Coalesced runs re-issued after a retryable failure.
    pub io_retries: u64,
    /// Checksum mismatches caught before bytes reached the engine.
    pub corrupt_detected: u64,
    /// Worker panics contained by the supervision layer.
    pub worker_panics: u64,
    /// Worker threads respawned after dying.
    pub workers_restarted: u64,
    /// Times the circuit breaker tripped the scheduler into sync routing.
    pub breaker_trips: u64,
    /// Scheduler dispatches per lane (Critical, Warm, Background).
    pub lane_dispatched: [u64; N_LANES],
    /// Scheduler queue wait per lane, microseconds.
    pub lane_wait_us: [u64; N_LANES],
    /// Queued plans merged into another plan's dispatch group.
    pub cross_plan_merges: u64,
    /// Background requests promoted past strict priority by aging.
    pub aged_promotions: u64,
}

impl PrefetchSummary {
    /// Mean extents merged per issued read (≥ 1.0 once anything ran).
    pub fn coalesce_factor(&self) -> f64 {
        if self.runs == 0 {
            return 1.0;
        }
        self.extents as f64 / self.runs as f64
    }
}

struct PendingPlan {
    layer: usize,
    /// `(sequence index, tags)` — the shape the flat chunk list scatters
    /// back into.
    shape: Vec<(usize, Vec<u32>)>,
    issued_at: Instant,
    ticket: Ticket,
}

pub struct Prefetcher {
    sched: Arc<IoScheduler>,
    /// Built our own scheduler (tests / standalone use): shut it down on
    /// drop. A scheduler shared with the engine outlives this client.
    owns_sched: bool,
    disk: Arc<SimDisk>,
    counters: Arc<PrefetchCounters>,
    /// In-flight plans, delivered FIFO by `recv`.
    pending: VecDeque<PendingPlan>,
    /// Scheduler counter baseline captured at the last `reset_counters`,
    /// so `summary()` reports service counters over the same window as
    /// the client counters.
    sched_base: Mutex<LaneSummary>,
    timeout: Duration,
    grace: Duration,
    closed: bool,
}

impl Prefetcher {
    pub fn spawn(disk: Arc<SimDisk>, cfg: &PrefetchConfig) -> Prefetcher {
        Prefetcher::spawn_with(disk, cfg, RetryPolicy::default())
    }

    /// Spawn with an explicit retry/breaker policy (the engine builds the
    /// policy from its validated `RetryConfig`). Creates a private
    /// scheduler; use [`Prefetcher::with_scheduler`] to join a shared
    /// one.
    pub fn spawn_with(disk: Arc<SimDisk>, cfg: &PrefetchConfig, retry: RetryPolicy) -> Prefetcher {
        let sched = Arc::new(IoScheduler::new(cfg, retry));
        let mut p = Prefetcher::with_scheduler(sched, disk);
        p.owns_sched = true;
        p
    }

    /// Attach to a shared [`IoScheduler`] as its `Critical`-lane client.
    /// The scheduler's lifetime is the caller's problem; this client
    /// only drains its own in-flight plans on shutdown.
    pub fn with_scheduler(sched: Arc<IoScheduler>, disk: Arc<SimDisk>) -> Prefetcher {
        Prefetcher {
            sched,
            owns_sched: false,
            disk,
            counters: Arc::new(PrefetchCounters::default()),
            pending: VecDeque::new(),
            sched_base: Mutex::new(LaneSummary::default()),
            timeout: Duration::from_secs(60),
            grace: Duration::from_secs(5),
            closed: false,
        }
    }

    pub fn is_synchronous(&self) -> bool {
        self.sched.is_synchronous()
    }

    /// Current breaker state (`Closed` = fully threaded routing).
    pub fn breaker_state(&self) -> BreakerState {
        self.sched.breaker_state()
    }

    /// The scheduler this client submits through.
    pub fn scheduler(&self) -> &Arc<IoScheduler> {
        &self.sched
    }

    /// Bound on how long `recv` waits for a staged load before abandoning
    /// the ticket with `DiskError::Timeout`.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Queue a plan on the `Critical` lane. In threaded mode this blocks
    /// once `queue_depth` plans are queued (backpressure); in synchronous
    /// mode — or while the breaker is open — it only issues an inline
    /// ticket and the read happens at `recv`.
    pub fn submit(&mut self, plan: PreloadPlan) -> DiskResult<()> {
        if self.closed {
            return Err(DiskError::QueueClosed);
        }
        let mut extents: Vec<(u64, usize)> = Vec::new();
        let mut shape: Vec<(usize, Vec<u32>)> = Vec::with_capacity(plan.per_seq.len());
        for (seq, seq_exts) in &plan.per_seq {
            let mut tags = Vec::with_capacity(seq_exts.len());
            for e in seq_exts {
                extents.push((e.offset, e.len));
                tags.push(e.tag);
            }
            shape.push((*seq, tags));
        }
        let ticket = self.sched.submit(IoRequest {
            lane: Lane::Critical,
            disk: self.disk.clone(),
            extents,
            counters: self.counters.clone(),
        })?;
        self.pending.push_back(PendingPlan {
            layer: plan.layer,
            shape,
            issued_at: Instant::now(),
            ticket,
        });
        self.counters.plans_submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Receive the next staged load, in submission order. A plan whose
    /// staging ultimately failed yields its typed error here; the ticket
    /// is consumed either way, so later plans still deliver.
    pub fn recv(&mut self) -> DiskResult<StagedLoad> {
        if self.closed {
            return Err(DiskError::QueueClosed);
        }
        // nothing in flight: recv without a matching submit
        let Some(p) = self.pending.pop_front() else {
            return Err(DiskError::QueueClosed);
        };
        match self.sched.wait(p.ticket, self.timeout) {
            Ok(done) => {
                let mut chunks = done.chunks.into_iter();
                let per_seq = p
                    .shape
                    .into_iter()
                    .map(|(seq, tags)| {
                        let loads = tags
                            .into_iter()
                            .map(|tag| (tag, chunks.next().expect("chunk per extent")))
                            .collect();
                        (seq, loads)
                    })
                    .collect();
                self.counters.plans_completed.fetch_add(1, Ordering::Relaxed);
                Ok(StagedLoad {
                    layer: p.layer,
                    per_seq,
                    io_time: done.io_time,
                    issued_at: p.issued_at,
                })
            }
            Err(e) => {
                self.counters.plans_failed.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Close the client: refuse new work and abandon in-flight plans
    /// (their completions are dropped with the reply channels). When this
    /// client owns its scheduler the pool is shut down too, bounded by
    /// `grace`.
    pub fn shutdown(&mut self, grace: Duration) {
        self.closed = true;
        self.pending.clear();
        if self.owns_sched {
            self.sched.shutdown(grace);
        }
    }

    /// Client counters plus the scheduler's service counters since the
    /// last [`reset_counters`](Prefetcher::reset_counters).
    pub fn summary(&self) -> PrefetchSummary {
        let mut s = self.counters.summary();
        let lanes = self.sched.lane_summary().since(&relock(&self.sched_base));
        s.worker_panics = lanes.worker_panics;
        s.workers_restarted = lanes.workers_restarted;
        s.breaker_trips = lanes.breaker_trips;
        s.lane_dispatched = lanes.lane_dispatched;
        s.lane_wait_us = lanes.lane_wait_us;
        s.cross_plan_merges = lanes.cross_plan_merges;
        s.aged_promotions = lanes.aged_promotions;
        s
    }

    pub fn reset_counters(&self) {
        self.counters.reset();
        *relock(&self.sched_base) = self.sched.lane_summary();
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let grace = self.grace;
        self.shutdown(grace);
    }
}

/// [`read_coalesced_with`] under the default retry policy — kept as the
/// stable entry point for callers outside the pipeline.
pub fn read_coalesced(
    disk: &SimDisk,
    extents: &[(u64, usize)],
    gap: u64,
    pool: &BufferPool,
    counters: &PrefetchCounters,
) -> DiskResult<(Vec<Vec<u8>>, Duration)> {
    read_coalesced_with(disk, extents, gap, pool, counters, &RetryPolicy::default())
}

/// Read `extents` through run coalescing: merge near-adjacent extents
/// (byte gap ≤ `gap`) into single `ReadReq`s, issue one batched read,
/// then scatter each extent's bytes back out in input order. Returns the
/// per-extent byte chunks plus the modeled device time.
///
/// This is the scheduler's group-read path
/// ([`sched::read_group`](super::sched)) applied to a single-plan group:
/// the first attempt is one batched submission (keeping the modeled
/// queue-depth overlap); staged extents are verified against their
/// write-time checksums; runs that failed — batched error or checksum
/// mismatch — are re-issued individually under the plan's retry budget
/// with jittered exponential backoff. Bytes reach the caller only after
/// every covering run has read and verified clean.
pub fn read_coalesced_with(
    disk: &SimDisk,
    extents: &[(u64, usize)],
    gap: u64,
    pool: &BufferPool,
    counters: &PrefetchCounters,
    retry: &RetryPolicy,
) -> DiskResult<(Vec<Vec<u8>>, Duration)> {
    if extents.is_empty() {
        return Ok((Vec::new(), Duration::ZERO));
    }
    let members = [sched::GroupMember { extents, counters }];
    let (mut chunks, mut times) = sched::read_group(disk, &members, gap, pool, retry)?;
    Ok((
        chunks.pop().expect("one member"),
        times.pop().expect("one member"),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RetryConfig;
    use crate::disk::backend::{Backend, MemBackend};
    use crate::disk::fault::{Fault, FaultBackend};
    use crate::disk::profile::DiskProfile;
    use std::sync::Arc;

    fn disk_with_image(n: usize) -> (Arc<SimDisk>, Vec<u8>) {
        let image: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
        let backend = Arc::new(MemBackend::new());
        backend.write_at(0, &image).unwrap();
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), backend, None));
        (disk, image)
    }

    /// Fast backoff so fault tests don't sleep their way through CI.
    fn fast_retry(max_retries: u32, breaker_threshold: u32, probe_after: u32) -> RetryPolicy {
        RetryPolicy::new(RetryConfig {
            max_retries,
            backoff_base_ms: 0.05,
            backoff_max_ms: 0.2,
            jitter: 0.5,
            breaker_threshold,
            breaker_probe_after: probe_after,
        })
    }

    fn pf_cfg(workers: usize, queue_depth: usize, coalesce_gap: u64) -> PrefetchConfig {
        PrefetchConfig {
            workers,
            queue_depth,
            coalesce_gap,
            // window 1 keeps per-plan counters exact for the assertions
            dispatch_window: 1,
            ..PrefetchConfig::default()
        }
    }

    fn plan(layer: usize, extents: &[(u64, usize)]) -> PreloadPlan {
        let per_seq = vec![(
            0usize,
            extents
                .iter()
                .enumerate()
                .map(|(i, &(offset, len))| PlannedExtent {
                    tag: i as u32,
                    offset,
                    len,
                })
                .collect(),
        )];
        PreloadPlan { layer, per_seq }
    }

    fn check_staged(staged: &StagedLoad, image: &[u8], extents: &[(u64, usize)]) {
        let loads = &staged.per_seq[0].1;
        assert_eq!(loads.len(), extents.len());
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(loads[i].0, i as u32);
            assert_eq!(
                loads[i].1,
                &image[off as usize..off as usize + len],
                "extent {i} at {off}+{len}"
            );
        }
    }

    #[test]
    fn threaded_pipeline_delivers_in_order_with_correct_bytes() {
        let (disk, image) = disk_with_image(1 << 16);
        let cfg = pf_cfg(3, 2, 64);
        let mut p = Prefetcher::spawn(disk, &cfg);
        assert!(!p.is_synchronous());
        assert_eq!(p.breaker_state(), BreakerState::Closed);
        let layouts: Vec<Vec<(u64, usize)>> = (0..6)
            .map(|l| {
                (0..8)
                    .map(|i| ((l * 4096 + i * 300) as u64, 128usize))
                    .collect()
            })
            .collect();
        // interleave submit/recv the way decode does (pipeline depth 2)
        p.submit(plan(0, &layouts[0])).unwrap();
        for l in 0..6 {
            if l + 1 < 6 {
                p.submit(plan(l + 1, &layouts[l + 1])).unwrap();
            }
            let staged = p.recv().unwrap();
            assert_eq!(staged.layer, l, "delivery must follow submission order");
            assert!(staged.io_time > Duration::ZERO);
            check_staged(&staged, &image, &layouts[l]);
        }
        let s = p.summary();
        assert_eq!(s.plans, 6);
        assert_eq!(s.plans_failed, 0);
        assert_eq!(s.extents, 6 * 8);
        // 300-byte stride with 128-byte extents and gap 64 merges nothing;
        // still at most one run per extent
        assert!(s.runs <= s.extents);
        assert!(s.coalesce_factor() >= 1.0);
        // every plan was dispatched on the critical lane
        assert_eq!(s.lane_dispatched[Lane::Critical.idx()], 6);
    }

    #[test]
    fn synchronous_mode_matches_and_flags_empty_recv() {
        let (disk, image) = disk_with_image(1 << 14);
        let mut p = Prefetcher::spawn(disk, &PrefetchConfig::synchronous());
        assert!(p.is_synchronous());
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
        let extents = [(0u64, 256usize), (256, 256), (1024, 128)];
        p.submit(plan(3, &extents)).unwrap();
        let staged = p.recv().unwrap();
        assert_eq!(staged.layer, 3);
        check_staged(&staged, &image, &extents);
        // adjacent first two extents coalesce into one run
        let s = p.summary();
        assert_eq!(s.extents, 3);
        assert_eq!(s.runs, 2);
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
    }

    #[test]
    fn coalesced_read_over_reads_gaps_but_stages_exact_bytes() {
        let (disk, image) = disk_with_image(8192);
        let pool = BufferPool::new(4);
        let counters = PrefetchCounters::default();
        // unsorted, with a small gap and an overlap
        let extents = [(512u64, 64usize), (0, 64), (96, 32), (540, 64)];
        let (chunks, t) = read_coalesced(&disk, &extents, 32, &pool, &counters).unwrap();
        assert!(t > Duration::ZERO);
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(chunks[i], &image[off as usize..off as usize + len]);
        }
        let s = counters.summary();
        assert_eq!(s.extents, 4);
        assert_eq!(s.runs, 2); // {0,96} merge across the 32-gap; {512,540} overlap
        assert_eq!(s.bytes_staged, 64 + 64 + 32 + 64);
        // empty input is a no-op
        let (none, t0) = read_coalesced(&disk, &[], 32, &pool, &counters).unwrap();
        assert!(none.is_empty());
        assert_eq!(t0, Duration::ZERO);
    }

    #[test]
    fn out_of_bounds_plan_surfaces_typed_error() {
        let (disk, _) = disk_with_image(1024);
        let mut p = Prefetcher::spawn(disk, &pf_cfg(1, 1, 0));
        p.submit(plan(0, &[(4096, 64)])).unwrap();
        assert!(matches!(p.recv(), Err(DiskError::OutOfBounds { .. })));
        let s = p.summary();
        assert_eq!(s.plans_failed, 1);
    }

    #[test]
    fn drop_joins_workers_with_inflight_completions() {
        let (disk, _) = disk_with_image(1 << 14);
        let mut p = Prefetcher::spawn(disk, &pf_cfg(2, 2, 0));
        for l in 0..4 {
            p.submit(plan(l, &[(0, 128)])).unwrap();
        }
        // drop without receiving: Drop must drain and join, not hang
        drop(p);
    }

    #[test]
    fn shutdown_is_bounded_and_flags_queue_closed() {
        let (disk, _) = disk_with_image(1 << 14);
        let mut p = Prefetcher::spawn(disk, &pf_cfg(2, 2, 0));
        p.submit(plan(0, &[(0, 128)])).unwrap();
        let t0 = Instant::now();
        p.shutdown(Duration::from_secs(2));
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(matches!(p.submit(plan(1, &[(0, 64)])), Err(DiskError::QueueClosed)));
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
        // idempotent
        p.shutdown(Duration::from_millis(10));
    }

    #[test]
    fn transient_faults_are_retried_to_clean_bytes() {
        let image: Vec<u8> = (0..(1 << 14)).map(|i| (i * 31 % 251) as u8).collect();
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = SimDisk::new(DiskProfile::nvme(), fb.clone(), None);
        disk.write(0, &image).unwrap();
        // fail ops 1 and 2 (first attempt of the second read + its first
        // retry), then succeed
        fb.script_at(1, Fault::TransientIo);
        fb.script_at(2, Fault::TransientIo);
        let pool = BufferPool::new(4);
        let counters = PrefetchCounters::default();
        let retry = fast_retry(3, 4, 8);
        let extents = [(0u64, 256usize), (8192, 256)];
        let (chunks, _) =
            read_coalesced_with(&disk, &extents, 0, &pool, &counters, &retry).unwrap();
        assert_eq!(chunks[0], &image[..256]);
        assert_eq!(chunks[1], &image[8192..8448]);
        let s = counters.summary();
        assert!(s.io_retries >= 2, "retries: {}", s.io_retries);
        assert_eq!(disk.stats().snapshot().read_retries, s.io_retries);
    }

    #[test]
    fn retry_budget_exhaustion_surfaces_the_error() {
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = SimDisk::new(DiskProfile::nvme(), fb.clone(), None);
        disk.write(0, &vec![5u8; 4096]).unwrap();
        fb.poison(0, 4096); // every attempt fails
        let pool = BufferPool::new(2);
        let counters = PrefetchCounters::default();
        let retry = fast_retry(2, 4, 8);
        let err =
            read_coalesced_with(&disk, &[(0, 512)], 0, &pool, &counters, &retry).unwrap_err();
        assert!(matches!(err, DiskError::Io { .. }));
        // 3 re-issues: the budget of 2 allows two more after the first
        assert_eq!(counters.summary().io_retries, 3);
    }

    #[test]
    fn bit_flips_are_detected_and_reread() {
        let image: Vec<u8> = (0..8192).map(|i| (i % 256) as u8).collect();
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = SimDisk::new(DiskProfile::nvme(), fb.clone(), None);
        // stamp a whole-extent record so verification is exact-match
        disk.write(4096, &image[..2048]).unwrap();
        fb.script_at(0, Fault::BitFlip);
        let pool = BufferPool::new(2);
        let counters = PrefetchCounters::default();
        let retry = fast_retry(3, 4, 8);
        let (chunks, _) =
            read_coalesced_with(&disk, &[(4096, 2048)], 0, &pool, &counters, &retry).unwrap();
        assert_eq!(chunks[0], &image[..2048], "re-read must replace flipped bytes");
        let s = counters.summary();
        assert_eq!(s.corrupt_detected, 1);
        assert!(s.io_retries >= 1);
    }

    #[test]
    fn worker_panic_is_contained_and_worker_respawns() {
        let image: Vec<u8> = vec![9u8; 4096];
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), fb.clone(), None));
        disk.write(0, &image).unwrap();
        // threshold high enough that one panic does not trip the breaker
        let mut p = Prefetcher::spawn_with(disk, &pf_cfg(2, 2, 0), fast_retry(0, 8, 8));
        fb.script_at(0, Fault::Panic);
        p.submit(plan(0, &[(0, 256)])).unwrap();
        let err = p.recv().unwrap_err();
        assert!(matches!(err, DiskError::WorkerPanic { .. }), "{err}");
        assert_eq!(p.summary().worker_panics, 1);
        // the pool keeps serving (surviving worker) and the dead thread is
        // respawned by a later submit
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut layer = 1;
        while p.summary().workers_restarted == 0 && Instant::now() < deadline {
            p.submit(plan(layer, &[(0, 256)])).unwrap();
            let staged = p.recv().unwrap();
            assert_eq!(staged.layer, layer);
            layer += 1;
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(p.summary().workers_restarted, 1, "dead worker respawned");
        assert_eq!(p.breaker_state(), BreakerState::Closed);
    }

    #[test]
    fn buffer_pool_recovers_from_poisoned_lock() {
        let pool = Arc::new(BufferPool::new(2));
        pool.put(vec![1, 2, 3]);
        let p2 = pool.clone();
        let _ = std::thread::spawn(move || {
            let _guard = p2.bufs.lock().unwrap();
            panic!("poison the pool lock");
        })
        .join();
        // take/put must recover, not propagate the poison
        let buf = pool.take();
        pool.put(buf);
    }

    #[test]
    fn buffer_pool_drops_oversized_buffers() {
        let pool = BufferPool::with_high_water(8, 1024);
        pool.put(Vec::with_capacity(4096)); // above high water: dropped
        assert_eq!(pool.take().capacity(), 0);
        pool.put(Vec::with_capacity(512)); // under: retained
        assert!(pool.take().capacity() >= 512);
    }

    #[test]
    fn breaker_trips_to_sync_and_recovers_via_probe() {
        let image: Vec<u8> = vec![7u8; 8192];
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), fb.clone(), None));
        disk.write(0, &image).unwrap();
        // no retries, trip after 3 failures, probe after 2 clean sync plans
        let mut p = Prefetcher::spawn_with(disk, &pf_cfg(2, 2, 0), fast_retry(0, 3, 2));
        fb.poison(0, 8192);

        let mut layer = 0;
        let mut submit_recv = |p: &mut Prefetcher, expect_ok: bool| {
            p.submit(plan(layer, &[(0, 512)])).unwrap();
            let r = p.recv();
            assert_eq!(r.is_ok(), expect_ok, "layer {layer}: {r:?}");
            layer += 1;
        };
        for _ in 0..3 {
            submit_recv(&mut p, false);
        }
        assert_eq!(p.breaker_state(), BreakerState::Open, "tripped after 3");
        assert_eq!(p.summary().breaker_trips, 1);

        // open: plans run inline; still failing while the device is sick
        submit_recv(&mut p, false);
        assert_eq!(p.breaker_state(), BreakerState::Open);

        // device recovers: sync plans succeed, then a probe closes it
        fb.heal();
        submit_recv(&mut p, true); // sync success 1
        submit_recv(&mut p, true); // sync success 2
        assert_eq!(p.breaker_state(), BreakerState::Open);
        submit_recv(&mut p, true); // half-open probe through the pool
        assert_eq!(p.breaker_state(), BreakerState::Closed, "probe closed it");

        // fully healthy again
        submit_recv(&mut p, true);
        let s = p.summary();
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.plans_failed, 4);
    }

    #[test]
    fn recv_timeout_abandons_only_that_ticket() {
        let image: Vec<u8> = (0..(1 << 14)).map(|i| (i * 31 % 251) as u8).collect();
        // stall the first read long past the recv timeout, then let
        // everything else through
        let slow = Arc::new(FaultBackend::quiet(Arc::new(MemBackend::new())));
        slow.script_at(0, Fault::LatencySpike(Duration::from_millis(250)));
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), slow, None));
        disk.write(0, &image).unwrap();
        let mut p = Prefetcher::spawn_with(disk, &pf_cfg(1, 2, 0), fast_retry(0, 8, 8));
        p.set_timeout(Duration::from_millis(30));
        p.submit(plan(0, &[(0, 128)])).unwrap(); // will stall past timeout
        p.submit(plan(1, &[(256, 128)])).unwrap();
        assert!(matches!(p.recv(), Err(DiskError::Timeout { .. })));
        // the next ticket still delivers once the stall clears; its stale
        // predecessor's completion is dropped, not delivered out of order
        p.set_timeout(Duration::from_secs(10));
        let staged = p.recv().unwrap();
        assert_eq!(staged.layer, 1);
        assert_eq!(staged.per_seq[0].1[0].1, &image[256..384]);
    }
}
