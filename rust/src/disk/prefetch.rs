//! Asynchronous prefetch pipeline — the paper's overlap of
//! prediction-driven preloads with compute, on *real* storage.
//!
//! A small worker pool consumes per-layer [`PreloadPlan`]s, coalesces the
//! planned group extents into large sequential reads ([`coalesce`]),
//! executes them through [`SimDisk::read_batch`], and stages the bytes
//! into recycled buffers. Completed [`StagedLoad`]s flow back to the
//! engine over a bounded channel; a ticket-numbered reorder buffer
//! restores submission order, so the engine always receives layer *l*'s
//! staging before layer *l+1*'s regardless of worker scheduling.
//!
//! Backpressure is end-to-end: both the job queue and the completion
//! queue are bounded at the configured queue depth, so a stalled engine
//! stops the workers and a slow disk stalls `submit` — staged bytes never
//! pile up beyond ~2×queue-depth buffers (the double-buffering bound).
//!
//! `PrefetchConfig { workers: 0 }` degrades to a *synchronous* pipeline:
//! `submit` only queues the plan and `recv` executes it inline. That mode
//! is the baseline the benches compare against, and the bit-identical
//! reference for the integration tests — both modes run byte-for-byte the
//! same reads, only the threading differs.
//!
//! The workers touch only [`Backend`](super::Backend) + staging memory;
//! nothing device- or runtime-bound (`Rc<PjrtRuntime>` etc.) crosses a
//! thread boundary.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::ReadReq;
use super::coalesce::coalesce;
use super::error::{DiskError, DiskResult};
use super::sim::SimDisk;
use crate::config::PrefetchConfig;

/// One planned group read, tagged so the engine can route the staged
/// bytes to the right cache slot (`tag` is policy-defined: group id,
/// `u32::MAX` for whole-layer staging, …).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedExtent {
    pub tag: u32,
    pub offset: u64,
    pub len: usize,
}

/// The preload work for one layer of one decode step, across the batch.
#[derive(Debug, Clone)]
pub struct PreloadPlan {
    pub layer: usize,
    /// `(sequence index, extents to stage for it)`.
    pub per_seq: Vec<(usize, Vec<PlannedExtent>)>,
}

/// A completed plan: staged bytes per sequence, ready to commit.
#[derive(Debug)]
pub struct StagedLoad {
    pub layer: usize,
    /// `(sequence index, [(tag, bytes)])` in plan order.
    pub per_seq: Vec<(usize, Vec<(u32, Vec<u8>)>)>,
    /// Modeled device time for the whole plan (virtual-clock accounting).
    pub io_time: Duration,
    /// When the plan was submitted — residual wait = how much of
    /// `io_time` was *not* hidden behind compute since this instant.
    pub issued_at: Instant,
}

/// Recycled staging buffers, bounded so double-buffering stays bounded.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    max: usize,
}

impl BufferPool {
    pub fn new(max: usize) -> BufferPool {
        BufferPool {
            bufs: Mutex::new(Vec::new()),
            max,
        }
    }

    pub fn take(&self) -> Vec<u8> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max {
            bufs.push(buf);
        }
    }
}

/// Shared pipeline counters (lives in [`read_coalesced`]'s signature, so
/// it is public; construct with `Default` when calling that directly).
#[derive(Default)]
pub struct PrefetchCounters {
    plans_submitted: AtomicU64,
    plans_completed: AtomicU64,
    extents_requested: AtomicU64,
    runs_issued: AtomicU64,
    bytes_staged: AtomicU64,
}

impl PrefetchCounters {
    pub fn summary(&self) -> PrefetchSummary {
        PrefetchSummary {
            plans: self.plans_completed.load(Ordering::Relaxed),
            extents: self.extents_requested.load(Ordering::Relaxed),
            runs: self.runs_issued.load(Ordering::Relaxed),
            bytes_staged: self.bytes_staged.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        self.plans_submitted.store(0, Ordering::Relaxed);
        self.plans_completed.store(0, Ordering::Relaxed);
        self.extents_requested.store(0, Ordering::Relaxed);
        self.runs_issued.store(0, Ordering::Relaxed);
        self.bytes_staged.store(0, Ordering::Relaxed);
    }
}

/// What the pipeline did over a decode run (reported in `DecodeStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefetchSummary {
    pub plans: u64,
    pub extents: u64,
    pub runs: u64,
    pub bytes_staged: u64,
}

impl PrefetchSummary {
    /// Mean extents merged per issued read (≥ 1.0 once anything ran).
    pub fn coalesce_factor(&self) -> f64 {
        if self.runs == 0 {
            return 1.0;
        }
        self.extents as f64 / self.runs as f64
    }
}

type Job = (u64, PreloadPlan, Instant);
type Completion = (u64, DiskResult<StagedLoad>);

pub struct Prefetcher {
    disk: Arc<SimDisk>,
    gap: u64,
    pool: Arc<BufferPool>,
    counters: Arc<PrefetchCounters>,
    /// `None` ⇒ synchronous mode (reads run inline in `recv`).
    tx: Option<SyncSender<Job>>,
    done_rx: Option<Receiver<Completion>>,
    workers: Vec<JoinHandle<()>>,
    next_ticket: u64,
    next_deliver: u64,
    reordered: BTreeMap<u64, DiskResult<StagedLoad>>,
    sync_queue: VecDeque<Job>,
    timeout: Duration,
}

impl Prefetcher {
    pub fn spawn(disk: Arc<SimDisk>, cfg: &PrefetchConfig) -> Prefetcher {
        let pool = Arc::new(BufferPool::new(2 * cfg.queue_depth.max(1)));
        let counters = Arc::new(PrefetchCounters::default());
        let mut p = Prefetcher {
            disk,
            gap: cfg.coalesce_gap,
            pool,
            counters,
            tx: None,
            done_rx: None,
            workers: Vec::new(),
            next_ticket: 0,
            next_deliver: 0,
            reordered: BTreeMap::new(),
            sync_queue: VecDeque::new(),
            timeout: Duration::from_secs(60),
        };
        if cfg.workers == 0 {
            return p;
        }
        let (tx, job_rx) = sync_channel::<Job>(cfg.queue_depth.max(1));
        let (done_tx, done_rx) = sync_channel::<Completion>(cfg.queue_depth.max(1));
        let job_rx = Arc::new(Mutex::new(job_rx));
        for w in 0..cfg.workers {
            let job_rx = job_rx.clone();
            let done_tx = done_tx.clone();
            let disk = p.disk.clone();
            let pool = p.pool.clone();
            let counters = p.counters.clone();
            let gap = p.gap;
            let handle = std::thread::Builder::new()
                .name(format!("kvswap-prefetch-{w}"))
                .spawn(move || loop {
                    let job = { job_rx.lock().unwrap().recv() };
                    let Ok((ticket, plan, issued_at)) = job else {
                        break;
                    };
                    let result = stage(&disk, &pool, &counters, gap, plan, issued_at);
                    if done_tx.send((ticket, result)).is_err() {
                        break;
                    }
                })
                .expect("spawn prefetch worker");
            p.workers.push(handle);
        }
        // workers hold the only remaining done_tx clones, so done_rx
        // disconnects exactly when the pool is gone
        drop(done_tx);
        p.tx = Some(tx);
        p.done_rx = Some(done_rx);
        p
    }

    pub fn is_synchronous(&self) -> bool {
        self.tx.is_none()
    }

    /// Queue a plan. In threaded mode this blocks once `queue_depth`
    /// plans are in flight (backpressure); in synchronous mode it only
    /// enqueues and the read happens at `recv`.
    pub fn submit(&mut self, plan: PreloadPlan) -> DiskResult<()> {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.counters.plans_submitted.fetch_add(1, Ordering::Relaxed);
        let job = (ticket, plan, Instant::now());
        match &self.tx {
            Some(tx) => tx.send(job).map_err(|_| DiskError::QueueClosed),
            None => {
                self.sync_queue.push_back(job);
                Ok(())
            }
        }
    }

    /// Receive the next staged load, in submission order.
    pub fn recv(&mut self) -> DiskResult<StagedLoad> {
        if self.next_deliver == self.next_ticket {
            // nothing in flight: recv without a matching submit
            return Err(DiskError::QueueClosed);
        }
        let ticket = self.next_deliver;
        if self.tx.is_none() {
            let (t, plan, issued_at) = self.sync_queue.pop_front().ok_or(DiskError::QueueClosed)?;
            debug_assert_eq!(t, ticket);
            self.next_deliver += 1;
            return stage(&self.disk, &self.pool, &self.counters, self.gap, plan, issued_at);
        }
        loop {
            if let Some(result) = self.reordered.remove(&ticket) {
                self.next_deliver += 1;
                return result;
            }
            let rx = self.done_rx.as_ref().ok_or(DiskError::QueueClosed)?;
            match rx.recv_timeout(self.timeout) {
                Ok((t, result)) => {
                    self.reordered.insert(t, result);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(DiskError::Timeout {
                        waited: self.timeout,
                    })
                }
                Err(RecvTimeoutError::Disconnected) => return Err(DiskError::QueueClosed),
            }
        }
    }

    pub fn summary(&self) -> PrefetchSummary {
        self.counters.summary()
    }

    pub fn reset_counters(&self) {
        self.counters.reset();
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // closing the job channel stops idle workers; draining completions
        // unblocks any worker parked in a bounded `send`
        drop(self.tx.take());
        if let Some(rx) = self.done_rx.take() {
            while rx.recv().is_ok() {}
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Execute one plan: flatten extents, read them coalesced, scatter the
/// bytes back per `(sequence, tag)`.
fn stage(
    disk: &SimDisk,
    pool: &BufferPool,
    counters: &PrefetchCounters,
    gap: u64,
    plan: PreloadPlan,
    issued_at: Instant,
) -> DiskResult<StagedLoad> {
    let mut extents: Vec<(u64, usize)> = Vec::new();
    for (_, seq_exts) in &plan.per_seq {
        for e in seq_exts {
            extents.push((e.offset, e.len));
        }
    }
    let (chunks, io_time) = read_coalesced(disk, &extents, gap, pool, counters)?;
    let mut chunks = chunks.into_iter();
    let per_seq = plan
        .per_seq
        .into_iter()
        .map(|(seq, seq_exts)| {
            let loads = seq_exts
                .into_iter()
                .map(|e| (e.tag, chunks.next().expect("chunk per extent")))
                .collect();
            (seq, loads)
        })
        .collect();
    counters.plans_completed.fetch_add(1, Ordering::Relaxed);
    Ok(StagedLoad {
        layer: plan.layer,
        per_seq,
        io_time,
        issued_at,
    })
}

/// Read `extents` through run coalescing: merge near-adjacent extents
/// (byte gap ≤ `gap`) into single [`ReadReq`]s, issue one batched read,
/// then scatter each extent's bytes back out in input order. Returns the
/// per-extent byte chunks plus the modeled device time.
pub fn read_coalesced(
    disk: &SimDisk,
    extents: &[(u64, usize)],
    gap: u64,
    pool: &BufferPool,
    counters: &PrefetchCounters,
) -> DiskResult<(Vec<Vec<u8>>, Duration)> {
    if extents.is_empty() {
        return Ok((Vec::new(), Duration::ZERO));
    }
    let runs = coalesce(extents, gap);
    counters
        .extents_requested
        .fetch_add(extents.len() as u64, Ordering::Relaxed);
    counters
        .runs_issued
        .fetch_add(runs.len() as u64, Ordering::Relaxed);
    disk.stats()
        .record_coalesce(extents.len() as u64, runs.len() as u64);

    let mut reqs: Vec<ReadReq> = runs
        .iter()
        .map(|r| ReadReq::with_buf(r.offset, pool.take(), r.len))
        .collect();
    let io_time = disk.read_batch(&mut reqs)?;

    let mut out: Vec<Vec<u8>> = vec![Vec::new(); extents.len()];
    let mut staged = 0u64;
    for (run, req) in runs.iter().zip(&reqs) {
        for &(idx, delta) in &run.members {
            let len = extents[idx].1;
            out[idx] = req.buf[delta..delta + len].to_vec();
            staged += len as u64;
        }
    }
    counters.bytes_staged.fetch_add(staged, Ordering::Relaxed);
    for req in reqs {
        pool.put(req.buf);
    }
    Ok((out, io_time))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::disk::backend::{Backend, MemBackend};
    use crate::disk::profile::DiskProfile;

    fn disk_with_image(n: usize) -> (Arc<SimDisk>, Vec<u8>) {
        let image: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
        let backend = Arc::new(MemBackend::new());
        backend.write_at(0, &image).unwrap();
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), backend, None));
        (disk, image)
    }

    fn plan(layer: usize, extents: &[(u64, usize)]) -> PreloadPlan {
        let per_seq = vec![(
            0usize,
            extents
                .iter()
                .enumerate()
                .map(|(i, &(offset, len))| PlannedExtent {
                    tag: i as u32,
                    offset,
                    len,
                })
                .collect(),
        )];
        PreloadPlan { layer, per_seq }
    }

    fn check_staged(staged: &StagedLoad, image: &[u8], extents: &[(u64, usize)]) {
        let loads = &staged.per_seq[0].1;
        assert_eq!(loads.len(), extents.len());
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(loads[i].0, i as u32);
            assert_eq!(
                loads[i].1,
                &image[off as usize..off as usize + len],
                "extent {i} at {off}+{len}"
            );
        }
    }

    #[test]
    fn threaded_pipeline_delivers_in_order_with_correct_bytes() {
        let (disk, image) = disk_with_image(1 << 16);
        let cfg = PrefetchConfig {
            workers: 3,
            queue_depth: 2,
            coalesce_gap: 64,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        assert!(!p.is_synchronous());
        let layouts: Vec<Vec<(u64, usize)>> = (0..6)
            .map(|l| {
                (0..8)
                    .map(|i| ((l * 4096 + i * 300) as u64, 128usize))
                    .collect()
            })
            .collect();
        // interleave submit/recv the way decode does (pipeline depth 2)
        p.submit(plan(0, &layouts[0])).unwrap();
        for l in 0..6 {
            if l + 1 < 6 {
                p.submit(plan(l + 1, &layouts[l + 1])).unwrap();
            }
            let staged = p.recv().unwrap();
            assert_eq!(staged.layer, l, "delivery must follow submission order");
            assert!(staged.io_time > Duration::ZERO);
            check_staged(&staged, &image, &layouts[l]);
        }
        let s = p.summary();
        assert_eq!(s.plans, 6);
        assert_eq!(s.extents, 6 * 8);
        // 300-byte stride with 128-byte extents and gap 64 merges nothing;
        // still at most one run per extent
        assert!(s.runs <= s.extents);
        assert!(s.coalesce_factor() >= 1.0);
    }

    #[test]
    fn synchronous_mode_matches_and_flags_empty_recv() {
        let (disk, image) = disk_with_image(1 << 14);
        let mut p = Prefetcher::spawn(disk, &PrefetchConfig::synchronous());
        assert!(p.is_synchronous());
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
        let extents = [(0u64, 256usize), (256, 256), (1024, 128)];
        p.submit(plan(3, &extents)).unwrap();
        let staged = p.recv().unwrap();
        assert_eq!(staged.layer, 3);
        check_staged(&staged, &image, &extents);
        // adjacent first two extents coalesce into one run
        let s = p.summary();
        assert_eq!(s.extents, 3);
        assert_eq!(s.runs, 2);
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
    }

    #[test]
    fn coalesced_read_over_reads_gaps_but_stages_exact_bytes() {
        let (disk, image) = disk_with_image(8192);
        let pool = BufferPool::new(4);
        let counters = PrefetchCounters::default();
        // unsorted, with a small gap and an overlap
        let extents = [(512u64, 64usize), (0, 64), (96, 32), (540, 64)];
        let (chunks, t) = read_coalesced(&disk, &extents, 32, &pool, &counters).unwrap();
        assert!(t > Duration::ZERO);
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(chunks[i], &image[off as usize..off as usize + len]);
        }
        let s = counters.summary();
        assert_eq!(s.extents, 4);
        assert_eq!(s.runs, 2); // {0,96} merge across the 32-gap; {512,540} overlap
        assert_eq!(s.bytes_staged, 64 + 64 + 32 + 64);
        // empty input is a no-op
        let (none, t0) = read_coalesced(&disk, &[], 32, &pool, &counters).unwrap();
        assert!(none.is_empty());
        assert_eq!(t0, Duration::ZERO);
    }

    #[test]
    fn out_of_bounds_plan_surfaces_typed_error() {
        let (disk, _) = disk_with_image(1024);
        let cfg = PrefetchConfig {
            workers: 1,
            queue_depth: 1,
            coalesce_gap: 0,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        p.submit(plan(0, &[(4096, 64)])).unwrap();
        assert!(matches!(p.recv(), Err(DiskError::OutOfBounds { .. })));
    }

    #[test]
    fn drop_joins_workers_with_inflight_completions() {
        let (disk, _) = disk_with_image(1 << 14);
        let cfg = PrefetchConfig {
            workers: 2,
            queue_depth: 2,
            coalesce_gap: 0,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        for l in 0..4 {
            p.submit(plan(l, &[(0, 128)])).unwrap();
        }
        // drop without receiving: Drop must drain and join, not hang
        drop(p);
    }
}
