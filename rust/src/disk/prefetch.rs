//! Asynchronous prefetch pipeline — the paper's overlap of
//! prediction-driven preloads with compute, on *real* storage.
//!
//! A small worker pool consumes per-layer [`PreloadPlan`]s, coalesces the
//! planned group extents into large sequential reads ([`coalesce`]),
//! executes them through [`SimDisk::read_batch`], and stages the bytes
//! into recycled buffers. Completed [`StagedLoad`]s flow back to the
//! engine over a bounded channel; a ticket-numbered reorder buffer
//! restores submission order, so the engine always receives layer *l*'s
//! staging before layer *l+1*'s regardless of worker scheduling.
//!
//! Backpressure is end-to-end: both the job queue and the completion
//! queue are bounded at the configured queue depth, so a stalled engine
//! stops the workers and a slow disk stalls `submit` — staged bytes never
//! pile up beyond ~2×queue-depth buffers (the double-buffering bound).
//!
//! `PrefetchConfig { workers: 0 }` degrades to a *synchronous* pipeline:
//! `submit` only queues the plan and `recv` executes it inline. That mode
//! is the baseline the benches compare against, and the bit-identical
//! reference for the integration tests — both modes run byte-for-byte the
//! same reads, only the threading differs.
//!
//! ## Failure handling
//!
//! The pipeline assumes storage misbehaves (see [`super#failure-model--degradation-ladder`]):
//!
//! * staging reads retry failed runs under a per-plan [`RetryPolicy`]
//!   budget and verify extent checksums before scattering bytes out;
//! * a worker panic is caught, surfaced as `DiskError::WorkerPanic` for
//!   *that plan only*, and the worker thread is recycled — `submit`
//!   respawns finished workers;
//! * a [`CircuitBreaker`] watches threaded plan outcomes: past
//!   `breaker_threshold` consecutive failures it routes new plans through
//!   the synchronous inline path (trading overlap for isolation from a
//!   sick worker pool), and after `breaker_probe_after` clean inline
//!   plans it sends a half-open probe back through the pool;
//! * `shutdown` bounds its drain/join by a grace period and leaves the
//!   pipeline returning `QueueClosed` instead of hanging on a wedged
//!   worker; a `recv` timeout abandons only that ticket.
//!
//! The workers touch only [`Backend`](super::Backend) + staging memory;
//! nothing device- or runtime-bound (`Rc<PjrtRuntime>` etc.) crosses a
//! thread boundary.
//!
//! This pool overlaps *decode* I/O with compute. Prefill has a second,
//! independent overlapped stream: the engine's store-restore worker
//! (`coordinator::engine`) streams persistent-store chunks under prefill
//! compute with the same thread-boundary rule and the same residual
//! `Phase::IoWait` accounting convention — only the stall compute failed
//! to hide is charged.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::ReadReq;
use super::coalesce::{coalesce, Run};
use super::error::{DiskError, DiskResult};
use super::relock;
use super::retry::RetryPolicy;
use super::sim::SimDisk;
use crate::config::PrefetchConfig;

/// One planned group read, tagged so the engine can route the staged
/// bytes to the right cache slot (`tag` is policy-defined: group id,
/// `u32::MAX` for whole-layer staging, …).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedExtent {
    pub tag: u32,
    pub offset: u64,
    pub len: usize,
}

/// The preload work for one layer of one decode step, across the batch.
#[derive(Debug, Clone)]
pub struct PreloadPlan {
    pub layer: usize,
    /// `(sequence index, extents to stage for it)`.
    pub per_seq: Vec<(usize, Vec<PlannedExtent>)>,
}

/// A completed plan: staged bytes per sequence, ready to commit.
#[derive(Debug)]
pub struct StagedLoad {
    pub layer: usize,
    /// `(sequence index, [(tag, bytes)])` in plan order.
    pub per_seq: Vec<(usize, Vec<(u32, Vec<u8>)>)>,
    /// Modeled device time for the whole plan (virtual-clock accounting).
    pub io_time: Duration,
    /// When the plan was submitted — residual wait = how much of
    /// `io_time` was *not* hidden behind compute since this instant.
    pub issued_at: Instant,
}

/// Recycled staging buffers, bounded so double-buffering stays bounded.
/// Locks recover from poisoning: a panicking worker must not take the
/// pool (and with it the engine thread) down with it.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    max: usize,
}

impl BufferPool {
    pub fn new(max: usize) -> BufferPool {
        BufferPool {
            bufs: Mutex::new(Vec::new()),
            max,
        }
    }

    pub fn take(&self) -> Vec<u8> {
        relock(&self.bufs).pop().unwrap_or_default()
    }

    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        let mut bufs = relock(&self.bufs);
        if bufs.len() < self.max {
            bufs.push(buf);
        }
    }
}

/// Shared pipeline counters (lives in [`read_coalesced`]'s signature, so
/// it is public; construct with `Default` when calling that directly).
#[derive(Default)]
pub struct PrefetchCounters {
    plans_submitted: AtomicU64,
    plans_completed: AtomicU64,
    plans_failed: AtomicU64,
    extents_requested: AtomicU64,
    runs_issued: AtomicU64,
    bytes_staged: AtomicU64,
    io_retries: AtomicU64,
    corrupt_detected: AtomicU64,
    worker_panics: AtomicU64,
    workers_restarted: AtomicU64,
    breaker_trips: AtomicU64,
}

impl PrefetchCounters {
    pub fn summary(&self) -> PrefetchSummary {
        PrefetchSummary {
            plans: self.plans_completed.load(Ordering::Relaxed),
            plans_failed: self.plans_failed.load(Ordering::Relaxed),
            extents: self.extents_requested.load(Ordering::Relaxed),
            runs: self.runs_issued.load(Ordering::Relaxed),
            bytes_staged: self.bytes_staged.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
            corrupt_detected: self.corrupt_detected.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            workers_restarted: self.workers_restarted.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        self.plans_submitted.store(0, Ordering::Relaxed);
        self.plans_completed.store(0, Ordering::Relaxed);
        self.plans_failed.store(0, Ordering::Relaxed);
        self.extents_requested.store(0, Ordering::Relaxed);
        self.runs_issued.store(0, Ordering::Relaxed);
        self.bytes_staged.store(0, Ordering::Relaxed);
        self.io_retries.store(0, Ordering::Relaxed);
        self.corrupt_detected.store(0, Ordering::Relaxed);
        self.worker_panics.store(0, Ordering::Relaxed);
        self.workers_restarted.store(0, Ordering::Relaxed);
        self.breaker_trips.store(0, Ordering::Relaxed);
    }
}

/// What the pipeline did over a decode run (reported in `DecodeStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefetchSummary {
    pub plans: u64,
    /// Plans that ultimately failed (retry budget exhausted / timeout /
    /// contained worker panic) and were reported to the engine as errors.
    pub plans_failed: u64,
    pub extents: u64,
    pub runs: u64,
    pub bytes_staged: u64,
    /// Coalesced runs re-issued after a retryable failure.
    pub io_retries: u64,
    /// Checksum mismatches caught before bytes reached the engine.
    pub corrupt_detected: u64,
    /// Worker panics contained by the supervision layer.
    pub worker_panics: u64,
    /// Worker threads respawned after dying.
    pub workers_restarted: u64,
    /// Times the circuit breaker tripped the pipeline into sync routing.
    pub breaker_trips: u64,
}

impl PrefetchSummary {
    /// Mean extents merged per issued read (≥ 1.0 once anything ran).
    pub fn coalesce_factor(&self) -> f64 {
        if self.runs == 0 {
            return 1.0;
        }
        self.extents as f64 / self.runs as f64
    }
}

/// Circuit-breaker state over the threaded pipeline (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: plans route through the worker pool.
    Closed,
    /// Tripped: plans route through the synchronous inline path.
    Open,
    /// One probe plan is in flight through the pool; everything else
    /// stays inline until its verdict.
    HalfOpen,
}

impl BreakerState {
    /// Stable lower-case label for logs and the serve `stats` line.
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Consecutive-failure breaker with half-open probing. Not a separate
/// thread — driven entirely by `submit` (routing) and `recv` (outcomes),
/// so it adds no synchronization to the hot path.
#[derive(Debug)]
struct CircuitBreaker {
    threshold: u32,
    probe_after: u32,
    state: BreakerState,
    consecutive_failures: u32,
    sync_successes: u32,
    probe_ticket: Option<u64>,
}

impl CircuitBreaker {
    fn new(threshold: u32, probe_after: u32) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            probe_after: probe_after.max(1),
            state: BreakerState::Closed,
            consecutive_failures: 0,
            sync_successes: 0,
            probe_ticket: None,
        }
    }

    fn state(&self) -> BreakerState {
        self.state
    }

    /// Routing decision for a new ticket: `true` = worker pool.
    fn route_threaded(&mut self, ticket: u64) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if self.sync_successes >= self.probe_after {
                    self.state = BreakerState::HalfOpen;
                    self.probe_ticket = Some(ticket);
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => false,
        }
    }

    fn on_result(&mut self, ticket: u64, threaded: bool, ok: bool, counters: &PrefetchCounters) {
        if ok {
            match self.state {
                BreakerState::HalfOpen if threaded && self.probe_ticket == Some(ticket) => {
                    // probe survived: the pool is healthy again
                    self.state = BreakerState::Closed;
                    self.consecutive_failures = 0;
                    self.sync_successes = 0;
                    self.probe_ticket = None;
                }
                BreakerState::Closed if threaded => self.consecutive_failures = 0,
                BreakerState::Open if !threaded => self.sync_successes += 1,
                _ => {}
            }
        } else {
            match self.state {
                BreakerState::Closed => {
                    if threaded {
                        self.consecutive_failures += 1;
                        if self.consecutive_failures >= self.threshold {
                            self.state = BreakerState::Open;
                            self.sync_successes = 0;
                            counters.breaker_trips.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                BreakerState::HalfOpen => {
                    // probe (or a straggler) failed: stay away from the pool
                    self.state = BreakerState::Open;
                    self.sync_successes = 0;
                    self.probe_ticket = None;
                }
                BreakerState::Open => self.sync_successes = 0,
            }
        }
    }
}

type Job = (u64, PreloadPlan, Instant);
type Completion = (u64, DiskResult<StagedLoad>);

/// Everything a staging call needs — shared by the engine thread (sync
/// path) and every worker, and cheap to clone into respawned workers.
#[derive(Clone)]
struct StageCtx {
    disk: Arc<SimDisk>,
    pool: Arc<BufferPool>,
    counters: Arc<PrefetchCounters>,
    gap: u64,
    retry: Arc<RetryPolicy>,
}

pub struct Prefetcher {
    ctx: StageCtx,
    /// `None` ⇒ synchronous mode (reads run inline in `recv`).
    tx: Option<SyncSender<Job>>,
    done_rx: Option<Receiver<Completion>>,
    /// Kept so `ensure_workers` can hand a sender to respawned workers;
    /// dropped at shutdown so the completion drain can disconnect.
    done_tx: Option<SyncSender<Completion>>,
    job_rx: Option<Arc<Mutex<Receiver<Job>>>>,
    workers: Vec<JoinHandle<()>>,
    breaker: CircuitBreaker,
    /// ticket → routed-through-pool? (decided at submit, consumed at recv)
    routes: BTreeMap<u64, bool>,
    next_ticket: u64,
    next_deliver: u64,
    reordered: BTreeMap<u64, DiskResult<StagedLoad>>,
    sync_queue: VecDeque<Job>,
    timeout: Duration,
    grace: Duration,
    closed: bool,
}

impl Prefetcher {
    pub fn spawn(disk: Arc<SimDisk>, cfg: &PrefetchConfig) -> Prefetcher {
        Prefetcher::spawn_with(disk, cfg, RetryPolicy::default())
    }

    /// Spawn with an explicit retry/breaker policy (the engine builds the
    /// policy from its validated `RetryConfig`).
    pub fn spawn_with(disk: Arc<SimDisk>, cfg: &PrefetchConfig, retry: RetryPolicy) -> Prefetcher {
        let rc = retry.config();
        let breaker = CircuitBreaker::new(rc.breaker_threshold, rc.breaker_probe_after);
        let ctx = StageCtx {
            disk,
            pool: Arc::new(BufferPool::new(2 * cfg.queue_depth.max(1))),
            counters: Arc::new(PrefetchCounters::default()),
            gap: cfg.coalesce_gap,
            retry: Arc::new(retry),
        };
        let mut p = Prefetcher {
            ctx,
            tx: None,
            done_rx: None,
            done_tx: None,
            job_rx: None,
            workers: Vec::new(),
            breaker,
            routes: BTreeMap::new(),
            next_ticket: 0,
            next_deliver: 0,
            reordered: BTreeMap::new(),
            sync_queue: VecDeque::new(),
            timeout: Duration::from_secs(60),
            grace: Duration::from_secs(5),
            closed: false,
        };
        if cfg.workers == 0 {
            return p;
        }
        let (tx, job_rx) = sync_channel::<Job>(cfg.queue_depth.max(1));
        let (done_tx, done_rx) = sync_channel::<Completion>(cfg.queue_depth.max(1));
        let job_rx = Arc::new(Mutex::new(job_rx));
        for w in 0..cfg.workers {
            p.workers
                .push(spawn_worker(w, job_rx.clone(), done_tx.clone(), p.ctx.clone()));
        }
        p.tx = Some(tx);
        p.done_rx = Some(done_rx);
        p.done_tx = Some(done_tx);
        p.job_rx = Some(job_rx);
        p
    }

    pub fn is_synchronous(&self) -> bool {
        self.tx.is_none()
    }

    /// Current breaker state (`Closed` = fully threaded routing).
    pub fn breaker_state(&self) -> BreakerState {
        self.breaker.state()
    }

    /// Bound on how long `recv` waits for a staged load before abandoning
    /// the ticket with `DiskError::Timeout`.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Queue a plan. In threaded mode this blocks once `queue_depth`
    /// plans are in flight (backpressure); in synchronous mode — or while
    /// the breaker is open — it only enqueues and the read happens at
    /// `recv`.
    pub fn submit(&mut self, plan: PreloadPlan) -> DiskResult<()> {
        if self.closed {
            return Err(DiskError::QueueClosed);
        }
        let ticket = self.next_ticket;
        let job = (ticket, plan, Instant::now());
        let threaded = self.tx.is_some() && self.breaker.route_threaded(ticket);
        if threaded {
            self.ensure_workers();
            let tx = self.tx.as_ref().expect("threaded route requires tx");
            tx.send(job).map_err(|_| DiskError::QueueClosed)?;
        } else {
            self.sync_queue.push_back(job);
        }
        self.routes.insert(ticket, threaded);
        self.next_ticket += 1;
        self.ctx
            .counters
            .plans_submitted
            .fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Receive the next staged load, in submission order. A plan whose
    /// staging ultimately failed yields its typed error here; the ticket
    /// is consumed either way, so later plans still deliver.
    pub fn recv(&mut self) -> DiskResult<StagedLoad> {
        if self.closed {
            return Err(DiskError::QueueClosed);
        }
        if self.next_deliver == self.next_ticket {
            // nothing in flight: recv without a matching submit
            return Err(DiskError::QueueClosed);
        }
        let ticket = self.next_deliver;
        let threaded = self.routes.remove(&ticket).unwrap_or(self.tx.is_some());
        let result = if threaded {
            self.recv_threaded(ticket)
        } else {
            self.run_sync(ticket)
        };
        self.breaker
            .on_result(ticket, threaded, result.is_ok(), &self.ctx.counters);
        if result.is_err() {
            self.ctx.counters.plans_failed.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn run_sync(&mut self, ticket: u64) -> DiskResult<StagedLoad> {
        let (t, plan, issued_at) = self.sync_queue.pop_front().ok_or(DiskError::QueueClosed)?;
        debug_assert_eq!(t, ticket);
        self.next_deliver += 1;
        stage_caught(&self.ctx, plan, issued_at)
    }

    fn recv_threaded(&mut self, ticket: u64) -> DiskResult<StagedLoad> {
        loop {
            if let Some(result) = self.reordered.remove(&ticket) {
                self.next_deliver += 1;
                return result;
            }
            let rx = self.done_rx.as_ref().ok_or(DiskError::QueueClosed)?;
            match rx.recv_timeout(self.timeout) {
                Ok((t, result)) => {
                    // completions for abandoned tickets are stale: drop them
                    if t >= self.next_deliver {
                        self.reordered.insert(t, result);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // abandon this ticket so later plans still deliver;
                    // its completion, if it ever lands, is dropped above
                    self.next_deliver += 1;
                    return Err(DiskError::Timeout {
                        waited: self.timeout,
                    });
                }
                Err(RecvTimeoutError::Disconnected) => return Err(DiskError::QueueClosed),
            }
        }
    }

    /// Respawn any worker whose thread has exited (a contained panic
    /// recycles the thread; see `spawn_worker`). Called from `submit`
    /// before handing a job to the pool.
    fn ensure_workers(&mut self) {
        let (Some(job_rx), Some(done_tx)) = (self.job_rx.clone(), self.done_tx.clone()) else {
            return;
        };
        for i in 0..self.workers.len() {
            if self.workers[i].is_finished() {
                let fresh = spawn_worker(i, job_rx.clone(), done_tx.clone(), self.ctx.clone());
                let dead = std::mem::replace(&mut self.workers[i], fresh);
                let _ = dead.join();
                self.ctx
                    .counters
                    .workers_restarted
                    .fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Close the pipeline: refuse new work, drain in-flight completions,
    /// and join workers — all bounded by `grace`. A worker that outlives
    /// the grace period is detached rather than hanging shutdown; later
    /// `submit`/`recv` calls return `QueueClosed`.
    pub fn shutdown(&mut self, grace: Duration) {
        self.closed = true;
        // closing the job channel stops idle workers; dropping our
        // completion sender lets the drain below observe disconnection
        // once every worker is gone
        drop(self.tx.take());
        drop(self.done_tx.take());
        let deadline = Instant::now() + grace;
        if let Some(rx) = self.done_rx.take() {
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(_) => {}
                    Err(_) => break, // disconnected (all workers exited) or out of grace
                }
            }
        }
        for h in self.workers.drain(..) {
            while !h.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            if h.is_finished() {
                let _ = h.join();
            }
            // else: detach — a wedged worker must not hang shutdown
        }
        self.job_rx = None;
        self.sync_queue.clear();
        self.reordered.clear();
        self.routes.clear();
    }

    pub fn summary(&self) -> PrefetchSummary {
        self.ctx.counters.summary()
    }

    pub fn reset_counters(&self) {
        self.ctx.counters.reset();
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let grace = self.grace;
        self.shutdown(grace);
    }
}

fn spawn_worker(
    idx: usize,
    job_rx: Arc<Mutex<Receiver<Job>>>,
    done_tx: SyncSender<Completion>,
    ctx: StageCtx,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("kvswap-prefetch-{idx}"))
        .spawn(move || loop {
            let job = { relock(&job_rx).recv() };
            let Ok((ticket, plan, issued_at)) = job else {
                break;
            };
            let result = stage_caught(&ctx, plan, issued_at);
            // a thread that panicked once is recycled after delivering
            // the typed error; `ensure_workers` respawns it
            let panicked = matches!(&result, Err(DiskError::WorkerPanic { .. }));
            if done_tx.send((ticket, result)).is_err() || panicked {
                break;
            }
        })
        .expect("spawn prefetch worker")
}

/// Run [`stage`] with panic containment: a panicking backend (or a bug in
/// the staging path) becomes a typed `WorkerPanic` error for this plan
/// instead of unwinding through the pool or the engine thread.
fn stage_caught(ctx: &StageCtx, plan: PreloadPlan, issued_at: Instant) -> DiskResult<StagedLoad> {
    match catch_unwind(AssertUnwindSafe(|| stage(ctx, plan, issued_at))) {
        Ok(result) => result,
        Err(payload) => {
            ctx.counters.worker_panics.fetch_add(1, Ordering::Relaxed);
            let what = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(DiskError::WorkerPanic { what })
        }
    }
}

/// Execute one plan: flatten extents, read them coalesced (with retries
/// and checksum verification), scatter the bytes back per
/// `(sequence, tag)`.
fn stage(ctx: &StageCtx, plan: PreloadPlan, issued_at: Instant) -> DiskResult<StagedLoad> {
    let mut extents: Vec<(u64, usize)> = Vec::new();
    for (_, seq_exts) in &plan.per_seq {
        for e in seq_exts {
            extents.push((e.offset, e.len));
        }
    }
    let (chunks, io_time) =
        read_coalesced_with(&ctx.disk, &extents, ctx.gap, &ctx.pool, &ctx.counters, &ctx.retry)?;
    let mut chunks = chunks.into_iter();
    let per_seq = plan
        .per_seq
        .into_iter()
        .map(|(seq, seq_exts)| {
            let loads = seq_exts
                .into_iter()
                .map(|e| (e.tag, chunks.next().expect("chunk per extent")))
                .collect();
            (seq, loads)
        })
        .collect();
    ctx.counters.plans_completed.fetch_add(1, Ordering::Relaxed);
    Ok(StagedLoad {
        layer: plan.layer,
        per_seq,
        io_time,
        issued_at,
    })
}

/// [`read_coalesced_with`] under the default retry policy — kept as the
/// stable entry point for callers outside the pipeline.
pub fn read_coalesced(
    disk: &SimDisk,
    extents: &[(u64, usize)],
    gap: u64,
    pool: &BufferPool,
    counters: &PrefetchCounters,
) -> DiskResult<(Vec<Vec<u8>>, Duration)> {
    read_coalesced_with(disk, extents, gap, pool, counters, &RetryPolicy::default())
}

/// Read `extents` through run coalescing: merge near-adjacent extents
/// (byte gap ≤ `gap`) into single [`ReadReq`]s, issue one batched read,
/// then scatter each extent's bytes back out in input order. Returns the
/// per-extent byte chunks plus the modeled device time.
///
/// Fault tolerance: the first attempt is one batched submission (keeping
/// the modeled queue-depth overlap); staged extents are then verified
/// against their write-time checksums. Runs that failed — batched error
/// or checksum mismatch — are re-issued individually under the plan's
/// retry budget with jittered exponential backoff. Bytes reach the
/// caller only after every covering run has read and verified clean.
pub fn read_coalesced_with(
    disk: &SimDisk,
    extents: &[(u64, usize)],
    gap: u64,
    pool: &BufferPool,
    counters: &PrefetchCounters,
    retry: &RetryPolicy,
) -> DiskResult<(Vec<Vec<u8>>, Duration)> {
    if extents.is_empty() {
        return Ok((Vec::new(), Duration::ZERO));
    }
    let runs = coalesce(extents, gap);
    counters
        .extents_requested
        .fetch_add(extents.len() as u64, Ordering::Relaxed);
    counters
        .runs_issued
        .fetch_add(runs.len() as u64, Ordering::Relaxed);
    disk.stats()
        .record_coalesce(extents.len() as u64, runs.len() as u64);

    let mut reqs: Vec<ReadReq> = runs
        .iter()
        .map(|r| ReadReq::with_buf(r.offset, pool.take(), r.len))
        .collect();
    let mut io_time = Duration::ZERO;
    let mut budget = retry.budget();

    // First attempt: the whole plan as one batched submission.
    let pending: Vec<usize> = match disk.read_batch(&mut reqs) {
        Ok(d) => {
            io_time += d;
            (0..runs.len())
                .filter(|&ri| verify_run(disk, &runs[ri], &reqs[ri], extents, counters).is_err())
                .collect()
        }
        Err(e) if e.is_retryable() => (0..runs.len()).collect(),
        Err(e) => return Err(e),
    };

    // Recovery: re-issue only the failed runs, individually, under the
    // per-plan budget. Every read here is a re-issue of a run that
    // already failed once (batched error or checksum mismatch), so each
    // counts as a retry whether or not it succeeds.
    for ri in pending {
        let mut attempt = 0u32;
        loop {
            counters.io_retries.fetch_add(1, Ordering::Relaxed);
            disk.stats().record_retry();
            let read = disk.read_batch(std::slice::from_mut(&mut reqs[ri]));
            let verified = read.and_then(|d| {
                verify_run(disk, &runs[ri], &reqs[ri], extents, counters)?;
                Ok(d)
            });
            match verified {
                Ok(d) => {
                    io_time += d;
                    break;
                }
                Err(e) => {
                    if !e.is_retryable() || !budget.try_consume() {
                        return Err(e);
                    }
                    retry.sleep_before_retry(attempt);
                    attempt += 1;
                }
            }
        }
    }

    let mut out: Vec<Vec<u8>> = vec![Vec::new(); extents.len()];
    let mut staged = 0u64;
    for (run, req) in runs.iter().zip(&reqs) {
        for &(idx, delta) in &run.members {
            let len = extents[idx].1;
            out[idx] = req.buf[delta..delta + len].to_vec();
            staged += len as u64;
        }
    }
    counters.bytes_staged.fetch_add(staged, Ordering::Relaxed);
    for req in reqs {
        pool.put(req.buf);
    }
    Ok((out, io_time))
}

/// Verify every member extent of `run` against its write-time checksum.
/// Extents the disk never stamped at exactly that (offset, len) pass.
fn verify_run(
    disk: &SimDisk,
    run: &Run,
    req: &ReadReq,
    extents: &[(u64, usize)],
    counters: &PrefetchCounters,
) -> DiskResult<()> {
    for &(idx, delta) in &run.members {
        let (offset, len) = extents[idx];
        if let Err(e) = disk.verify_extent(offset, &req.buf[delta..delta + len]) {
            counters.corrupt_detected.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RetryConfig;
    use crate::disk::backend::{Backend, MemBackend};
    use crate::disk::fault::{Fault, FaultBackend};
    use crate::disk::profile::DiskProfile;

    fn disk_with_image(n: usize) -> (Arc<SimDisk>, Vec<u8>) {
        let image: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
        let backend = Arc::new(MemBackend::new());
        backend.write_at(0, &image).unwrap();
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), backend, None));
        (disk, image)
    }

    /// Fast backoff so fault tests don't sleep their way through CI.
    fn fast_retry(max_retries: u32, breaker_threshold: u32, probe_after: u32) -> RetryPolicy {
        RetryPolicy::new(RetryConfig {
            max_retries,
            backoff_base_ms: 0.05,
            backoff_max_ms: 0.2,
            jitter: 0.5,
            breaker_threshold,
            breaker_probe_after: probe_after,
        })
    }

    fn plan(layer: usize, extents: &[(u64, usize)]) -> PreloadPlan {
        let per_seq = vec![(
            0usize,
            extents
                .iter()
                .enumerate()
                .map(|(i, &(offset, len))| PlannedExtent {
                    tag: i as u32,
                    offset,
                    len,
                })
                .collect(),
        )];
        PreloadPlan { layer, per_seq }
    }

    fn check_staged(staged: &StagedLoad, image: &[u8], extents: &[(u64, usize)]) {
        let loads = &staged.per_seq[0].1;
        assert_eq!(loads.len(), extents.len());
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(loads[i].0, i as u32);
            assert_eq!(
                loads[i].1,
                &image[off as usize..off as usize + len],
                "extent {i} at {off}+{len}"
            );
        }
    }

    #[test]
    fn threaded_pipeline_delivers_in_order_with_correct_bytes() {
        let (disk, image) = disk_with_image(1 << 16);
        let cfg = PrefetchConfig {
            workers: 3,
            queue_depth: 2,
            coalesce_gap: 64,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        assert!(!p.is_synchronous());
        assert_eq!(p.breaker_state(), BreakerState::Closed);
        let layouts: Vec<Vec<(u64, usize)>> = (0..6)
            .map(|l| {
                (0..8)
                    .map(|i| ((l * 4096 + i * 300) as u64, 128usize))
                    .collect()
            })
            .collect();
        // interleave submit/recv the way decode does (pipeline depth 2)
        p.submit(plan(0, &layouts[0])).unwrap();
        for l in 0..6 {
            if l + 1 < 6 {
                p.submit(plan(l + 1, &layouts[l + 1])).unwrap();
            }
            let staged = p.recv().unwrap();
            assert_eq!(staged.layer, l, "delivery must follow submission order");
            assert!(staged.io_time > Duration::ZERO);
            check_staged(&staged, &image, &layouts[l]);
        }
        let s = p.summary();
        assert_eq!(s.plans, 6);
        assert_eq!(s.plans_failed, 0);
        assert_eq!(s.extents, 6 * 8);
        // 300-byte stride with 128-byte extents and gap 64 merges nothing;
        // still at most one run per extent
        assert!(s.runs <= s.extents);
        assert!(s.coalesce_factor() >= 1.0);
    }

    #[test]
    fn synchronous_mode_matches_and_flags_empty_recv() {
        let (disk, image) = disk_with_image(1 << 14);
        let mut p = Prefetcher::spawn(disk, &PrefetchConfig::synchronous());
        assert!(p.is_synchronous());
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
        let extents = [(0u64, 256usize), (256, 256), (1024, 128)];
        p.submit(plan(3, &extents)).unwrap();
        let staged = p.recv().unwrap();
        assert_eq!(staged.layer, 3);
        check_staged(&staged, &image, &extents);
        // adjacent first two extents coalesce into one run
        let s = p.summary();
        assert_eq!(s.extents, 3);
        assert_eq!(s.runs, 2);
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
    }

    #[test]
    fn coalesced_read_over_reads_gaps_but_stages_exact_bytes() {
        let (disk, image) = disk_with_image(8192);
        let pool = BufferPool::new(4);
        let counters = PrefetchCounters::default();
        // unsorted, with a small gap and an overlap
        let extents = [(512u64, 64usize), (0, 64), (96, 32), (540, 64)];
        let (chunks, t) = read_coalesced(&disk, &extents, 32, &pool, &counters).unwrap();
        assert!(t > Duration::ZERO);
        for (i, &(off, len)) in extents.iter().enumerate() {
            assert_eq!(chunks[i], &image[off as usize..off as usize + len]);
        }
        let s = counters.summary();
        assert_eq!(s.extents, 4);
        assert_eq!(s.runs, 2); // {0,96} merge across the 32-gap; {512,540} overlap
        assert_eq!(s.bytes_staged, 64 + 64 + 32 + 64);
        // empty input is a no-op
        let (none, t0) = read_coalesced(&disk, &[], 32, &pool, &counters).unwrap();
        assert!(none.is_empty());
        assert_eq!(t0, Duration::ZERO);
    }

    #[test]
    fn out_of_bounds_plan_surfaces_typed_error() {
        let (disk, _) = disk_with_image(1024);
        let cfg = PrefetchConfig {
            workers: 1,
            queue_depth: 1,
            coalesce_gap: 0,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        p.submit(plan(0, &[(4096, 64)])).unwrap();
        assert!(matches!(p.recv(), Err(DiskError::OutOfBounds { .. })));
        let s = p.summary();
        assert_eq!(s.plans_failed, 1);
    }

    #[test]
    fn drop_joins_workers_with_inflight_completions() {
        let (disk, _) = disk_with_image(1 << 14);
        let cfg = PrefetchConfig {
            workers: 2,
            queue_depth: 2,
            coalesce_gap: 0,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        for l in 0..4 {
            p.submit(plan(l, &[(0, 128)])).unwrap();
        }
        // drop without receiving: Drop must drain and join, not hang
        drop(p);
    }

    #[test]
    fn shutdown_is_bounded_and_flags_queue_closed() {
        let (disk, _) = disk_with_image(1 << 14);
        let cfg = PrefetchConfig {
            workers: 2,
            queue_depth: 2,
            coalesce_gap: 0,
        };
        let mut p = Prefetcher::spawn(disk, &cfg);
        p.submit(plan(0, &[(0, 128)])).unwrap();
        let t0 = Instant::now();
        p.shutdown(Duration::from_secs(2));
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(matches!(p.submit(plan(1, &[(0, 64)])), Err(DiskError::QueueClosed)));
        assert!(matches!(p.recv(), Err(DiskError::QueueClosed)));
        // idempotent
        p.shutdown(Duration::from_millis(10));
    }

    #[test]
    fn transient_faults_are_retried_to_clean_bytes() {
        let image: Vec<u8> = (0..(1 << 14)).map(|i| (i * 31 % 251) as u8).collect();
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = SimDisk::new(DiskProfile::nvme(), fb.clone(), None);
        disk.write(0, &image).unwrap();
        // fail ops 1 and 2 (first attempt of the second read + its first
        // retry), then succeed
        fb.script_at(1, Fault::TransientIo);
        fb.script_at(2, Fault::TransientIo);
        let pool = BufferPool::new(4);
        let counters = PrefetchCounters::default();
        let retry = fast_retry(3, 4, 8);
        let extents = [(0u64, 256usize), (8192, 256)];
        let (chunks, _) =
            read_coalesced_with(&disk, &extents, 0, &pool, &counters, &retry).unwrap();
        assert_eq!(chunks[0], &image[..256]);
        assert_eq!(chunks[1], &image[8192..8448]);
        let s = counters.summary();
        assert!(s.io_retries >= 2, "retries: {}", s.io_retries);
        assert_eq!(disk.stats().snapshot().read_retries, s.io_retries);
    }

    #[test]
    fn retry_budget_exhaustion_surfaces_the_error() {
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = SimDisk::new(DiskProfile::nvme(), fb.clone(), None);
        disk.write(0, &vec![5u8; 4096]).unwrap();
        fb.poison(0, 4096); // every attempt fails
        let pool = BufferPool::new(2);
        let counters = PrefetchCounters::default();
        let retry = fast_retry(2, 4, 8);
        let err =
            read_coalesced_with(&disk, &[(0, 512)], 0, &pool, &counters, &retry).unwrap_err();
        assert!(matches!(err, DiskError::Io { .. }));
        // 3 re-issues: the budget of 2 allows two more after the first
        assert_eq!(counters.summary().io_retries, 3);
    }

    #[test]
    fn bit_flips_are_detected_and_reread() {
        let image: Vec<u8> = (0..8192).map(|i| (i % 256) as u8).collect();
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = SimDisk::new(DiskProfile::nvme(), fb.clone(), None);
        // stamp a whole-extent record so verification is exact-match
        disk.write(4096, &image[..2048]).unwrap();
        fb.script_at(0, Fault::BitFlip);
        let pool = BufferPool::new(2);
        let counters = PrefetchCounters::default();
        let retry = fast_retry(3, 4, 8);
        let (chunks, _) =
            read_coalesced_with(&disk, &[(4096, 2048)], 0, &pool, &counters, &retry).unwrap();
        assert_eq!(chunks[0], &image[..2048], "re-read must replace flipped bytes");
        let s = counters.summary();
        assert_eq!(s.corrupt_detected, 1);
        assert!(s.io_retries >= 1);
    }

    #[test]
    fn worker_panic_is_contained_and_worker_respawns() {
        let image: Vec<u8> = vec![9u8; 4096];
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), fb.clone(), None));
        disk.write(0, &image).unwrap();
        let cfg = PrefetchConfig {
            workers: 2,
            queue_depth: 2,
            coalesce_gap: 0,
        };
        // threshold high enough that one panic does not trip the breaker
        let mut p = Prefetcher::spawn_with(disk, &cfg, fast_retry(0, 8, 8));
        fb.script_at(0, Fault::Panic);
        p.submit(plan(0, &[(0, 256)])).unwrap();
        let err = p.recv().unwrap_err();
        assert!(matches!(err, DiskError::WorkerPanic { .. }), "{err}");
        assert_eq!(p.summary().worker_panics, 1);
        // the pool keeps serving (surviving worker) and the dead thread is
        // respawned by a later submit
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut layer = 1;
        while p.summary().workers_restarted == 0 && Instant::now() < deadline {
            p.submit(plan(layer, &[(0, 256)])).unwrap();
            let staged = p.recv().unwrap();
            assert_eq!(staged.layer, layer);
            layer += 1;
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(p.summary().workers_restarted, 1, "dead worker respawned");
        assert_eq!(p.breaker_state(), BreakerState::Closed);
    }

    #[test]
    fn buffer_pool_recovers_from_poisoned_lock() {
        let pool = Arc::new(BufferPool::new(2));
        pool.put(vec![1, 2, 3]);
        let p2 = pool.clone();
        let _ = std::thread::spawn(move || {
            let _guard = p2.bufs.lock().unwrap();
            panic!("poison the pool lock");
        })
        .join();
        // take/put must recover, not propagate the poison
        let buf = pool.take();
        pool.put(buf);
    }

    #[test]
    fn breaker_trips_to_sync_and_recovers_via_probe() {
        let image: Vec<u8> = vec![7u8; 8192];
        let inner = Arc::new(MemBackend::new());
        let fb = Arc::new(FaultBackend::quiet(inner));
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), fb.clone(), None));
        disk.write(0, &image).unwrap();
        let cfg = PrefetchConfig {
            workers: 2,
            queue_depth: 2,
            coalesce_gap: 0,
        };
        // no retries, trip after 3 failures, probe after 2 clean sync plans
        let mut p = Prefetcher::spawn_with(disk, &cfg, fast_retry(0, 3, 2));
        fb.poison(0, 8192);

        let mut layer = 0;
        let mut submit_recv = |p: &mut Prefetcher, expect_ok: bool| {
            p.submit(plan(layer, &[(0, 512)])).unwrap();
            let r = p.recv();
            assert_eq!(r.is_ok(), expect_ok, "layer {layer}: {r:?}");
            layer += 1;
        };
        for _ in 0..3 {
            submit_recv(&mut p, false);
        }
        assert_eq!(p.breaker_state(), BreakerState::Open, "tripped after 3");
        assert_eq!(p.summary().breaker_trips, 1);

        // open: plans run inline; still failing while the device is sick
        submit_recv(&mut p, false);
        assert_eq!(p.breaker_state(), BreakerState::Open);

        // device recovers: sync plans succeed, then a probe closes it
        fb.heal();
        submit_recv(&mut p, true); // sync success 1
        submit_recv(&mut p, true); // sync success 2
        assert_eq!(p.breaker_state(), BreakerState::Open);
        submit_recv(&mut p, true); // half-open probe through the pool
        assert_eq!(p.breaker_state(), BreakerState::Closed, "probe closed it");

        // fully healthy again
        submit_recv(&mut p, true);
        let s = p.summary();
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.plans_failed, 4);
    }

    #[test]
    fn recv_timeout_abandons_only_that_ticket() {
        let image: Vec<u8> = (0..(1 << 14)).map(|i| (i * 31 % 251) as u8).collect();
        // stall the first read long past the recv timeout, then let
        // everything else through
        let slow = Arc::new(FaultBackend::quiet(Arc::new(MemBackend::new())));
        slow.script_at(0, Fault::LatencySpike(Duration::from_millis(250)));
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), slow, None));
        disk.write(0, &image).unwrap();
        let cfg = PrefetchConfig {
            workers: 1,
            queue_depth: 2,
            coalesce_gap: 0,
        };
        let mut p = Prefetcher::spawn_with(disk, &cfg, fast_retry(0, 8, 8));
        p.set_timeout(Duration::from_millis(30));
        p.submit(plan(0, &[(0, 128)])).unwrap(); // will stall past timeout
        p.submit(plan(1, &[(256, 128)])).unwrap();
        assert!(matches!(p.recv(), Err(DiskError::Timeout { .. })));
        // the next ticket still delivers once the stall clears; its stale
        // predecessor's completion is dropped, not delivered out of order
        p.set_timeout(Duration::from_secs(10));
        let staged = p.recv().unwrap();
        assert_eq!(staged.layer, 1);
        assert_eq!(staged.per_seq[0].1[0].1, &image[256..384]);
    }
}
