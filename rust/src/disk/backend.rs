//! Storage backends: where offloaded KV bytes physically live.
//!
//! `MemBackend` keeps the "disk" contents in RAM (fast, used by tests and
//! virtual-clock benches — the *timing* comes from the profile model, not
//! the backend). `FileBackend` uses positional file I/O on a real file so
//! the serving example exercises genuine storage syscalls.
//!
//! The whole trait speaks typed [`DiskError`]s so the prefetch
//! pipeline can match on failure kind; multi-extent access goes through
//! [`Backend::read_batch`], which backends override with their best
//! submission order (e.g. `FileBackend` sorts by offset).

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::error::{DiskError, DiskResult};
use super::relock;

/// One pending read: `buf.len()` bytes at `offset`, filled in place.
#[derive(Debug)]
pub struct ReadReq {
    pub offset: u64,
    pub buf: Vec<u8>,
}

impl ReadReq {
    pub fn new(offset: u64, len: usize) -> ReadReq {
        ReadReq {
            offset,
            buf: vec![0u8; len],
        }
    }

    /// Build a request around a recycled buffer (capacity reuse).
    pub fn with_buf(offset: u64, mut buf: Vec<u8>, len: usize) -> ReadReq {
        buf.clear();
        buf.resize(len, 0);
        ReadReq { offset, buf }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

pub trait Backend: Send + Sync {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> DiskResult<()>;
    fn write_at(&self, offset: u64, data: &[u8]) -> DiskResult<()>;
    fn len(&self) -> u64;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill every request in `reqs`. The default implementation loops
    /// over `read_at`; backends override it to pick a better submission
    /// order or amortize locking. Data visibility is identical either
    /// way — only performance differs.
    fn read_batch(&self, reqs: &mut [ReadReq]) -> DiskResult<()> {
        for r in reqs.iter_mut() {
            self.read_at(r.offset, &mut r.buf)?;
        }
        Ok(())
    }

    /// Shrink the backing store to `len` bytes, discarding everything
    /// past it (store compaction reclaims freed tail space this way).
    /// Backends that cannot shrink simply keep the old size — callers
    /// must not rely on reads past `len` failing afterwards.
    fn truncate(&self, _len: u64) -> DiskResult<()> {
        Ok(())
    }
}

/// Where a [`crate::disk::SimDisk`]'s bytes live — resolved to a concrete
/// [`Backend`] when the engine is built.
#[derive(Clone, Default)]
pub enum StorageBackend {
    /// Growable RAM store (virtual-clock benches, tests).
    #[default]
    Mem,
    /// Real file at this path (created/truncated), genuine syscalls.
    File(PathBuf),
    /// Caller-provided backend (e.g. a latency-injecting test wrapper).
    Custom(Arc<dyn Backend>),
}

impl StorageBackend {
    pub fn open(&self) -> DiskResult<Arc<dyn Backend>> {
        match self {
            StorageBackend::Mem => Ok(Arc::new(MemBackend::new())),
            StorageBackend::File(path) => Ok(Arc::new(FileBackend::create(path)?)),
            StorageBackend::Custom(b) => Ok(b.clone()),
        }
    }
}

impl std::fmt::Debug for StorageBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageBackend::Mem => write!(f, "StorageBackend::Mem"),
            StorageBackend::File(p) => write!(f, "StorageBackend::File({p:?})"),
            StorageBackend::Custom(_) => write!(f, "StorageBackend::Custom(..)"),
        }
    }
}

/// Growable in-memory backing store.
pub struct MemBackend {
    data: Mutex<Vec<u8>>,
}

impl MemBackend {
    pub fn new() -> MemBackend {
        MemBackend {
            data: Mutex::new(Vec::new()),
        }
    }

    pub fn with_capacity(cap: usize) -> MemBackend {
        MemBackend {
            data: Mutex::new(Vec::with_capacity(cap)),
        }
    }

    fn copy_range(data: &[u8], offset: u64, buf: &mut [u8]) -> DiskResult<()> {
        let oob = || DiskError::OutOfBounds {
            offset,
            len: buf.len(),
            size: data.len() as u64,
        };
        let start = usize::try_from(offset).map_err(|_| oob())?;
        let end = start.checked_add(buf.len()).ok_or_else(oob)?;
        if end > data.len() {
            return Err(oob());
        }
        buf.copy_from_slice(&data[start..end]);
        Ok(())
    }
}

impl Default for MemBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MemBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> DiskResult<()> {
        let data = relock(&self.data);
        Self::copy_range(&data, offset, buf)
    }

    fn write_at(&self, offset: u64, src: &[u8]) -> DiskResult<()> {
        let mut data = relock(&self.data);
        let oob = || DiskError::OutOfBounds {
            offset,
            len: src.len(),
            size: data.len() as u64,
        };
        let start = usize::try_from(offset).map_err(|_| oob())?;
        let end = start.checked_add(src.len()).ok_or_else(oob)?;
        if end > data.len() {
            data.resize(end, 0);
        }
        data[start..end].copy_from_slice(src);
        Ok(())
    }

    fn len(&self) -> u64 {
        relock(&self.data).len() as u64
    }

    fn truncate(&self, len: u64) -> DiskResult<()> {
        let mut data = relock(&self.data);
        let new_len = usize::try_from(len).unwrap_or(usize::MAX);
        if new_len < data.len() {
            data.truncate(new_len);
            data.shrink_to_fit();
        }
        Ok(())
    }

    /// One lock acquisition for the whole batch.
    fn read_batch(&self, reqs: &mut [ReadReq]) -> DiskResult<()> {
        let data = relock(&self.data);
        for r in reqs.iter_mut() {
            Self::copy_range(&data, r.offset, &mut r.buf)?;
        }
        Ok(())
    }
}

/// Real-file backing store (positional reads/writes, no seek contention).
pub struct FileBackend {
    file: File,
    len: Mutex<u64>,
}

impl FileBackend {
    pub fn create<P: AsRef<Path>>(path: P) -> DiskResult<FileBackend> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| DiskError::io(e, 0, 0))?;
        Ok(FileBackend {
            file,
            len: Mutex::new(0),
        })
    }

    /// Open an existing data file *without truncating it* (creating it
    /// empty if absent). This is the persistence path: the KV store's
    /// records must survive process restarts, so reopening the backing
    /// file has to preserve the bytes `create` would wipe.
    pub fn open<P: AsRef<Path>>(path: P) -> DiskResult<FileBackend> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .map_err(|e| DiskError::io(e, 0, 0))?;
        let len = file.metadata().map(|m| m.len()).unwrap_or(0);
        Ok(FileBackend {
            file,
            len: Mutex::new(len),
        })
    }
}

impl Backend for FileBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> DiskResult<()> {
        self.file
            .read_exact_at(buf, offset)
            .map_err(|e| match e.kind() {
                std::io::ErrorKind::UnexpectedEof => DiskError::OutOfBounds {
                    offset,
                    len: buf.len(),
                    size: self.len(),
                },
                _ => DiskError::io(e, offset, buf.len()),
            })
    }

    fn write_at(&self, offset: u64, data: &[u8]) -> DiskResult<()> {
        self.file
            .write_all_at(data, offset)
            .map_err(|e| DiskError::io(e, offset, data.len()))?;
        let mut len = relock(&self.len);
        *len = (*len).max(offset + data.len() as u64);
        Ok(())
    }

    fn len(&self) -> u64 {
        *relock(&self.len)
    }

    fn truncate(&self, len: u64) -> DiskResult<()> {
        let mut cur = relock(&self.len);
        if len < *cur {
            self.file
                .set_len(len)
                .map_err(|e| DiskError::io(e, len, 0))?;
            *cur = len;
        }
        Ok(())
    }

    /// Issue in ascending offset order: positional syscalls hit the page
    /// cache / device queue sequentially even when the caller's plan is
    /// scattered.
    fn read_batch(&self, reqs: &mut [ReadReq]) -> DiskResult<()> {
        let mut order: Vec<usize> = (0..reqs.len()).collect();
        order.sort_by_key(|&i| reqs[i].offset);
        for i in order {
            let r = &mut reqs[i];
            self.read_at(r.offset, &mut r.buf)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(b: &dyn Backend) {
        b.write_at(10, b"hello").unwrap();
        b.write_at(0, b"01").unwrap();
        let mut buf = [0u8; 5];
        b.read_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"hello");
        let mut buf2 = [0u8; 2];
        b.read_at(0, &mut buf2).unwrap();
        assert_eq!(&buf2, b"01");
        assert_eq!(b.len(), 15);
    }

    fn batch_roundtrip(b: &dyn Backend) {
        b.write_at(0, &(0..64u8).collect::<Vec<_>>()).unwrap();
        // deliberately unsorted offsets
        let mut reqs = vec![ReadReq::new(48, 8), ReadReq::new(0, 4), ReadReq::new(16, 2)];
        b.read_batch(&mut reqs).unwrap();
        assert_eq!(&reqs[0].buf, &(48..56u8).collect::<Vec<_>>());
        assert_eq!(&reqs[1].buf, &[0, 1, 2, 3]);
        assert_eq!(&reqs[2].buf, &[16, 17]);
    }

    #[test]
    fn mem_backend_roundtrip() {
        roundtrip(&MemBackend::new());
        batch_roundtrip(&MemBackend::new());
    }

    #[test]
    fn mem_backend_read_past_end_errors() {
        let b = MemBackend::new();
        b.write_at(0, b"xy").unwrap();
        let mut buf = [0u8; 4];
        assert!(matches!(
            b.read_at(0, &mut buf),
            Err(DiskError::OutOfBounds { size: 2, .. })
        ));
    }

    #[test]
    fn mem_backend_adversarial_offsets_do_not_panic() {
        let b = MemBackend::new();
        b.write_at(0, b"data").unwrap();
        // offset + len would wrap u64 / usize
        let mut buf = [0u8; 16];
        assert!(matches!(
            b.read_at(u64::MAX - 4, &mut buf),
            Err(DiskError::OutOfBounds { .. })
        ));
        assert!(matches!(
            b.write_at(u64::MAX - 4, b"boom"),
            Err(DiskError::OutOfBounds { .. })
        ));
        // a batch with one bad extent fails typed, not by panic
        let mut reqs = vec![ReadReq::new(0, 4), ReadReq::new(u64::MAX, 1)];
        assert!(matches!(
            b.read_batch(&mut reqs),
            Err(DiskError::OutOfBounds { .. })
        ));
    }

    #[test]
    fn file_backend_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kvswap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("backend.bin");
        {
            let b = FileBackend::create(&path).unwrap();
            roundtrip(&b);
        }
        {
            let b = FileBackend::create(&path).unwrap();
            batch_roundtrip(&b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn file_backend_short_read_is_out_of_bounds() {
        let dir = std::env::temp_dir().join(format!("kvswap-test-sr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("short.bin");
        let b = FileBackend::create(&path).unwrap();
        b.write_at(0, b"abc").unwrap();
        let mut buf = [0u8; 8];
        assert!(matches!(
            b.read_at(0, &mut buf),
            Err(DiskError::OutOfBounds { .. })
        ));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncate_shrinks_and_never_grows() {
        let b = MemBackend::new();
        b.write_at(0, &(0..32u8).collect::<Vec<_>>()).unwrap();
        b.truncate(64).unwrap(); // grow request: no-op
        assert_eq!(b.len(), 32);
        b.truncate(8).unwrap();
        assert_eq!(b.len(), 8);
        let mut buf = [0u8; 8];
        b.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(matches!(
            b.read_at(8, &mut buf),
            Err(DiskError::OutOfBounds { .. })
        ));

        let dir = std::env::temp_dir().join(format!("kvswap-test-tr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.bin");
        let f = FileBackend::create(&path).unwrap();
        f.write_at(0, &(0..32u8).collect::<Vec<_>>()).unwrap();
        f.truncate(8).unwrap();
        assert_eq!(f.len(), 8);
        assert!(matches!(
            f.read_at(4, &mut buf),
            Err(DiskError::OutOfBounds { .. })
        ));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mem_backend_gap_is_zero_filled() {
        let b = MemBackend::new();
        b.write_at(8, b"z").unwrap();
        let mut buf = [1u8; 8];
        b.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 8]);
    }

    #[test]
    fn storage_backend_opens_each_kind() {
        assert_eq!(StorageBackend::Mem.open().unwrap().len(), 0);
        let custom = StorageBackend::Custom(Arc::new(MemBackend::new()));
        let b = custom.open().unwrap();
        b.write_at(0, b"x").unwrap();
        // Custom shares the instance
        let again = custom.open().unwrap();
        assert_eq!(again.len(), 1);
        assert!(format!("{custom:?}").contains("Custom"));
    }
}
