//! Storage backends: where offloaded KV bytes physically live.
//!
//! `MemBackend` keeps the "disk" contents in RAM (fast, used by tests and
//! virtual-clock benches — the *timing* comes from the profile model, not
//! the backend). `FileBackend` uses positional file I/O on a real file so
//! the serving example exercises genuine storage syscalls.

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::Mutex;

pub trait Backend: Send + Sync {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> anyhow::Result<()>;
    fn write_at(&self, offset: u64, data: &[u8]) -> anyhow::Result<()>;
    fn len(&self) -> u64;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Growable in-memory backing store.
pub struct MemBackend {
    data: Mutex<Vec<u8>>,
}

impl MemBackend {
    pub fn new() -> MemBackend {
        MemBackend {
            data: Mutex::new(Vec::new()),
        }
    }

    pub fn with_capacity(cap: usize) -> MemBackend {
        MemBackend {
            data: Mutex::new(Vec::with_capacity(cap)),
        }
    }
}

impl Default for MemBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MemBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> anyhow::Result<()> {
        let data = self.data.lock().unwrap();
        let end = offset as usize + buf.len();
        if end > data.len() {
            anyhow::bail!(
                "mem backend read past end: {}+{} > {}",
                offset,
                buf.len(),
                data.len()
            );
        }
        buf.copy_from_slice(&data[offset as usize..end]);
        Ok(())
    }

    fn write_at(&self, offset: u64, src: &[u8]) -> anyhow::Result<()> {
        let mut data = self.data.lock().unwrap();
        let end = offset as usize + src.len();
        if end > data.len() {
            data.resize(end, 0);
        }
        data[offset as usize..end].copy_from_slice(src);
        Ok(())
    }

    fn len(&self) -> u64 {
        self.data.lock().unwrap().len() as u64
    }
}

/// Real-file backing store (positional reads/writes, no seek contention).
pub struct FileBackend {
    file: File,
    len: Mutex<u64>,
}

impl FileBackend {
    pub fn create<P: AsRef<Path>>(path: P) -> anyhow::Result<FileBackend> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(FileBackend {
            file,
            len: Mutex::new(0),
        })
    }
}

impl Backend for FileBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> anyhow::Result<()> {
        self.file.read_exact_at(buf, offset)?;
        Ok(())
    }

    fn write_at(&self, offset: u64, data: &[u8]) -> anyhow::Result<()> {
        self.file.write_all_at(data, offset)?;
        let mut len = self.len.lock().unwrap();
        *len = (*len).max(offset + data.len() as u64);
        Ok(())
    }

    fn len(&self) -> u64 {
        *self.len.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(b: &dyn Backend) {
        b.write_at(10, b"hello").unwrap();
        b.write_at(0, b"01").unwrap();
        let mut buf = [0u8; 5];
        b.read_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"hello");
        let mut buf2 = [0u8; 2];
        b.read_at(0, &mut buf2).unwrap();
        assert_eq!(&buf2, b"01");
        assert_eq!(b.len(), 15);
    }

    #[test]
    fn mem_backend_roundtrip() {
        roundtrip(&MemBackend::new());
    }

    #[test]
    fn mem_backend_read_past_end_errors() {
        let b = MemBackend::new();
        b.write_at(0, b"xy").unwrap();
        let mut buf = [0u8; 4];
        assert!(b.read_at(0, &mut buf).is_err());
    }

    #[test]
    fn file_backend_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kvswap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("backend.bin");
        roundtrip(&FileBackend::create(&path).unwrap());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mem_backend_gap_is_zero_filled() {
        let b = MemBackend::new();
        b.write_at(8, b"z").unwrap();
        let mut buf = [1u8; 8];
        b.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 8]);
    }
}
