//! Read coalescing — the paper's read orchestration (§3.3): adjacent or
//! near-adjacent group extents are merged into large sequential reads so
//! the device sees few big operations instead of many small ones.
//!
//! Merging across a small byte gap deliberately over-reads the gap: on
//! every profiled device one op-latency charge costs far more than a few
//! KiB of extra transfer (e.g. NVMe's 80 µs ≈ 144 KiB at 1.8 GB/s), so a
//! bounded `max_gap` trades wasted bytes for saved commands.

/// One physical read covering one or more logical extents.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Run {
    pub offset: u64,
    pub len: usize,
    /// `(extent index, byte delta of the extent start inside the run)`,
    /// indices referring to the input slice passed to [`coalesce`].
    pub members: Vec<(usize, usize)>,
}

/// Merge `extents` (`(offset, len)` pairs, any order, overlaps allowed)
/// into sequential runs: two extents join the same run when the byte gap
/// between them is at most `max_gap`. Every input extent appears in
/// exactly one run's member list; scattering `run[delta..delta+len]`
/// back out reproduces a direct read of each extent byte-for-byte.
pub fn coalesce(extents: &[(u64, usize)], max_gap: u64) -> Vec<Run> {
    let mut order: Vec<usize> = (0..extents.len()).collect();
    order.sort_by_key(|&i| extents[i]);
    let mut runs: Vec<Run> = Vec::new();
    for i in order {
        let (off, len) = extents[i];
        match runs.last_mut() {
            Some(r) if off - r.offset <= (r.len as u64).saturating_add(max_gap) => {
                // `off >= r.offset` by sort order, so the delta fits usize
                // whenever the run itself does
                let delta = (off - r.offset) as usize;
                r.len = r.len.max(delta + len);
                r.members.push((i, delta));
            }
            _ => runs.push(Run {
                offset: off,
                len,
                members: vec![(i, 0)],
            }),
        }
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacent_extents_merge_into_one_run() {
        let runs = coalesce(&[(0, 64), (64, 64), (128, 64)], 0);
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].offset, 0);
        assert_eq!(runs[0].len, 192);
        assert_eq!(runs[0].members, vec![(0, 0), (1, 64), (2, 128)]);
    }

    #[test]
    fn gap_threshold_controls_merging() {
        // 32-byte hole between the extents
        let e = [(0u64, 64usize), (96, 64)];
        assert_eq!(coalesce(&e, 0).len(), 2);
        assert_eq!(coalesce(&e, 31).len(), 2);
        let merged = coalesce(&e, 32);
        assert_eq!(merged.len(), 1);
        // the run spans the hole
        assert_eq!(merged[0].len, 160);
        assert_eq!(merged[0].members[1], (1, 96));
    }

    #[test]
    fn unsorted_input_keeps_original_indices() {
        let runs = coalesce(&[(128, 32), (0, 32), (32, 32)], 0);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].members, vec![(1, 0), (2, 32)]);
        assert_eq!(runs[1].members, vec![(0, 0)]);
    }

    #[test]
    fn overlapping_extents_share_a_run() {
        let runs = coalesce(&[(0, 100), (50, 100), (100, 10)], 0);
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].len, 150);
        assert_eq!(runs[0].members, vec![(0, 0), (1, 50), (2, 100)]);
    }

    #[test]
    fn duplicate_extents_both_served() {
        let runs = coalesce(&[(64, 32), (64, 32)], 0);
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].members.len(), 2);
        assert_eq!(runs[0].len, 32);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert!(coalesce(&[], 4096).is_empty());
        let one = coalesce(&[(42, 7)], 4096);
        assert_eq!(one.len(), 1);
        assert_eq!((one[0].offset, one[0].len), (42, 7));
    }

    #[test]
    fn every_extent_appears_exactly_once() {
        let extents: Vec<(u64, usize)> =
            (0..50).map(|i| ((i * 137) % 4096, 64 + i as usize)).collect();
        for gap in [0u64, 16, 512, 1 << 20] {
            let runs = coalesce(&extents, gap);
            let mut seen = vec![0u32; extents.len()];
            for r in &runs {
                for &(idx, delta) in &r.members {
                    seen[idx] += 1;
                    // member stays inside its run
                    assert!(delta + extents[idx].1 <= r.len);
                    assert_eq!(r.offset + delta as u64, extents[idx].0);
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "gap {gap}");
        }
    }
}
