//! `SimDisk` — the simulated storage device all offloading policies talk to.
//!
//! Couples a byte `Backend` (where the data lives) with a `DiskProfile`
//! (how long access takes, including page-granule read amplification) and
//! an optional pacing `Clock`:
//!
//! * real-clock pacing → reads genuinely block for the modeled duration,
//!   so the end-to-end serving example behaves like the device;
//! * no pacing (virtual-clock benches) → reads return immediately and the
//!   engine folds the returned modeled `Duration`s into its pipeline
//!   accounting.
//!
//! The backend is shared (`Arc`) so the prefetch worker pool and the
//! engine thread address the same bytes. All ops speak [`DiskResult`] and
//! update `DiskStats` (logical vs physical bytes, busy time) from which
//! the benches derive I/O utilization (paper Fig. 12 annotations).

use std::sync::Arc;
use std::time::Duration;

use super::backend::{Backend, ReadReq};
use super::error::DiskResult;
use super::integrity::IntegrityMap;
use super::profile::DiskProfile;
use super::stats::DiskStats;
use crate::util::clock::Clock;

pub struct SimDisk {
    profile: DiskProfile,
    backend: Arc<dyn Backend>,
    pacing: Option<Clock>,
    stats: Arc<DiskStats>,
    /// Write-time checksums, verified on exact-extent reads (see
    /// [`super::integrity`] for the failure model).
    integrity: IntegrityMap,
}

impl SimDisk {
    pub fn new(profile: DiskProfile, backend: Arc<dyn Backend>, pacing: Option<Clock>) -> SimDisk {
        SimDisk {
            profile,
            backend,
            pacing,
            stats: Arc::new(DiskStats::default()),
            integrity: IntegrityMap::new(),
        }
    }

    /// In-memory simulated disk without pacing (timing returned, not slept).
    pub fn in_memory(profile: DiskProfile) -> SimDisk {
        SimDisk::new(profile, Arc::new(super::backend::MemBackend::new()), None)
    }

    pub fn profile(&self) -> &DiskProfile {
        &self.profile
    }

    pub fn stats(&self) -> Arc<DiskStats> {
        self.stats.clone()
    }

    pub fn integrity(&self) -> &IntegrityMap {
        &self.integrity
    }

    /// Verify `bytes` staged from `offset` against the write-time
    /// checksum (no-op for extents that were never stamped at exactly
    /// this offset/length). Counts detections in [`DiskStats`].
    pub fn verify_extent(&self, offset: u64, bytes: &[u8]) -> DiskResult<()> {
        self.integrity.verify(offset, bytes).map_err(|e| {
            self.stats.record_corruption();
            e
        })
    }

    /// Read `buf.len()` bytes at `offset`; returns the *modeled* duration.
    /// Checksum-verified when the extent matches a stamped write.
    pub fn read(&self, offset: u64, buf: &mut [u8]) -> DiskResult<Duration> {
        self.backend.read_at(offset, buf)?;
        self.verify_extent(offset, buf)?;
        let dur = self.profile.read_time(offset, buf.len() as u64);
        let phys = self.profile.physical_bytes(offset, buf.len() as u64);
        self.stats.record_read(buf.len() as u64, phys, dur);
        if let Some(c) = &self.pacing {
            c.advance(dur);
        }
        Ok(dur)
    }

    /// Multi-extent read where each extent is an independent operation
    /// (one latency charge each, queue-depth 1) — the *uncoalesced*
    /// baseline. Data lands in `out` back-to-back.
    pub fn read_extents(&self, extents: &[(u64, usize)], out: &mut [u8]) -> DiskResult<Duration> {
        let mut total = Duration::ZERO;
        let mut cursor = 0;
        for &(off, len) in extents {
            total += self.read(off, &mut out[cursor..cursor + len])?;
            cursor += len;
        }
        Ok(total)
    }

    /// Queue-depth-aware batched read: all requests are issued together
    /// through [`Backend::read_batch`], so command latencies overlap up
    /// to the device's native queue depth while transfers serialize on
    /// the bus (the paper's "orchestrates read patterns to match storage
    /// device characteristics"). Returns the modeled duration of the
    /// whole batch (paced once in real mode).
    pub fn read_batch(&self, reqs: &mut [ReadReq]) -> DiskResult<Duration> {
        self.backend.read_batch(reqs)?;
        let mut total_phys = 0u64;
        let mut logical = 0u64;
        for r in reqs.iter() {
            total_phys += self.profile.physical_bytes(r.offset, r.len() as u64);
            logical += r.len() as u64;
        }
        let dur = self.profile.batched_read_time(total_phys, reqs.len() as u64);
        self.stats
            .record_batch_read(reqs.len() as u64, logical, total_phys, dur);
        if let Some(c) = &self.pacing {
            c.advance(dur);
        }
        Ok(dur)
    }

    /// Write; returns modeled duration. Stamps the extent's checksum so
    /// later staging reads can detect silent corruption.
    pub fn write(&self, offset: u64, data: &[u8]) -> DiskResult<Duration> {
        self.backend.write_at(offset, data)?;
        self.integrity.stamp(offset, data);
        let dur = self.profile.write_time(offset, data.len() as u64);
        let phys = self.profile.physical_bytes(offset, data.len() as u64);
        self.stats.record_write(data.len() as u64, phys, dur);
        if let Some(c) = &self.pacing {
            c.advance(dur);
        }
        Ok(dur)
    }

    pub fn len(&self) -> u64 {
        self.backend.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backend.is_empty()
    }

    /// Shrink the backing store to `len` bytes (store compaction). No
    /// time is modeled: truncation is a metadata operation, not a data
    /// transfer. Stale checksum stamps past the cut are harmless — the
    /// space is only read again after being rewritten (and restamped).
    pub fn truncate(&self, len: u64) -> DiskResult<()> {
        self.backend.truncate(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::disk::backend::MemBackend;

    #[test]
    fn read_write_roundtrip_with_modeled_time() {
        let d = SimDisk::in_memory(DiskProfile::nvme());
        let data = vec![7u8; 8192];
        let wt = d.write(0, &data).unwrap();
        assert!(wt > Duration::ZERO);
        let mut buf = vec![0u8; 8192];
        let rt = d.read(0, &mut buf).unwrap();
        assert_eq!(buf, data);
        // 8192B at 1.8GB/s + 80us latency
        let expect = 80e-6 + 8192.0 / 1.8e9;
        assert!((rt.as_secs_f64() - expect).abs() < 1e-6);
    }

    #[test]
    fn stats_track_amplification() {
        let d = SimDisk::in_memory(DiskProfile::emmc()); // 16K pages
        d.write(0, &vec![1u8; 65536]).unwrap();
        let s = d.stats();
        s.reset();
        let mut buf = vec![0u8; 512];
        d.read(0, &mut buf).unwrap(); // 512 logical, 16384 physical
        d.read(16384, &mut buf).unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.logical_read_bytes, 1024);
        assert_eq!(snap.physical_read_bytes, 32768);
        assert_eq!(snap.read_ops, 2);
        assert!(snap.read_busy > Duration::ZERO);
    }

    #[test]
    fn read_extents_accumulates() {
        let d = SimDisk::in_memory(DiskProfile::nvme());
        d.write(0, &(0..128u8).collect::<Vec<_>>()).unwrap();
        let mut out = vec![0u8; 8];
        let t = d.read_extents(&[(0, 4), (100, 4)], &mut out).unwrap();
        assert_eq!(&out[..4], &[0, 1, 2, 3]);
        assert_eq!(&out[4..], &[100, 101, 102, 103]);
        // two ops => two latency charges
        assert!(t >= DiskProfile::nvme().op_latency * 2);
    }

    #[test]
    fn silent_backend_corruption_is_caught_on_read() {
        use crate::disk::error::DiskError;
        let backend = Arc::new(MemBackend::new());
        let d = SimDisk::new(DiskProfile::nvme(), backend.clone(), None);
        let rec = vec![9u8; 4096];
        d.write(8192, &rec).unwrap();
        let mut buf = vec![0u8; 4096];
        d.read(8192, &mut buf).unwrap();

        // flip one bit *underneath* the SimDisk (no re-stamp)
        let mut bad = rec.clone();
        bad[100] ^= 0x01;
        backend.write_at(8192, &bad).unwrap();
        let err = d.read(8192, &mut buf).unwrap_err();
        assert!(matches!(err, DiskError::Corrupt { offset: 8192, .. }));
        assert_eq!(d.stats().snapshot().corruptions_detected, 1);

        // a legitimate overwrite through SimDisk re-stamps
        d.write(8192, &bad).unwrap();
        d.read(8192, &mut buf).unwrap();
        assert_eq!(buf, bad);
    }

    #[test]
    fn real_pacing_actually_sleeps() {
        let clock = Clock::real();
        let d = SimDisk::new(
            DiskProfile {
                name: "slow",
                read_bw: 1e6,
                write_bw: 1e6,
                op_latency: Duration::from_millis(1),
                page_bytes: 512,
                queue_depth: 1,
            },
            Arc::new(MemBackend::new()),
            Some(clock),
        );
        d.write(0, &vec![0u8; 4096]).unwrap();
        let t0 = std::time::Instant::now();
        let mut buf = vec![0u8; 4096];
        d.read(0, &mut buf).unwrap(); // ~1ms + 4ms transfer
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn batched_reads_overlap_latency_up_to_queue_depth() {
        let d = SimDisk::in_memory(DiskProfile::nvme()); // QD 16
        d.write(0, &vec![1u8; 1 << 20]).unwrap();
        let extents: Vec<(u64, usize)> = (0..32).map(|i| (i * 8192, 4096usize)).collect();
        let mut reqs: Vec<ReadReq> = extents
            .iter()
            .map(|&(off, len)| ReadReq::new(off, len))
            .collect();
        let t_batch = d.read_batch(&mut reqs).unwrap();
        for req in &reqs {
            assert!(req.buf.iter().all(|&b| b == 1));
        }
        let mut out = vec![0u8; 32 * 4096];
        let t_serial = d.read_extents(&extents, &mut out).unwrap();
        // 32 ops: serial pays 32 latencies, batched pays ceil(32/16) = 2
        assert!(
            t_serial.as_secs_f64() / t_batch.as_secs_f64() > 5.0,
            "serial {t_serial:?} vs batch {t_batch:?}"
        );
        // logical bytes identical either way
        let snap = d.stats().snapshot();
        assert_eq!(snap.logical_read_bytes, 2 * 32 * 4096);
    }

    #[test]
    fn grouped_reads_beat_scattered_reads() {
        // The core premise of the paper's grouping design: fetching the
        // same bytes in fewer, larger extents is faster.
        let d = SimDisk::in_memory(DiskProfile::emmc());
        d.write(0, &vec![3u8; 1 << 20]).unwrap();
        let mut out = vec![0u8; 65536];
        // 128 scattered 512-B entries, page-spread
        let scattered: Vec<(u64, usize)> = (0..128).map(|i| (i * 8192, 512usize)).collect();
        let t_scatter = d.read_extents(&scattered, &mut out).unwrap();
        // same 64 KiB as one extent
        let t_grouped = d.read(0, &mut out).unwrap();
        assert!(
            t_scatter.as_secs_f64() / t_grouped.as_secs_f64() > 10.0,
            "scatter {t_scatter:?} grouped {t_grouped:?}"
        );
    }
}
