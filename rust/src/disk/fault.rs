//! Deterministic fault injection around any [`Backend`].
//!
//! `FaultBackend` wraps an inner backend and perturbs its *read* path —
//! writes always pass through untouched, so the stored image (and the
//! write-time checksums stamped above it) stays truthful. Two injection
//! channels compose:
//!
//! * **Scripted**: [`FaultBackend::script_at`] pins an exact fault to the
//!   N-th read op, for tests that need a failure at a precise point.
//! * **Probabilistic**: per-read Bernoulli draws from a seeded PRNG
//!   ([`FaultConfig`]`{rate, corruption_rate, seed}`), so a "5% flaky
//!   disk" run is reproducible bit-for-bit.
//!
//! Injected faults mirror how real storage misbehaves:
//!
//! * transient `Io` errors that clear on re-issue,
//! * *persistent* extent poison ([`FaultBackend::poison`], or every
//!   probabilistic fault when `persistent` is set) that keeps failing
//!   until [`FaultBackend::heal`],
//! * latency spikes (the read succeeds, late),
//! * short reads surfacing as `UnexpectedEof`,
//! * **silent bit flips** — the read *succeeds* with one wrong bit; only
//!   the integrity checksums can catch these.
//!
//! `read_batch` deliberately degrades to per-request `read_at` so every
//! extent gets an independent fault draw; batched-submission timing is
//! modeled above this layer by `SimDisk`, not here.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::backend::Backend;
use super::error::{DiskError, DiskResult};
use super::relock;
use crate::config::FaultConfig;
use crate::util::rng::Rng;

/// One injected failure mode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Fail this read with an `Io` error; the next attempt is clean.
    TransientIo,
    /// Fail this read and poison its extent: all later overlapping reads
    /// fail too, until `heal()`.
    PersistentIo,
    /// Delay the read by the given wall-clock duration, then succeed.
    LatencySpike(Duration),
    /// Return `UnexpectedEof` as a device short-read would.
    ShortRead,
    /// Succeed but flip one bit of the returned buffer (silent).
    BitFlip,
    /// Panic inside the read — exercises worker supervision.
    Panic,
}

/// Injection counters, snapshotted for assertions.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultSnapshot {
    pub reads: u64,
    pub injected_io: u64,
    pub injected_latency: u64,
    pub injected_short: u64,
    pub injected_flips: u64,
    pub injected_panics: u64,
}

impl FaultSnapshot {
    pub fn total_injected(&self) -> u64 {
        self.injected_io
            + self.injected_latency
            + self.injected_short
            + self.injected_flips
            + self.injected_panics
    }
}

fn injected_io_error(offset: u64, len: usize, what: &str) -> DiskError {
    DiskError::io(
        std::io::Error::other(format!("injected fault: {what}")),
        offset,
        len,
    )
}

/// The wrapper. `Send + Sync` like any backend; all mutable state is
/// behind atomics/mutexes and no lock is held across the inner I/O call
/// (or across an injected panic).
pub struct FaultBackend {
    inner: Arc<dyn Backend>,
    cfg: FaultConfig,
    rng: Mutex<Rng>,
    ops: AtomicU64,
    script: Mutex<HashMap<u64, Fault>>,
    /// Poisoned (offset, len) extents; small, scanned linearly.
    poisoned: Mutex<Vec<(u64, u64)>>,
    n_io: AtomicU64,
    n_latency: AtomicU64,
    n_short: AtomicU64,
    n_flips: AtomicU64,
    n_panics: AtomicU64,
}

impl FaultBackend {
    pub fn new(inner: Arc<dyn Backend>, cfg: FaultConfig) -> FaultBackend {
        FaultBackend {
            rng: Mutex::new(Rng::new(cfg.seed)),
            inner,
            cfg,
            ops: AtomicU64::new(0),
            script: Mutex::new(HashMap::new()),
            poisoned: Mutex::new(Vec::new()),
            n_io: AtomicU64::new(0),
            n_latency: AtomicU64::new(0),
            n_short: AtomicU64::new(0),
            n_flips: AtomicU64::new(0),
            n_panics: AtomicU64::new(0),
        }
    }

    /// Wrap with injection disabled; faults come only from `script_at`
    /// and `poison`.
    pub fn quiet(inner: Arc<dyn Backend>) -> FaultBackend {
        FaultBackend::new(inner, FaultConfig::default())
    }

    /// Pin `fault` to the read op with index `op` (0-based, counted
    /// across all reads). Scripted faults win over probabilistic draws.
    pub fn script_at(&self, op: u64, fault: Fault) {
        relock(&self.script).insert(op, fault);
    }

    /// Persistently poison `[offset, offset+len)`.
    pub fn poison(&self, offset: u64, len: u64) {
        relock(&self.poisoned).push((offset, len));
    }

    /// Clear all persistent poison and pending scripted faults — the
    /// "device recovered" transition for breaker-recovery tests.
    pub fn heal(&self) {
        relock(&self.poisoned).clear();
        relock(&self.script).clear();
    }

    pub fn snapshot(&self) -> FaultSnapshot {
        FaultSnapshot {
            reads: self.ops.load(Ordering::Relaxed),
            injected_io: self.n_io.load(Ordering::Relaxed),
            injected_latency: self.n_latency.load(Ordering::Relaxed),
            injected_short: self.n_short.load(Ordering::Relaxed),
            injected_flips: self.n_flips.load(Ordering::Relaxed),
            injected_panics: self.n_panics.load(Ordering::Relaxed),
        }
    }

    fn poisoned_overlap(&self, offset: u64, len: usize) -> bool {
        let end = offset.saturating_add(len as u64);
        relock(&self.poisoned)
            .iter()
            .any(|&(o, l)| o < end && o.saturating_add(l) > offset)
    }

    /// Probabilistic draw for one read. Order matters: an I/O-level fault
    /// preempts a silent flip (a failed read returns no bytes to flip).
    fn draw(&self) -> Option<Fault> {
        if !self.cfg.enabled() {
            return None;
        }
        let mut rng = relock(&self.rng);
        if self.cfg.rate > 0.0 && rng.chance(self.cfg.rate) {
            if self.cfg.persistent {
                return Some(Fault::PersistentIo);
            }
            return Some(match rng.below(4) {
                0 | 1 => Fault::TransientIo,
                2 => Fault::LatencySpike(Duration::from_micros(200)),
                _ => Fault::ShortRead,
            });
        }
        if self.cfg.corruption_rate > 0.0 && rng.chance(self.cfg.corruption_rate) {
            return Some(Fault::BitFlip);
        }
        None
    }

    fn flip_position(&self, len: usize) -> (usize, u8) {
        let mut rng = relock(&self.rng);
        (rng.below(len.max(1)), 1u8 << rng.below(8))
    }
}

impl Backend for FaultBackend {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> DiskResult<()> {
        let op = self.ops.fetch_add(1, Ordering::Relaxed);
        if self.poisoned_overlap(offset, buf.len()) {
            self.n_io.fetch_add(1, Ordering::Relaxed);
            return Err(injected_io_error(offset, buf.len(), "poisoned extent"));
        }
        let fault = relock(&self.script).remove(&op).or_else(|| self.draw());
        match fault {
            None => self.inner.read_at(offset, buf),
            Some(Fault::TransientIo) => {
                self.n_io.fetch_add(1, Ordering::Relaxed);
                Err(injected_io_error(offset, buf.len(), "transient EIO"))
            }
            Some(Fault::PersistentIo) => {
                self.n_io.fetch_add(1, Ordering::Relaxed);
                self.poison(offset, buf.len() as u64);
                Err(injected_io_error(offset, buf.len(), "persistent EIO"))
            }
            Some(Fault::LatencySpike(d)) => {
                self.n_latency.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(d);
                self.inner.read_at(offset, buf)
            }
            Some(Fault::ShortRead) => {
                self.n_short.fetch_add(1, Ordering::Relaxed);
                // partially fill the buffer like a real short read would
                let half = buf.len() / 2;
                let _ = self.inner.read_at(offset, &mut buf[..half]);
                Err(DiskError::io(
                    std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "injected fault: short read",
                    ),
                    offset,
                    buf.len(),
                ))
            }
            Some(Fault::BitFlip) => {
                self.inner.read_at(offset, buf)?;
                if !buf.is_empty() {
                    self.n_flips.fetch_add(1, Ordering::Relaxed);
                    let (i, mask) = self.flip_position(buf.len());
                    buf[i] ^= mask;
                }
                Ok(())
            }
            Some(Fault::Panic) => {
                self.n_panics.fetch_add(1, Ordering::Relaxed);
                panic!("injected fault: backend panic at read op {op}");
            }
        }
    }

    fn write_at(&self, offset: u64, data: &[u8]) -> DiskResult<()> {
        // the write path is trusted: faults target reads, and keeping the
        // stored image truthful lets tests assert bit-identity end-to-end
        self.inner.write_at(offset, data)
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn truncate(&self, len: u64) -> DiskResult<()> {
        // trusted like writes: compaction must actually reclaim space
        self.inner.truncate(len)
    }

    // default read_batch would coalesce the fault draws; go per-extent
    fn read_batch(&self, reqs: &mut [super::backend::ReadReq]) -> DiskResult<()> {
        for req in reqs.iter_mut() {
            let offset = req.offset;
            self.read_at(offset, &mut req.buf)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::disk::MemBackend;

    fn image(n: usize) -> (Arc<MemBackend>, Vec<u8>) {
        let data: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
        let b = Arc::new(MemBackend::new());
        b.write_at(0, &data).unwrap();
        (b, data)
    }

    #[test]
    fn quiet_wrapper_is_transparent() {
        let (inner, data) = image(1024);
        let fb = FaultBackend::quiet(inner);
        let mut buf = vec![0u8; 256];
        fb.read_at(128, &mut buf).unwrap();
        assert_eq!(buf, &data[128..384]);
        assert_eq!(fb.len(), 1024);
        assert_eq!(fb.snapshot().total_injected(), 0);
    }

    #[test]
    fn scripted_faults_fire_at_exact_ops() {
        let (inner, data) = image(512);
        let fb = FaultBackend::quiet(inner);
        fb.script_at(1, Fault::TransientIo);
        fb.script_at(2, Fault::BitFlip);
        let mut buf = vec![0u8; 64];
        fb.read_at(0, &mut buf).unwrap(); // op 0: clean
        assert!(matches!(
            fb.read_at(0, &mut buf), // op 1: scripted EIO
            Err(DiskError::Io { .. })
        ));
        fb.read_at(0, &mut buf).unwrap(); // op 2: silent flip
        assert_ne!(buf, &data[..64], "bit flip must corrupt the buffer");
        let delta: u32 = buf
            .iter()
            .zip(&data[..64])
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(delta, 1, "exactly one flipped bit");
        fb.read_at(0, &mut buf).unwrap(); // op 3: clean again
        assert_eq!(buf, &data[..64]);
        let s = fb.snapshot();
        assert_eq!((s.injected_io, s.injected_flips, s.reads), (1, 1, 4));
    }

    #[test]
    fn probabilistic_injection_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let (inner, _) = image(4096);
            let fb = FaultBackend::new(
                inner,
                FaultConfig {
                    rate: 0.3,
                    corruption_rate: 0.0,
                    seed,
                    persistent: false,
                },
            );
            let mut buf = vec![0u8; 32];
            (0..64).map(|_| fb.read_at(0, &mut buf).is_err()).collect()
        };
        assert_eq!(run(7), run(7), "same seed, same fault sequence");
        assert_ne!(run(7), run(8), "different seed, different sequence");
        assert!(run(7).iter().any(|&e| e), "30% rate must inject something");
        assert!(!run(7).iter().all(|&e| e), "…but not fail everything");
    }

    #[test]
    fn poison_persists_until_heal() {
        let (inner, data) = image(1024);
        let fb = FaultBackend::quiet(inner);
        fb.poison(256, 128);
        let mut buf = vec![0u8; 64];
        fb.read_at(0, &mut buf).unwrap(); // disjoint: fine
        for _ in 0..3 {
            assert!(fb.read_at(300, &mut buf).is_err(), "overlap keeps failing");
        }
        assert!(fb.read_at(250, &mut buf).is_err(), "straddling start fails");
        fb.heal();
        fb.read_at(300, &mut buf).unwrap();
        assert_eq!(buf, &data[300..364]);
    }

    #[test]
    fn persistent_mode_converts_hits_into_poison() {
        let (inner, _) = image(4096);
        let fb = FaultBackend::new(
            inner,
            FaultConfig {
                rate: 1.0,
                corruption_rate: 0.0,
                seed: 1,
                persistent: true,
            },
        );
        let mut buf = vec![0u8; 64];
        assert!(fb.read_at(64, &mut buf).is_err()); // draws + poisons
        fb.heal();
        // rate 1.0 still draws a fresh persistent fault post-heal
        assert!(fb.read_at(64, &mut buf).is_err());
    }
}
