//! Unified priority I/O scheduler — one disk service for every read
//! stream in the system.
//!
//! Before this module the disk layer had three independent consumers —
//! the decode prefetch pool, the engine's store-restore worker, and the
//! scrub maintainer — each issuing its own reads with no cross-stream
//! coalescing or prioritization. [`IoScheduler`] folds them into a
//! single service that owns the worker pool, the staging [`BufferPool`],
//! the retry budget, and the circuit breaker, and serves requests
//! through three priority lanes:
//!
//! * [`Lane::Critical`] — decode-blocking preloads. Always dispatched
//!   first; a decode step stalls on exactly these bytes.
//! * [`Lane::Warm`] — pipelined persistent-store restores. Hidden under
//!   prefill compute, so they yield to `Critical` but should still make
//!   steady progress.
//! * [`Lane::Background`] — scrub / maintenance reads. Strictly lowest
//!   priority, but protected from starvation: once the head request has
//!   waited longer than the configured aging bound it is promoted and
//!   dispatched next (`aged_promotions` counts these).
//!
//! ## Cross-plan coalescing
//!
//! When a worker picks a request it opens a *dispatch window*: up to
//! `dispatch_window - 1` additional queued requests (any lane, same
//! backing device) whose extents are gap-close to the group are merged
//! into one coalesced batched read, and the staged bytes are split back
//! per request afterwards. This is how warm-restore chunks of adjacent
//! layers — contiguous in the layer-major store layout — become one
//! sequential read instead of many random ones, and how a warm extent
//! adjacent to a critical run rides along for free. A merge is accepted
//! only when the combined run count is strictly lower than reading the
//! two plans separately (`cross_plan_merges` counts accepted riders).
//! Requests against *different* devices (the working-cache disk vs the
//! store's disk) never merge.
//!
//! ## Failure model
//!
//! The scheduler inherits the whole degradation ladder (see
//! [`super#failure-model--degradation-ladder`]) and applies it to every
//! lane uniformly:
//!
//! * each dispatch group carries its own [`RetryBudget`] drawn from the
//!   scheduler's policy — per-plan budgets stay per-lane because a
//!   group's budget is consumed only by the plans merged into it;
//! * a worker panic is contained per group (every member gets a typed
//!   `WorkerPanic` error) and the thread is respawned on a later submit;
//! * the [`CircuitBreaker`] watches threaded outcomes across *all*
//!   lanes: past `breaker_threshold` consecutive failures the whole
//!   scheduler degrades to synchronous routing — `submit` returns an
//!   inline ticket and the read runs on the caller's thread at `wait`
//!   time (preserving the accounting convention that an un-overlapped
//!   read charges its full modeled time) — until half-open probing
//!   closes it again.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::ReadReq;
use super::coalesce::{coalesce, Run};
use super::error::{DiskError, DiskResult};
use super::prefetch::{BufferPool, PrefetchCounters};
use super::relock;
use super::retry::RetryPolicy;
use super::sim::SimDisk;
use crate::config::PrefetchConfig;

/// Priority class of a scheduler request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Decode-blocking preloads: dispatched before everything else.
    Critical,
    /// Pipelined warm-start restores: yield to `Critical` only.
    Warm,
    /// Scrub/maintenance: lowest priority, aged to avoid starvation.
    Background,
}

pub const N_LANES: usize = 3;

impl Lane {
    pub fn idx(self) -> usize {
        match self {
            Lane::Critical => 0,
            Lane::Warm => 1,
            Lane::Background => 2,
        }
    }

    /// Stable lower-case label for logs and stats lines.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Critical => "critical",
            Lane::Warm => "warm",
            Lane::Background => "background",
        }
    }
}

/// Circuit-breaker state over the threaded pipeline (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: requests route through the worker pool.
    Closed,
    /// Tripped: requests route through the synchronous inline path.
    Open,
    /// One probe request is in flight through the pool; everything else
    /// stays inline until its verdict.
    HalfOpen,
}

impl BreakerState {
    /// Stable lower-case label for logs and the serve `stats` line.
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Consecutive-failure breaker with half-open probing. Not a separate
/// thread — driven entirely by `submit` (routing) and `wait` (outcomes),
/// so it adds no synchronization to the hot path beyond one short lock.
#[derive(Debug)]
struct CircuitBreaker {
    threshold: u32,
    probe_after: u32,
    state: BreakerState,
    consecutive_failures: u32,
    sync_successes: u32,
    probe_ticket: Option<u64>,
}

impl CircuitBreaker {
    fn new(threshold: u32, probe_after: u32) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            probe_after: probe_after.max(1),
            state: BreakerState::Closed,
            consecutive_failures: 0,
            sync_successes: 0,
            probe_ticket: None,
        }
    }

    /// Routing decision for a new ticket: `true` = worker pool.
    fn route_threaded(&mut self, ticket: u64) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if self.sync_successes >= self.probe_after {
                    self.state = BreakerState::HalfOpen;
                    self.probe_ticket = Some(ticket);
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => false,
        }
    }

    /// Feed an outcome; returns `true` when this failure tripped the
    /// breaker open (the caller counts the trip).
    fn on_result(&mut self, ticket: u64, threaded: bool, ok: bool) -> bool {
        if ok {
            match self.state {
                BreakerState::HalfOpen if threaded && self.probe_ticket == Some(ticket) => {
                    // probe survived: the pool is healthy again
                    self.state = BreakerState::Closed;
                    self.consecutive_failures = 0;
                    self.sync_successes = 0;
                    self.probe_ticket = None;
                }
                BreakerState::Closed if threaded => self.consecutive_failures = 0,
                BreakerState::Open if !threaded => self.sync_successes += 1,
                _ => {}
            }
            false
        } else {
            match self.state {
                BreakerState::Closed => {
                    if threaded {
                        self.consecutive_failures += 1;
                        if self.consecutive_failures >= self.threshold {
                            self.state = BreakerState::Open;
                            self.sync_successes = 0;
                            return true;
                        }
                    }
                    false
                }
                BreakerState::HalfOpen => {
                    // probe (or a straggler) failed: stay away from the pool
                    self.state = BreakerState::Open;
                    self.sync_successes = 0;
                    self.probe_ticket = None;
                    false
                }
                BreakerState::Open => {
                    self.sync_successes = 0;
                    false
                }
            }
        }
    }
}

/// One read request against one device, tagged with its priority lane.
/// `counters` is the *client's* counter block — staging work (extents,
/// runs, bytes, retries, corruption catches) is attributed to the stream
/// that asked for it, while pool-level events (panics, respawns, breaker
/// trips, lane stats) live in the scheduler's own counters.
pub struct IoRequest {
    pub lane: Lane,
    pub disk: Arc<SimDisk>,
    pub extents: Vec<(u64, usize)>,
    pub counters: Arc<PrefetchCounters>,
}

/// Staged bytes for one request: one chunk per input extent, in input
/// order, plus this request's share of the modeled device time (a merged
/// group's time is split proportionally by member bytes so virtual-clock
/// accounting never double-charges).
#[derive(Debug)]
pub struct IoCompletion {
    pub chunks: Vec<Vec<u8>>,
    pub io_time: Duration,
}

/// Handle for a submitted request; redeem with [`IoScheduler::wait`].
/// Dropping a ticket abandons the request — a late completion is
/// discarded when the reply channel disconnects.
pub struct Ticket {
    id: u64,
    threaded: bool,
    inner: TicketInner,
}

enum TicketInner {
    /// Queued to the worker pool; the reply arrives on this channel.
    Queued(Receiver<DiskResult<IoCompletion>>),
    /// Synchronous routing (no workers, or breaker open): the read runs
    /// on the caller's thread when the ticket is redeemed.
    Inline(Box<IoRequest>),
}

struct QueuedReq {
    id: u64,
    lane: Lane,
    disk: Arc<SimDisk>,
    extents: Vec<(u64, usize)>,
    counters: Arc<PrefetchCounters>,
    enqueued: Instant,
    reply: SyncSender<DiskResult<IoCompletion>>,
}

/// Scheduler-level counters: per-lane service stats plus pool-health
/// events that belong to the shared service rather than any one client.
#[derive(Default)]
struct SchedCounters {
    lane_dispatched: [AtomicU64; N_LANES],
    lane_wait_us: [AtomicU64; N_LANES],
    cross_plan_merges: AtomicU64,
    aged_promotions: AtomicU64,
    worker_panics: AtomicU64,
    workers_restarted: AtomicU64,
    breaker_trips: AtomicU64,
}

/// Snapshot of the scheduler counters (cumulative since construction).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneSummary {
    /// Requests served per lane (Critical, Warm, Background).
    pub lane_dispatched: [u64; N_LANES],
    /// Total queue wait per lane, microseconds (enqueue → dispatch).
    pub lane_wait_us: [u64; N_LANES],
    /// Queued requests merged into another plan's dispatch group.
    pub cross_plan_merges: u64,
    /// Background requests promoted past the strict-priority order
    /// because they aged beyond the starvation bound.
    pub aged_promotions: u64,
    /// Worker panics contained by the supervision layer.
    pub worker_panics: u64,
    /// Worker threads respawned after dying.
    pub workers_restarted: u64,
    /// Times the breaker tripped the scheduler into sync routing.
    pub breaker_trips: u64,
}

impl LaneSummary {
    /// Counter delta since `base` (for window-scoped reporting).
    pub fn since(&self, base: &LaneSummary) -> LaneSummary {
        let sub3 = |a: [u64; N_LANES], b: [u64; N_LANES]| {
            [
                a[0].saturating_sub(b[0]),
                a[1].saturating_sub(b[1]),
                a[2].saturating_sub(b[2]),
            ]
        };
        LaneSummary {
            lane_dispatched: sub3(self.lane_dispatched, base.lane_dispatched),
            lane_wait_us: sub3(self.lane_wait_us, base.lane_wait_us),
            cross_plan_merges: self.cross_plan_merges.saturating_sub(base.cross_plan_merges),
            aged_promotions: self.aged_promotions.saturating_sub(base.aged_promotions),
            worker_panics: self.worker_panics.saturating_sub(base.worker_panics),
            workers_restarted: self
                .workers_restarted
                .saturating_sub(base.workers_restarted),
            breaker_trips: self.breaker_trips.saturating_sub(base.breaker_trips),
        }
    }

    /// Mean queue wait for one lane, in microseconds.
    pub fn mean_wait_us(&self, lane: Lane) -> f64 {
        let i = lane.idx();
        if self.lane_dispatched[i] == 0 {
            return 0.0;
        }
        self.lane_wait_us[i] as f64 / self.lane_dispatched[i] as f64
    }
}

impl SchedCounters {
    fn summary(&self) -> LaneSummary {
        let load3 = |a: &[AtomicU64; N_LANES]| {
            [
                a[0].load(Ordering::Relaxed),
                a[1].load(Ordering::Relaxed),
                a[2].load(Ordering::Relaxed),
            ]
        };
        LaneSummary {
            lane_dispatched: load3(&self.lane_dispatched),
            lane_wait_us: load3(&self.lane_wait_us),
            cross_plan_merges: self.cross_plan_merges.load(Ordering::Relaxed),
            aged_promotions: self.aged_promotions.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            workers_restarted: self.workers_restarted.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
        }
    }

    fn note_dispatch(&self, lane: Lane, waited: Duration) {
        self.lane_dispatched[lane.idx()].fetch_add(1, Ordering::Relaxed);
        self.lane_wait_us[lane.idx()].fetch_add(waited.as_micros() as u64, Ordering::Relaxed);
    }
}

struct Queues {
    lanes: [VecDeque<QueuedReq>; N_LANES],
    closed: bool,
}

impl Queues {
    fn all_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }
}

struct Shared {
    q: Mutex<Queues>,
    cv: Condvar,
    pool: BufferPool,
    retry: RetryPolicy,
    breaker: Mutex<CircuitBreaker>,
    counters: SchedCounters,
    gap: u64,
    queue_depth: usize,
    dispatch_window: usize,
    aging: Duration,
    n_workers: usize,
}

impl Shared {
    /// Condvar-aware poison-recovering wait.
    fn cv_wait<'a>(&self, g: MutexGuard<'a, Queues>) -> MutexGuard<'a, Queues> {
        self.cv.wait(g).unwrap_or_else(|p| p.into_inner())
    }
}

/// The unified I/O service. Cheap to share (`Arc`); all methods take
/// `&self`. One instance per engine serves the prefetch pipeline
/// (`Critical`), the store-restore worker (`Warm`), and the scrub
/// maintainer (`Background`).
pub struct IoScheduler {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl IoScheduler {
    /// Build a scheduler from the pipeline knobs. `workers == 0` means
    /// every request routes inline (the synchronous baseline).
    pub fn new(cfg: &PrefetchConfig, retry: RetryPolicy) -> IoScheduler {
        let rc = retry.config();
        let breaker = CircuitBreaker::new(rc.breaker_threshold, rc.breaker_probe_after);
        let shared = Arc::new(Shared {
            q: Mutex::new(Queues {
                lanes: Default::default(),
                closed: false,
            }),
            cv: Condvar::new(),
            pool: BufferPool::new(2 * cfg.queue_depth.max(1)),
            retry,
            breaker: Mutex::new(breaker),
            counters: SchedCounters::default(),
            gap: cfg.coalesce_gap,
            queue_depth: cfg.queue_depth.max(1),
            dispatch_window: cfg.dispatch_window.max(1),
            aging: Duration::from_millis(cfg.aging_ms),
            n_workers: cfg.workers,
        });
        let workers = (0..cfg.workers)
            .map(|w| spawn_worker(w, shared.clone()))
            .collect();
        IoScheduler {
            shared,
            workers: Mutex::new(workers),
            next_id: AtomicU64::new(0),
        }
    }

    /// `true` when the scheduler was built with no workers — every
    /// request runs inline on the caller's thread at `wait` time.
    pub fn is_synchronous(&self) -> bool {
        self.shared.n_workers == 0
    }

    pub fn breaker_state(&self) -> BreakerState {
        relock(&self.shared.breaker).state
    }

    /// Cumulative lane/service counters since construction.
    pub fn lane_summary(&self) -> LaneSummary {
        self.shared.counters.summary()
    }

    /// Submit a request to its lane. Threaded routing blocks once the
    /// lane holds `queue_depth` requests (backpressure); inline routing
    /// (no workers, or breaker open) never blocks — the read happens at
    /// [`wait`](IoScheduler::wait).
    pub fn submit(&self, req: IoRequest) -> DiskResult<Ticket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let threaded = self.shared.n_workers > 0 && relock(&self.shared.breaker).route_threaded(id);
        if !threaded {
            if relock(&self.shared.q).closed {
                return Err(DiskError::QueueClosed);
            }
            return Ok(Ticket {
                id,
                threaded: false,
                inner: TicketInner::Inline(Box::new(req)),
            });
        }
        self.ensure_workers();
        let (reply, rx) = sync_channel(1);
        let lane = req.lane;
        let mut q = relock(&self.shared.q);
        loop {
            if q.closed {
                return Err(DiskError::QueueClosed);
            }
            if q.lanes[lane.idx()].len() < self.shared.queue_depth {
                break;
            }
            q = self.shared.cv_wait(q);
        }
        q.lanes[lane.idx()].push_back(QueuedReq {
            id,
            lane,
            disk: req.disk,
            extents: req.extents,
            counters: req.counters,
            enqueued: Instant::now(),
            reply,
        });
        drop(q);
        self.shared.cv.notify_all();
        Ok(Ticket {
            id,
            threaded: true,
            inner: TicketInner::Queued(rx),
        })
    }

    /// Redeem a ticket: block (up to `timeout`) for the staged bytes.
    /// Inline tickets execute the read here, on the caller's thread —
    /// that keeps the synchronous baseline's accounting honest (nothing
    /// ran before the caller asked). Every outcome, including a timeout,
    /// feeds the breaker.
    pub fn wait(&self, ticket: Ticket, timeout: Duration) -> DiskResult<IoCompletion> {
        let Ticket {
            id,
            threaded,
            inner,
        } = ticket;
        let result = match inner {
            TicketInner::Inline(req) => self.serve_inline(*req),
            TicketInner::Queued(rx) => match rx.recv_timeout(timeout) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => Err(DiskError::Timeout { waited: timeout }),
                Err(RecvTimeoutError::Disconnected) => Err(DiskError::QueueClosed),
            },
        };
        if relock(&self.shared.breaker).on_result(id, threaded, result.is_ok()) {
            self.shared
                .counters
                .breaker_trips
                .fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn serve_inline(&self, req: IoRequest) -> DiskResult<IoCompletion> {
        let sh = &self.shared;
        sh.counters.note_dispatch(req.lane, Duration::ZERO);
        let members = [GroupMember {
            extents: &req.extents,
            counters: &req.counters,
        }];
        // Inline reads stay panic-contained too: a poisoned backend must
        // degrade this one request, not unwind the engine thread.
        match catch_unwind(AssertUnwindSafe(|| {
            read_group(&req.disk, &members, sh.gap, &sh.pool, &sh.retry)
        })) {
            Ok(r) => r.map(|(mut chunks, mut times)| IoCompletion {
                chunks: chunks.pop().unwrap_or_default(),
                io_time: times.pop().unwrap_or(Duration::ZERO),
            }),
            Err(payload) => {
                sh.counters.worker_panics.fetch_add(1, Ordering::Relaxed);
                Err(panic_error(payload))
            }
        }
    }

    /// Respawn any worker whose thread has exited (a contained panic
    /// recycles the thread). Called from `submit` before enqueueing.
    fn ensure_workers(&self) {
        let mut workers = relock(&self.workers);
        for i in 0..workers.len() {
            if workers[i].is_finished() {
                let fresh = spawn_worker(i, self.shared.clone());
                let dead = std::mem::replace(&mut workers[i], fresh);
                let _ = dead.join();
                self.shared
                    .counters
                    .workers_restarted
                    .fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Close the scheduler: refuse new work, drop queued requests (their
    /// waiters see `QueueClosed`), and join workers — bounded by `grace`.
    /// A worker that outlives the grace period is detached rather than
    /// hanging shutdown.
    pub fn shutdown(&self, grace: Duration) {
        {
            let mut q = relock(&self.shared.q);
            q.closed = true;
            for lane in q.lanes.iter_mut() {
                lane.clear(); // dropping replies disconnects waiters
            }
        }
        self.shared.cv.notify_all();
        let deadline = Instant::now() + grace;
        for h in relock(&self.workers).drain(..) {
            while !h.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            if h.is_finished() {
                let _ = h.join();
            }
            // else: detach — a wedged worker must not hang shutdown
        }
    }
}

impl Drop for IoScheduler {
    fn drop(&mut self) {
        self.shutdown(Duration::from_secs(5));
    }
}

fn spawn_worker(idx: usize, shared: Arc<Shared>) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("kvswap-io-{idx}"))
        .spawn(move || worker_loop(&shared))
        .expect("spawn io scheduler worker")
}

fn worker_loop(shared: &Shared) {
    loop {
        let group = {
            let mut q = relock(&shared.q);
            loop {
                if q.closed {
                    return;
                }
                if !q.all_empty() {
                    break;
                }
                // bounded wait so aged Background promotion is observed
                // even when no submit/pop wakes us
                let (g, _) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(5))
                    .unwrap_or_else(|p| p.into_inner());
                q = g;
            }
            let primary = pop_primary(&mut q, shared);
            take_group(&mut q, primary, shared)
        };
        shared.cv.notify_all(); // queue space freed: wake submitters
        for m in &group {
            shared.counters.note_dispatch(m.lane, m.enqueued.elapsed());
        }
        if !serve_group(shared, group) {
            // a thread that panicked once is recycled after delivering
            // the typed errors; `ensure_workers` respawns it
            return;
        }
    }
}

/// Strict-priority pop with Background aging: the head Background
/// request preempts everything once it has waited past the bound.
fn pop_primary(q: &mut Queues, shared: &Shared) -> QueuedReq {
    if let Some(b) = q.lanes[Lane::Background.idx()].front() {
        if b.enqueued.elapsed() >= shared.aging {
            shared
                .counters
                .aged_promotions
                .fetch_add(1, Ordering::Relaxed);
            return q.lanes[Lane::Background.idx()].pop_front().unwrap();
        }
    }
    for lane in 0..N_LANES {
        if let Some(r) = q.lanes[lane].pop_front() {
            return r;
        }
    }
    unreachable!("pop_primary called with all lanes empty")
}

/// Open the dispatch window: pull queued requests (any lane, same
/// device) whose extents coalesce with the group — strictly fewer
/// combined runs than reading the plans separately.
fn take_group(q: &mut Queues, primary: QueuedReq, shared: &Shared) -> Vec<QueuedReq> {
    let mut group = vec![primary];
    if shared.dispatch_window <= 1 {
        return group;
    }
    let mut extents: Vec<(u64, usize)> = group[0].extents.clone();
    let mut n_runs = coalesce(&extents, shared.gap).len();
    for lane in 0..N_LANES {
        let mut i = 0;
        while i < q.lanes[lane].len() && group.len() < shared.dispatch_window {
            let cand = &q.lanes[lane][i];
            if !Arc::ptr_eq(&cand.disk, &group[0].disk) || cand.extents.is_empty() {
                i += 1;
                continue;
            }
            let cand_runs = coalesce(&cand.extents, shared.gap).len();
            let mut combined = extents.clone();
            combined.extend(cand.extents.iter().copied());
            let combined_runs = coalesce(&combined, shared.gap).len();
            if combined_runs < n_runs + cand_runs {
                extents = combined;
                n_runs = combined_runs;
                let c = q.lanes[lane].remove(i).expect("candidate indexed");
                shared
                    .counters
                    .cross_plan_merges
                    .fetch_add(1, Ordering::Relaxed);
                group.push(c);
            } else {
                i += 1;
            }
        }
    }
    group
}

/// Serve one dispatch group; returns `false` when the worker thread
/// should recycle itself (a contained panic).
fn serve_group(shared: &Shared, group: Vec<QueuedReq>) -> bool {
    let members: Vec<GroupMember> = group
        .iter()
        .map(|m| GroupMember {
            extents: &m.extents,
            counters: &m.counters,
        })
        .collect();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        read_group(&group[0].disk, &members, shared.gap, &shared.pool, &shared.retry)
    }));
    drop(members);
    match outcome {
        Ok(Ok((chunks, times))) => {
            for (m, (c, t)) in group.into_iter().zip(chunks.into_iter().zip(times)) {
                let _ = m.reply.send(Ok(IoCompletion {
                    chunks: c,
                    io_time: t,
                }));
            }
            true
        }
        Ok(Err(e)) => {
            // the group fails together: every member sees the same kind
            for m in &group {
                let _ = m.reply.send(Err(clone_kind(&e)));
            }
            true
        }
        Err(payload) => {
            shared.counters.worker_panics.fetch_add(1, Ordering::Relaxed);
            let e = panic_error(payload);
            for m in &group {
                let _ = m.reply.send(Err(clone_kind(&e)));
            }
            false
        }
    }
}

fn panic_error(payload: Box<dyn std::any::Any + Send>) -> DiskError {
    let what = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string());
    DiskError::WorkerPanic { what }
}

/// Reconstruct an error of the same kind for each member of a failed
/// group (`DiskError` holds an `io::Error` source, so it is not `Clone`).
fn clone_kind(e: &DiskError) -> DiskError {
    match e {
        DiskError::OutOfBounds { offset, len, size } => DiskError::OutOfBounds {
            offset: *offset,
            len: *len,
            size: *size,
        },
        DiskError::Io {
            source,
            offset,
            len,
        } => DiskError::io(
            std::io::Error::new(source.kind(), source.to_string()),
            *offset,
            *len,
        ),
        DiskError::QueueClosed => DiskError::QueueClosed,
        DiskError::Timeout { waited } => DiskError::Timeout { waited: *waited },
        DiskError::Corrupt {
            offset,
            len,
            expect,
            got,
        } => DiskError::corrupt(*offset, *len, *expect, *got),
        DiskError::WorkerPanic { what } => DiskError::WorkerPanic { what: what.clone() },
    }
}

/// One member of a dispatch group: its extents and the client counter
/// block its staging work is attributed to.
pub(crate) struct GroupMember<'a> {
    pub extents: &'a [(u64, usize)],
    pub counters: &'a PrefetchCounters,
}

/// Read a dispatch group through run coalescing: flatten every member's
/// extents, merge near-adjacent ones (byte gap ≤ `gap`) into single
/// [`ReadReq`]s, issue one batched read, then scatter each extent's
/// bytes back per member in input order. Returns per-member chunk lists
/// and each member's proportional share of the modeled device time.
///
/// Fault tolerance matches the original single-plan path exactly: the
/// first attempt is one batched submission; staged extents are verified
/// against their write-time checksums; failed runs are re-issued
/// individually with jittered backoff. Each member draws its own
/// [`RetryBudget`] — a re-issue consumes budget from every member with
/// an extent in the failing run, so merged plans cannot steal each
/// other's whole budget.
pub(crate) fn read_group(
    disk: &SimDisk,
    members: &[GroupMember],
    gap: u64,
    pool: &BufferPool,
    retry: &RetryPolicy,
) -> DiskResult<(Vec<Vec<Vec<u8>>>, Vec<Duration>)> {
    // flatten with an owner map: flat extent index → member index
    let mut extents: Vec<(u64, usize)> = Vec::new();
    let mut owner: Vec<usize> = Vec::new();
    for (mi, m) in members.iter().enumerate() {
        m.counters.add_extents(m.extents.len() as u64);
        for &e in m.extents {
            extents.push(e);
            owner.push(mi);
        }
    }
    if extents.is_empty() {
        return Ok((
            members.iter().map(|_| Vec::new()).collect(),
            vec![Duration::ZERO; members.len()],
        ));
    }
    let runs = coalesce(&extents, gap);
    for ri in 0..runs.len() {
        for mi in run_owners(&runs[ri], &owner) {
            members[mi].counters.add_runs(1);
        }
    }
    disk.stats()
        .record_coalesce(extents.len() as u64, runs.len() as u64);

    let mut reqs: Vec<ReadReq> = runs
        .iter()
        .map(|r| ReadReq::with_buf(r.offset, pool.take(), r.len))
        .collect();
    let mut io_time = Duration::ZERO;
    let mut budgets: Vec<_> = members.iter().map(|_| retry.budget()).collect();

    // First attempt: the whole group as one batched submission.
    let pending: Vec<usize> = match disk.read_batch(&mut reqs) {
        Ok(d) => {
            io_time += d;
            (0..runs.len())
                .filter(|&ri| verify_run(disk, &runs[ri], &reqs[ri], &extents, &owner, members).is_err())
                .collect()
        }
        Err(e) if e.is_retryable() => (0..runs.len()).collect(),
        Err(e) => return Err(e),
    };

    // Recovery: re-issue only the failed runs, individually, under the
    // owning members' budgets. Every read here is a re-issue of a run
    // that already failed once (batched error or checksum mismatch), so
    // each counts as a retry whether or not it succeeds.
    for ri in pending {
        let owners = run_owners(&runs[ri], &owner);
        let mut attempt = 0u32;
        loop {
            for &mi in &owners {
                members[mi].counters.add_retry();
            }
            disk.stats().record_retry();
            let read = disk.read_batch(std::slice::from_mut(&mut reqs[ri]));
            let verified = read.and_then(|d| {
                verify_run(disk, &runs[ri], &reqs[ri], &extents, &owner, members)?;
                Ok(d)
            });
            match verified {
                Ok(d) => {
                    io_time += d;
                    break;
                }
                Err(e) => {
                    let exhausted = owners.iter().any(|&mi| !budgets[mi].try_consume());
                    if !e.is_retryable() || exhausted {
                        return Err(e);
                    }
                    retry.sleep_before_retry(attempt);
                    attempt += 1;
                }
            }
        }
    }

    // Scatter per member, in each member's extent order.
    let mut out: Vec<Vec<Vec<u8>>> = members
        .iter()
        .map(|m| vec![Vec::new(); m.extents.len()])
        .collect();
    let mut member_start: Vec<usize> = Vec::with_capacity(members.len());
    let mut acc = 0usize;
    for m in members {
        member_start.push(acc);
        acc += m.extents.len();
    }
    let mut member_bytes = vec![0u64; members.len()];
    for (run, req) in runs.iter().zip(&reqs) {
        for &(idx, delta) in &run.members {
            let mi = owner[idx];
            let len = extents[idx].1;
            out[mi][idx - member_start[mi]] = req.buf[delta..delta + len].to_vec();
            member_bytes[mi] += len as u64;
        }
    }
    for (mi, m) in members.iter().enumerate() {
        m.counters.add_bytes(member_bytes[mi]);
    }
    for req in reqs {
        pool.put(req.buf);
    }

    // Split the modeled device time proportionally by member bytes so a
    // merged group never double-charges the virtual clock.
    let total_bytes: u64 = member_bytes.iter().sum();
    let times = if members.len() == 1 {
        vec![io_time]
    } else {
        member_bytes
            .iter()
            .map(|&b| {
                if total_bytes == 0 {
                    Duration::ZERO
                } else {
                    Duration::from_secs_f64(io_time.as_secs_f64() * b as f64 / total_bytes as f64)
                }
            })
            .collect()
    };
    Ok((out, times))
}

/// Distinct member indices owning at least one extent in `run`,
/// ascending.
fn run_owners(run: &Run, owner: &[usize]) -> Vec<usize> {
    let mut owners: Vec<usize> = run.members.iter().map(|&(idx, _)| owner[idx]).collect();
    owners.sort_unstable();
    owners.dedup();
    owners
}

/// Verify every member extent of `run` against its write-time checksum,
/// attributing a catch to the owning member's counters. Extents the disk
/// never stamped at exactly that (offset, len) pass.
fn verify_run(
    disk: &SimDisk,
    run: &Run,
    req: &ReadReq,
    extents: &[(u64, usize)],
    owner: &[usize],
    members: &[GroupMember],
) -> DiskResult<()> {
    for &(idx, delta) in &run.members {
        let (offset, len) = extents[idx];
        if let Err(e) = disk.verify_extent(offset, &req.buf[delta..delta + len]) {
            members[owner[idx]].counters.add_corrupt();
            return Err(e);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RetryConfig;
    use crate::disk::backend::{Backend, MemBackend};
    use crate::disk::profile::DiskProfile;

    fn disk_with_image(n: usize) -> (Arc<SimDisk>, Vec<u8>) {
        let image: Vec<u8> = (0..n).map(|i| (i * 37 % 239) as u8).collect();
        let backend = Arc::new(MemBackend::new());
        backend.write_at(0, &image).unwrap();
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), backend, None));
        (disk, image)
    }

    fn cfg(workers: usize, depth: usize, window: usize, aging_ms: u64) -> PrefetchConfig {
        PrefetchConfig {
            workers,
            queue_depth: depth,
            coalesce_gap: 64,
            dispatch_window: window,
            aging_ms,
            unified_io: true,
        }
    }

    fn fast_retry() -> RetryPolicy {
        RetryPolicy::new(RetryConfig {
            max_retries: 2,
            backoff_base_ms: 0.05,
            backoff_max_ms: 0.2,
            jitter: 0.5,
            breaker_threshold: 4,
            breaker_probe_after: 8,
        })
    }

    fn req(disk: &Arc<SimDisk>, lane: Lane, extents: &[(u64, usize)]) -> IoRequest {
        IoRequest {
            lane,
            disk: disk.clone(),
            extents: extents.to_vec(),
            counters: Arc::new(PrefetchCounters::default()),
        }
    }

    #[test]
    fn lanes_have_stable_names_and_indices() {
        assert_eq!(Lane::Critical.idx(), 0);
        assert_eq!(Lane::Warm.idx(), 1);
        assert_eq!(Lane::Background.idx(), 2);
        assert_eq!(Lane::Warm.name(), "warm");
    }

    #[test]
    fn inline_scheduler_serves_at_wait_time() {
        let (disk, image) = disk_with_image(4096);
        let s = IoScheduler::new(&cfg(0, 2, 4, 50), fast_retry());
        assert!(s.is_synchronous());
        let t = s.submit(req(&disk, Lane::Critical, &[(0, 128), (256, 64)])).unwrap();
        let c = s.wait(t, Duration::from_secs(1)).unwrap();
        assert_eq!(c.chunks[0], &image[..128]);
        assert_eq!(c.chunks[1], &image[256..320]);
        assert!(c.io_time > Duration::ZERO);
        let ls = s.lane_summary();
        assert_eq!(ls.lane_dispatched, [1, 0, 0]);
    }

    #[test]
    fn threaded_scheduler_serves_all_lanes() {
        let (disk, image) = disk_with_image(1 << 14);
        let s = IoScheduler::new(&cfg(2, 4, 4, 50), fast_retry());
        let tickets: Vec<(Ticket, u64, usize)> = [
            (Lane::Critical, 0u64, 512usize),
            (Lane::Warm, 1024, 256),
            (Lane::Background, 4096, 128),
        ]
        .into_iter()
        .map(|(lane, off, len)| (s.submit(req(&disk, lane, &[(off, len)])).unwrap(), off, len))
        .collect();
        for (t, off, len) in tickets {
            let c = s.wait(t, Duration::from_secs(5)).unwrap();
            assert_eq!(c.chunks[0], &image[off as usize..off as usize + len]);
        }
        let ls = s.lane_summary();
        assert_eq!(ls.lane_dispatched.iter().sum::<u64>(), 3);
    }

    #[test]
    fn empty_request_completes_with_no_io() {
        let (disk, _) = disk_with_image(1024);
        let s = IoScheduler::new(&cfg(1, 2, 4, 50), fast_retry());
        let t = s.submit(req(&disk, Lane::Warm, &[])).unwrap();
        let c = s.wait(t, Duration::from_secs(1)).unwrap();
        assert!(c.chunks.is_empty());
        assert_eq!(c.io_time, Duration::ZERO);
    }

    #[test]
    fn shutdown_disconnects_queued_waiters() {
        let (disk, _) = disk_with_image(4096);
        let s = IoScheduler::new(&cfg(1, 4, 1, 50), fast_retry());
        let t = s.submit(req(&disk, Lane::Critical, &[(0, 64)])).unwrap();
        // let the worker serve it, then close
        let _ = s.wait(t, Duration::from_secs(5)).unwrap();
        s.shutdown(Duration::from_secs(2));
        assert!(matches!(
            s.submit(req(&disk, Lane::Critical, &[(0, 64)])),
            Err(DiskError::QueueClosed)
        ));
    }

    #[test]
    fn dropped_ticket_abandons_request_without_wedging_pool() {
        let (disk, image) = disk_with_image(8192);
        let s = IoScheduler::new(&cfg(1, 4, 1, 50), fast_retry());
        let t0 = s.submit(req(&disk, Lane::Critical, &[(0, 128)])).unwrap();
        drop(t0); // abandoned: completion send fails, worker moves on
        let t1 = s.submit(req(&disk, Lane::Critical, &[(512, 128)])).unwrap();
        let c = s.wait(t1, Duration::from_secs(5)).unwrap();
        assert_eq!(c.chunks[0], &image[512..640]);
    }

    #[test]
    fn merged_group_splits_io_time_by_bytes() {
        let (disk, image) = disk_with_image(1 << 14);
        // single worker + a held queue: submit two adjacent plans before
        // the worker can pop, so the second merges into the first's group
        let s = IoScheduler::new(&cfg(1, 8, 4, 50), fast_retry());
        // stall the worker on an unrelated far-away read first
        let warmup = s.submit(req(&disk, Lane::Critical, &[(12000, 64)])).unwrap();
        let ta = s.submit(req(&disk, Lane::Warm, &[(0, 256)])).unwrap();
        let tb = s.submit(req(&disk, Lane::Warm, &[(256, 256)])).unwrap();
        let _ = s.wait(warmup, Duration::from_secs(5)).unwrap();
        let ca = s.wait(ta, Duration::from_secs(5)).unwrap();
        let cb = s.wait(tb, Duration::from_secs(5)).unwrap();
        assert_eq!(ca.chunks[0], &image[..256]);
        assert_eq!(cb.chunks[0], &image[256..512]);
        // merging is timing-dependent (the worker may pop one at a time),
        // but when it happens the split must conserve modeled time
        let ls = s.lane_summary();
        if ls.cross_plan_merges > 0 {
            assert!(ca.io_time > Duration::ZERO && cb.io_time > Duration::ZERO);
        }
    }

    #[test]
    fn read_group_attributes_counters_per_member() {
        let (disk, image) = disk_with_image(1 << 13);
        let pool = BufferPool::new(4);
        let retry = fast_retry();
        let c0 = PrefetchCounters::default();
        let c1 = PrefetchCounters::default();
        let m0 = [(0u64, 128usize), (128, 128)];
        let m1 = [(256u64, 128usize)];
        let members = [
            GroupMember {
                extents: &m0,
                counters: &c0,
            },
            GroupMember {
                extents: &m1,
                counters: &c1,
            },
        ];
        let (chunks, times) = read_group(&disk, &members, 64, &pool, &retry).unwrap();
        assert_eq!(chunks[0][0], &image[..128]);
        assert_eq!(chunks[0][1], &image[128..256]);
        assert_eq!(chunks[1][0], &image[256..384]);
        let s0 = c0.summary();
        let s1 = c1.summary();
        assert_eq!(s0.extents, 2);
        assert_eq!(s1.extents, 1);
        assert_eq!(s0.bytes_staged, 256);
        assert_eq!(s1.bytes_staged, 128);
        // all three extents coalesce into one run, owned by both members
        assert_eq!(s0.runs, 1);
        assert_eq!(s1.runs, 1);
        // proportional time split: member 0 staged 2× member 1's bytes
        let (t0, t1) = (times[0].as_secs_f64(), times[1].as_secs_f64());
        assert!(t0 > 0.0 && t1 > 0.0);
        assert!((t0 / t1 - 2.0).abs() < 0.05, "t0/t1 = {}", t0 / t1);
    }

    #[test]
    fn background_head_is_promoted_past_aging_bound() {
        let (disk, image) = disk_with_image(1 << 14);
        let s = IoScheduler::new(&cfg(1, 16, 1, 10), fast_retry());
        // park a background request while a stream of critical work keeps
        // the lane busy; strict priority alone would starve it
        let tb = s.submit(req(&disk, Lane::Background, &[(8192, 64)])).unwrap();
        let mut crit = VecDeque::new();
        let deadline = Instant::now() + Duration::from_millis(400);
        let mut served_background = false;
        while Instant::now() < deadline {
            crit.push_back(s.submit(req(&disk, Lane::Critical, &[(0, 128)])).unwrap());
            if crit.len() >= 4 {
                let t = crit.pop_front().unwrap();
                let _ = s.wait(t, Duration::from_secs(5)).unwrap();
            }
            if s.lane_summary().lane_dispatched[Lane::Background.idx()] > 0 {
                served_background = true;
                break;
            }
        }
        assert!(served_background, "background starved under critical load");
        assert!(s.lane_summary().aged_promotions >= 1);
        for t in crit {
            let _ = s.wait(t, Duration::from_secs(5));
        }
        let c = s.wait(tb, Duration::from_secs(5)).unwrap();
        assert_eq!(c.chunks[0], &image[8192..8256]);
    }
}
