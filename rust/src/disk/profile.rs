//! Storage-device timing profiles.
//!
//! The paper's testbed has real NVMe (1.8 GB/s) and eMMC (250 MB/s)
//! devices; UFS is "similar to NVMe" (paper footnote 2). We model a
//! device by: peak bandwidth, per-operation setup latency, and a physical
//! access granule (NAND page / controller read unit). The controller
//! reads whole granules ("read amplification", paper §1 & [27,45]), so
//! effective bandwidth collapses for small requests — this model
//! reproduces the shape of the paper's Fig. 2 directly (see
//! `benches/fig2_bandwidth.rs`).

use std::time::Duration;

#[derive(Debug, Clone, PartialEq)]
pub struct DiskProfile {
    pub name: &'static str,
    /// Peak sustained read bandwidth, bytes/second.
    pub read_bw: f64,
    /// Peak sustained write bandwidth, bytes/second.
    pub write_bw: f64,
    /// Per-operation setup latency (command issue + device latency).
    pub op_latency: Duration,
    /// Physical read granule: a request touching any byte of a granule
    /// pays for the whole granule.
    pub page_bytes: u64,
    /// Native command queue depth: how many outstanding ops the device
    /// overlaps (NVMe NCQ >= 16; eMMC CQE ~4; SD none).
    pub queue_depth: u32,
}

impl DiskProfile {
    pub fn nvme() -> DiskProfile {
        DiskProfile {
            name: "nvme",
            read_bw: 1.8e9,
            write_bw: 1.2e9,
            op_latency: Duration::from_micros(80),
            page_bytes: 4096,
            queue_depth: 16,
        }
    }

    pub fn emmc() -> DiskProfile {
        DiskProfile {
            name: "emmc",
            read_bw: 250e6,
            write_bw: 120e6,
            op_latency: Duration::from_micros(250),
            page_bytes: 16384,
            queue_depth: 4,
        }
    }

    /// UFS: paper footnote 2 — "I/O bandwidth and characteristics similar
    /// to NVMe", slightly lower peak.
    pub fn ufs() -> DiskProfile {
        DiskProfile {
            name: "ufs",
            read_bw: 1.2e9,
            write_bw: 0.8e9,
            op_latency: Duration::from_micros(120),
            page_bytes: 4096,
            queue_depth: 8,
        }
    }

    /// SD-card class (the paper's "<200 MB/s low-bandwidth device" regime).
    pub fn sd() -> DiskProfile {
        DiskProfile {
            name: "sd",
            read_bw: 90e6,
            write_bw: 40e6,
            op_latency: Duration::from_micros(600),
            page_bytes: 32768,
            queue_depth: 1,
        }
    }

    pub fn by_name(name: &str) -> Option<DiskProfile> {
        match name {
            "nvme" => Some(Self::nvme()),
            "emmc" => Some(Self::emmc()),
            "ufs" => Some(Self::ufs()),
            "sd" => Some(Self::sd()),
            _ => None,
        }
    }

    /// Physical bytes actually moved for a logical read [offset, offset+len):
    /// whole granules touched (read amplification).
    pub fn physical_bytes(&self, offset: u64, len: u64) -> u64 {
        if len == 0 {
            return 0;
        }
        let first = offset / self.page_bytes;
        let last = (offset + len - 1) / self.page_bytes;
        (last - first + 1) * self.page_bytes
    }

    /// Modeled duration of one read op.
    pub fn read_time(&self, offset: u64, len: u64) -> Duration {
        let phys = self.physical_bytes(offset, len);
        self.op_latency + Duration::from_secs_f64(phys as f64 / self.read_bw)
    }

    /// Modeled duration of one write op (writes are granule-aligned too).
    pub fn write_time(&self, offset: u64, len: u64) -> Duration {
        let phys = self.physical_bytes(offset, len);
        self.op_latency + Duration::from_secs_f64(phys as f64 / self.write_bw)
    }

    /// Modeled duration of `n` independent read ops of `len` bytes each
    /// issued together: the device overlaps command latency across its
    /// native queue depth, transfers serialize on the bus.
    pub fn batched_read_time(&self, total_phys: u64, n_ops: u64) -> Duration {
        if n_ops == 0 {
            return Duration::ZERO;
        }
        let waves = n_ops.div_ceil(self.queue_depth.max(1) as u64);
        self.op_latency * waves as u32
            + Duration::from_secs_f64(total_phys as f64 / self.read_bw)
    }

    /// Effective bandwidth for aligned reads of `block` bytes — the
    /// quantity Fig. 2 plots (normalized to `read_bw`).
    pub fn effective_read_bw(&self, block: u64) -> f64 {
        let t = self.read_time(0, block);
        block as f64 / t.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn physical_bytes_rounds_to_pages() {
        let p = DiskProfile::nvme(); // 4K pages
        assert_eq!(p.physical_bytes(0, 1), 4096);
        assert_eq!(p.physical_bytes(0, 4096), 4096);
        assert_eq!(p.physical_bytes(0, 4097), 8192);
        assert_eq!(p.physical_bytes(4095, 2), 8192); // straddles boundary
        assert_eq!(p.physical_bytes(8192, 4096), 4096);
        assert_eq!(p.physical_bytes(100, 0), 0);
    }

    #[test]
    fn small_reads_waste_bandwidth() {
        // Paper §2.3: at 512 B (one KV entry) effective bandwidth is <6%
        // of peak for both NVMe and eMMC.
        for p in [DiskProfile::nvme(), DiskProfile::emmc()] {
            let frac = p.effective_read_bw(512) / p.read_bw;
            assert!(frac < 0.06, "{}: {frac}", p.name);
        }
    }

    #[test]
    fn large_reads_approach_peak() {
        for p in [DiskProfile::nvme(), DiskProfile::emmc(), DiskProfile::ufs()] {
            let frac = p.effective_read_bw(8 * 1024 * 1024) / p.read_bw;
            assert!(frac > 0.85, "{}: {frac}", p.name);
        }
    }

    #[test]
    fn effective_bw_monotone_in_block_size() {
        let p = DiskProfile::emmc();
        let mut prev = 0.0;
        for shift in 9..24 {
            let bw = p.effective_read_bw(1 << shift);
            assert!(bw >= prev);
            prev = bw;
        }
    }

    #[test]
    fn nvme_much_faster_than_emmc() {
        let n = DiskProfile::nvme();
        let e = DiskProfile::emmc();
        let tn = n.read_time(0, 1 << 20).as_secs_f64();
        let te = e.read_time(0, 1 << 20).as_secs_f64();
        assert!(te / tn > 4.0);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(DiskProfile::by_name("nvme").unwrap().name, "nvme");
        assert_eq!(DiskProfile::by_name("sd").unwrap().page_bytes, 32768);
        assert!(DiskProfile::by_name("floppy").is_none());
    }
}
