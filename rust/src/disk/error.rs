//! Typed storage errors for the `disk` module's public API.
//!
//! The prefetch pipeline and retry logic need to *match* on failure kind
//! (a bounds bug is fatal, a closed queue means shutdown, a timeout may
//! be retried) rather than string-matching opaque error messages.
//! Everything inside `disk/` speaks `DiskError`; callers convert to
//! their generic error type at the engine boundary via the std `Error`
//! impl.

use std::fmt;
use std::time::Duration;

/// Result alias used throughout the `disk` module.
pub type DiskResult<T> = Result<T, DiskError>;

/// Storage failure, by kind.
#[derive(Debug)]
pub enum DiskError {
    /// A read past the end of the backing store, or an offset/length pair
    /// that overflows the address space.
    OutOfBounds {
        offset: u64,
        len: usize,
        /// Current size of the backing store.
        size: u64,
    },
    /// An underlying I/O failure (real-file backends), tagged with the
    /// extent that was being accessed.
    Io {
        source: std::io::Error,
        offset: u64,
        len: usize,
    },
    /// The prefetch queue (or its worker pool) has shut down.
    QueueClosed,
    /// A staged buffer did not arrive within the wait bound.
    Timeout { waited: Duration },
    /// A staged extent failed its write-time checksum: the bytes on (or
    /// coming back from) the device are not the bytes that were written.
    Corrupt {
        offset: u64,
        len: usize,
        expect: u64,
        got: u64,
    },
    /// A prefetch worker panicked mid-plan; the panic was contained and
    /// the plan is reported failed instead of unwinding the engine.
    WorkerPanic { what: String },
}

impl DiskError {
    /// Tag an `io::Error` with the extent being accessed.
    pub fn io(source: std::io::Error, offset: u64, len: usize) -> DiskError {
        DiskError::Io {
            source,
            offset,
            len,
        }
    }

    /// Checksum-mismatch constructor used by the integrity layer.
    pub fn corrupt(offset: u64, len: usize, expect: u64, got: u64) -> DiskError {
        DiskError::Corrupt {
            offset,
            len,
            expect,
            got,
        }
    }

    /// Whether a retry of the same operation can plausibly succeed.
    ///
    /// * `Io` — transient device errors (and injected faults) clear on
    ///   re-issue; persistent ones exhaust the retry budget and surface.
    /// * `Corrupt` — a re-read replaces the damaged staging bytes unless
    ///   the medium itself lost the data.
    /// * `Timeout` / `WorkerPanic` — the *plan* can be re-staged (e.g.
    ///   synchronously after the circuit breaker trips).
    /// * `OutOfBounds` / `QueueClosed` — logic errors or shutdown;
    ///   retrying can never help.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            DiskError::Io { .. }
                | DiskError::Corrupt { .. }
                | DiskError::Timeout { .. }
                | DiskError::WorkerPanic { .. }
        )
    }
}

impl fmt::Display for DiskError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DiskError::OutOfBounds { offset, len, size } => write!(
                f,
                "read/write out of bounds: offset {offset} + len {len} exceeds backing size {size}"
            ),
            DiskError::Io {
                source,
                offset,
                len,
            } => write!(f, "storage I/O error at offset {offset} (len {len}): {source}"),
            DiskError::QueueClosed => write!(f, "prefetch queue closed"),
            DiskError::Timeout { waited } => {
                write!(f, "staged buffer not ready after {waited:?}")
            }
            DiskError::Corrupt {
                offset,
                len,
                expect,
                got,
            } => write!(
                f,
                "checksum mismatch at offset {offset} (len {len}): \
                 expected {expect:#018x}, got {got:#018x}"
            ),
            DiskError::WorkerPanic { what } => {
                write!(f, "prefetch worker panicked: {what}")
            }
        }
    }
}

impl std::error::Error for DiskError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DiskError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_extent_context() {
        let e = DiskError::OutOfBounds {
            offset: 100,
            len: 8,
            size: 64,
        };
        let s = e.to_string();
        assert!(s.contains("100") && s.contains('8') && s.contains("64"), "{s}");

        let io = DiskError::io(
            std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof"),
            42,
            512,
        );
        assert!(io.to_string().contains("42"));
    }

    #[test]
    fn error_kinds_are_matchable() {
        // the whole point of the typed enum: callers branch on kind
        let errs = [
            DiskError::QueueClosed,
            DiskError::Timeout {
                waited: Duration::from_secs(1),
            },
        ];
        let retryable = errs
            .iter()
            .filter(|e| matches!(e, DiskError::Timeout { .. }))
            .count();
        assert_eq!(retryable, 1);
    }

    #[test]
    fn retryable_classification_drives_recovery() {
        assert!(DiskError::io(std::io::Error::other("transient"), 0, 8).is_retryable());
        assert!(DiskError::corrupt(64, 32, 1, 2).is_retryable());
        assert!(DiskError::Timeout {
            waited: Duration::from_millis(5)
        }
        .is_retryable());
        assert!(DiskError::WorkerPanic {
            what: "boom".into()
        }
        .is_retryable());
        // logic errors and shutdown must never be retried
        assert!(!DiskError::OutOfBounds {
            offset: 9,
            len: 9,
            size: 1
        }
        .is_retryable());
        assert!(!DiskError::QueueClosed.is_retryable());
    }

    #[test]
    fn corrupt_display_names_both_checksums() {
        let e = DiskError::corrupt(4096, 128, 0xdead, 0xbeef);
        let s = e.to_string();
        assert!(s.contains("4096") && s.contains("dead") && s.contains("beef"), "{s}");
    }

    #[test]
    fn io_source_is_chained() {
        use std::error::Error;
        let e = DiskError::io(std::io::Error::other("disk on fire"), 0, 1);
        assert!(e.source().is_some());
        // generic-error conversion works at the engine boundary
        let b: Box<dyn Error + Send + Sync> = e.into();
        assert!(b.source().unwrap().to_string().contains("disk on fire"));
    }
}
