//! Disk I/O statistics: logical vs physical byte counts (read
//! amplification), op counts and busy time. Lock-free atomics — the
//! prefetch thread updates these from the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

#[derive(Default)]
pub struct DiskStats {
    read_ops: AtomicU64,
    write_ops: AtomicU64,
    logical_read: AtomicU64,
    physical_read: AtomicU64,
    logical_write: AtomicU64,
    physical_write: AtomicU64,
    read_busy_ns: AtomicU64,
    write_busy_ns: AtomicU64,
    coalesce_extents_in: AtomicU64,
    coalesce_runs_out: AtomicU64,
    read_retries: AtomicU64,
    corruptions: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskSnapshot {
    pub read_ops: u64,
    pub write_ops: u64,
    pub logical_read_bytes: u64,
    pub physical_read_bytes: u64,
    pub logical_write_bytes: u64,
    pub physical_write_bytes: u64,
    pub read_busy: Duration,
    pub write_busy: Duration,
    /// Logical extents that entered the prefetcher's coalescer…
    pub coalesce_extents_in: u64,
    /// …and the physical runs it issued for them.
    pub coalesce_runs_out: u64,
    /// Read operations that were re-issued after a retryable failure.
    pub read_retries: u64,
    /// Staged extents whose write-time checksum did not match.
    pub corruptions_detected: u64,
}

impl DiskStats {
    pub fn record_read(&self, logical: u64, physical: u64, dur: Duration) {
        self.read_ops.fetch_add(1, Ordering::Relaxed);
        self.logical_read.fetch_add(logical, Ordering::Relaxed);
        self.physical_read.fetch_add(physical, Ordering::Relaxed);
        self.read_busy_ns
            .fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    /// One batched read of `ops` extents (queue-depth overlapped).
    pub fn record_batch_read(&self, ops: u64, logical: u64, physical: u64, dur: Duration) {
        self.read_ops.fetch_add(ops, Ordering::Relaxed);
        self.logical_read.fetch_add(logical, Ordering::Relaxed);
        self.physical_read.fetch_add(physical, Ordering::Relaxed);
        self.read_busy_ns
            .fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    /// One coalescing pass: `extents_in` logical extents became
    /// `runs_out` physical reads.
    pub fn record_coalesce(&self, extents_in: u64, runs_out: u64) {
        self.coalesce_extents_in.fetch_add(extents_in, Ordering::Relaxed);
        self.coalesce_runs_out.fetch_add(runs_out, Ordering::Relaxed);
    }

    /// One re-issued read after a retryable failure.
    pub fn record_retry(&self) {
        self.read_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// One checksum mismatch caught at staging.
    pub fn record_corruption(&self) {
        self.corruptions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_write(&self, logical: u64, physical: u64, dur: Duration) {
        self.write_ops.fetch_add(1, Ordering::Relaxed);
        self.logical_write.fetch_add(logical, Ordering::Relaxed);
        self.physical_write.fetch_add(physical, Ordering::Relaxed);
        self.write_busy_ns
            .fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> DiskSnapshot {
        DiskSnapshot {
            read_ops: self.read_ops.load(Ordering::Relaxed),
            write_ops: self.write_ops.load(Ordering::Relaxed),
            logical_read_bytes: self.logical_read.load(Ordering::Relaxed),
            physical_read_bytes: self.physical_read.load(Ordering::Relaxed),
            logical_write_bytes: self.logical_write.load(Ordering::Relaxed),
            physical_write_bytes: self.physical_write.load(Ordering::Relaxed),
            read_busy: Duration::from_nanos(self.read_busy_ns.load(Ordering::Relaxed)),
            write_busy: Duration::from_nanos(self.write_busy_ns.load(Ordering::Relaxed)),
            coalesce_extents_in: self.coalesce_extents_in.load(Ordering::Relaxed),
            coalesce_runs_out: self.coalesce_runs_out.load(Ordering::Relaxed),
            read_retries: self.read_retries.load(Ordering::Relaxed),
            corruptions_detected: self.corruptions.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.read_ops.store(0, Ordering::Relaxed);
        self.write_ops.store(0, Ordering::Relaxed);
        self.logical_read.store(0, Ordering::Relaxed);
        self.physical_read.store(0, Ordering::Relaxed);
        self.logical_write.store(0, Ordering::Relaxed);
        self.physical_write.store(0, Ordering::Relaxed);
        self.read_busy_ns.store(0, Ordering::Relaxed);
        self.write_busy_ns.store(0, Ordering::Relaxed);
        self.coalesce_extents_in.store(0, Ordering::Relaxed);
        self.coalesce_runs_out.store(0, Ordering::Relaxed);
        self.read_retries.store(0, Ordering::Relaxed);
        self.corruptions.store(0, Ordering::Relaxed);
    }
}

impl DiskSnapshot {
    /// Fraction of physically-moved read bytes that were actually wanted
    /// (1.0 = no read amplification).
    pub fn read_amplification_efficiency(&self) -> f64 {
        if self.physical_read_bytes == 0 {
            return 1.0;
        }
        self.logical_read_bytes as f64 / self.physical_read_bytes as f64
    }

    /// Mean logical extents folded into each physical read by the
    /// prefetcher (1.0 when coalescing never fired or never merged).
    pub fn coalesce_factor(&self) -> f64 {
        if self.coalesce_runs_out == 0 {
            return 1.0;
        }
        self.coalesce_extents_in as f64 / self.coalesce_runs_out as f64
    }

    /// Device read-busy time accrued since an `earlier` snapshot of the
    /// same stats source — the denominator of the prefill-phase overlap
    /// ratio (how much store-restore device time a warm start incurred).
    pub fn read_busy_since(&self, earlier: &DiskSnapshot) -> Duration {
        self.read_busy.saturating_sub(earlier.read_busy)
    }

    /// Effective bandwidth relative to `peak_bw` over the busy period —
    /// the "I/O utilization" the paper annotates in Fig. 12.
    pub fn io_utilization(&self, peak_bw: f64) -> f64 {
        let secs = self.read_busy.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        (self.logical_read_bytes as f64 / secs) / peak_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_reset() {
        let s = DiskStats::default();
        s.record_read(512, 4096, Duration::from_micros(100));
        s.record_read(512, 4096, Duration::from_micros(100));
        s.record_write(1024, 4096, Duration::from_micros(50));
        let snap = s.snapshot();
        assert_eq!(snap.read_ops, 2);
        assert_eq!(snap.logical_read_bytes, 1024);
        assert_eq!(snap.physical_read_bytes, 8192);
        assert_eq!(snap.write_ops, 1);
        assert_eq!(snap.read_busy, Duration::from_micros(200));
        s.reset();
        assert_eq!(s.snapshot().read_ops, 0);
    }

    #[test]
    fn amplification_efficiency() {
        let s = DiskStats::default();
        s.record_read(512, 4096, Duration::from_micros(10));
        assert!((s.snapshot().read_amplification_efficiency() - 0.125).abs() < 1e-9);
        let empty = DiskStats::default();
        assert_eq!(empty.snapshot().read_amplification_efficiency(), 1.0);
    }

    #[test]
    fn coalesce_factor_tracks_merge_ratio() {
        let s = DiskStats::default();
        assert_eq!(s.snapshot().coalesce_factor(), 1.0);
        s.record_coalesce(8, 2);
        s.record_coalesce(4, 2);
        let snap = s.snapshot();
        assert_eq!(snap.coalesce_extents_in, 12);
        assert_eq!(snap.coalesce_runs_out, 4);
        assert!((snap.coalesce_factor() - 3.0).abs() < 1e-12);
        s.reset();
        assert_eq!(s.snapshot().coalesce_extents_in, 0);
    }

    #[test]
    fn retry_and_corruption_counters() {
        let s = DiskStats::default();
        s.record_retry();
        s.record_retry();
        s.record_corruption();
        let snap = s.snapshot();
        assert_eq!(snap.read_retries, 2);
        assert_eq!(snap.corruptions_detected, 1);
        s.reset();
        assert_eq!(s.snapshot().read_retries, 0);
    }

    #[test]
    fn read_busy_since_is_a_saturating_delta() {
        let s = DiskStats::default();
        s.record_read(512, 4096, Duration::from_micros(100));
        let before = s.snapshot();
        s.record_read(512, 4096, Duration::from_micros(250));
        let after = s.snapshot();
        assert_eq!(after.read_busy_since(&before), Duration::from_micros(250));
        // reversed order saturates to zero instead of panicking
        assert_eq!(before.read_busy_since(&after), Duration::ZERO);
    }

    #[test]
    fn io_utilization() {
        let s = DiskStats::default();
        // 1 MiB in 1 ms against a 2 GB/s device => ~52% utilization
        s.record_read(1 << 20, 1 << 20, Duration::from_millis(1));
        let u = s.snapshot().io_utilization(2e9);
        assert!((u - (1 << 20) as f64 / 1e-3 / 2e9).abs() < 1e-9);
        assert_eq!(DiskStats::default().snapshot().io_utilization(2e9), 0.0);
    }
}
