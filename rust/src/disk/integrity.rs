//! Write-time checksums for disk-resident KV extents.
//!
//! Flash and file systems can return *wrong bytes* without returning an
//! error — a bit flip in a group record silently corrupts attention for
//! every later step that reuses it. The fix is end-to-end: [`SimDisk`]
//! stamps an FNV-1a checksum for every extent it writes into an
//! [`IntegrityMap`], and the staging path re-hashes the bytes it read
//! back. A mismatch surfaces as the typed, retryable
//! [`DiskError::Corrupt`](super::DiskError::Corrupt) so the coalesced
//! read path can re-issue the run instead of feeding garbage to the
//! kernels.
//!
//! Verification is *exact-extent*: only a read whose `(offset, len)`
//! matches a stamped write is checked. Reads that slice a record
//! differently (FlexGen's whole-layer extents, ShadowKv's V-half reads)
//! are unverifiable by construction and pass through unchecked — the
//! KVSwap group reads, which dominate the hot path, always match.
//!
//! [`SimDisk`]: super::SimDisk

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::error::{DiskError, DiskResult};
use super::relock;

/// 64-bit FNV-1a: tiny, dependency-free, and byte-order independent.
/// Not cryptographic — the adversary here is a flipped bit, not an
/// attacker — and fast enough to stamp on every group flush.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Default)]
struct Stamps {
    /// offset → (len, checksum) of the most recent write at that offset.
    by_offset: BTreeMap<u64, (usize, u64)>,
    /// Largest stamped extent length, bounding the overlap scan below.
    max_len: u64,
}

/// Checksum registry for one backing store. Shared between the write
/// path (stamping) and the staging path (verification); a plain mutex is
/// fine because both sides touch it once per multi-kilobyte extent.
#[derive(Default)]
pub struct IntegrityMap {
    inner: Mutex<Stamps>,
}

impl IntegrityMap {
    pub fn new() -> IntegrityMap {
        IntegrityMap::default()
    }

    /// Record the checksum of `data` as the truth for extent
    /// `(offset, data.len())`, invalidating any previously stamped extent
    /// it overlaps (a partial overwrite changes those bytes too).
    pub fn stamp(&self, offset: u64, data: &[u8]) {
        if data.is_empty() {
            return;
        }
        self.stamp_sum(offset, data.len(), fnv1a64(data));
    }

    /// Record a checksum computed elsewhere — e.g. reloaded from the
    /// persistent store's manifest on open — as the truth for extent
    /// `(offset, len)`, with the same overlap invalidation as
    /// [`IntegrityMap::stamp`]. Reads of the extent then verify against
    /// the *historical* write, which is exactly what a reopened store
    /// needs: bytes that rotted while the process was down must fail.
    pub fn stamp_sum(&self, offset: u64, len: usize, sum: u64) {
        if len == 0 {
            return;
        }
        let len64 = len as u64;
        let mut inner = relock(&self.inner);
        // Any stamped extent starting within `max_len` before us may reach
        // into [offset, offset+len); everything starting inside the write
        // certainly overlaps.
        let lo = offset.saturating_sub(inner.max_len);
        let hi = offset.saturating_add(len64);
        let stale: Vec<u64> = inner
            .by_offset
            .range(lo..hi)
            .filter(|&(&o, &(l, _))| o.saturating_add(l as u64) > offset && o != offset)
            .map(|(&o, _)| o)
            .collect();
        for o in stale {
            inner.by_offset.remove(&o);
        }
        inner.max_len = inner.max_len.max(len64);
        inner.by_offset.insert(offset, (len, sum));
    }

    /// Verify `bytes` read back from `offset` against the stamped
    /// checksum. Extents that were never stamped at exactly this
    /// `(offset, len)` are unverifiable and pass.
    pub fn verify(&self, offset: u64, bytes: &[u8]) -> DiskResult<()> {
        let expect = {
            let inner = relock(&self.inner);
            match inner.by_offset.get(&offset) {
                Some(&(len, sum)) if len == bytes.len() => sum,
                _ => return Ok(()),
            }
        };
        let got = fnv1a64(bytes);
        if got == expect {
            Ok(())
        } else {
            Err(DiskError::corrupt(offset, bytes.len(), expect, got))
        }
    }

    /// Whether extent `(offset, len)` has a verifiable stamp.
    pub fn is_stamped(&self, offset: u64, len: usize) -> bool {
        let inner = relock(&self.inner);
        matches!(inner.by_offset.get(&offset), Some(&(l, _)) if l == len)
    }

    /// Number of stamped extents (diagnostics/tests).
    pub fn len(&self) -> usize {
        relock(&self.inner).by_offset.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn stamp_then_verify_roundtrip() {
        let m = IntegrityMap::new();
        let rec = vec![0xABu8; 256];
        m.stamp(4096, &rec);
        assert!(m.is_stamped(4096, 256));
        m.verify(4096, &rec).unwrap();

        // a single flipped bit is caught
        let mut bad = rec.clone();
        bad[17] ^= 0x40;
        let err = m.verify(4096, &bad).unwrap_err();
        assert!(matches!(err, DiskError::Corrupt { offset: 4096, len: 256, .. }));
        assert!(err.is_retryable());
    }

    #[test]
    fn unstamped_or_mismatched_extents_pass_unchecked() {
        let m = IntegrityMap::new();
        m.stamp(0, &[1u8; 64]);
        // never written: unverifiable
        m.verify(8192, &[9u8; 64]).unwrap();
        // same offset, different length (e.g. a whole-layer read): skip
        m.verify(0, &[9u8; 32]).unwrap();
        assert!(!m.is_stamped(0, 32));
    }

    #[test]
    fn stamp_sum_behaves_like_stamp() {
        let m = IntegrityMap::new();
        let rec = vec![0x3Cu8; 128];
        // re-stamping from a persisted checksum (manifest reopen path)
        // verifies identically to stamping the bytes directly
        m.stamp_sum(512, rec.len(), fnv1a64(&rec));
        assert!(m.is_stamped(512, 128));
        m.verify(512, &rec).unwrap();
        let mut bad = rec.clone();
        bad[0] ^= 1;
        assert!(m.verify(512, &bad).is_err());
        // and it carries the same overlap invalidation
        m.stamp(600, &[7u8; 64]);
        m.stamp_sum(560, 80, 42);
        assert!(!m.is_stamped(512, 128));
        assert!(!m.is_stamped(600, 64));
        assert!(m.is_stamped(560, 80));
    }

    #[test]
    fn overwrite_restamps_and_overlap_invalidates() {
        let m = IntegrityMap::new();
        m.stamp(100, &[1u8; 50]);
        m.stamp(200, &[2u8; 50]);
        // exact overwrite replaces the stamp
        m.stamp(100, &[3u8; 50]);
        m.verify(100, &[3u8; 50]).unwrap();
        assert!(m.verify(100, &[1u8; 50]).is_err());
        // a partial overwrite straddling extent 200 invalidates it
        m.stamp(180, &[4u8; 40]);
        assert!(!m.is_stamped(200, 50));
        m.verify(200, &[0x5Au8; 50]).unwrap(); // now unverifiable, passes
        // the straddling write itself is verifiable
        assert!(m.is_stamped(180, 40));
        assert_eq!(m.len(), 2); // offsets 100 and 180
    }
}
