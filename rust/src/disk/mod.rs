//! Disk substrate: device timing profiles (NVMe/eMMC/UFS/SD) with
//! page-granule read amplification, byte backends (memory / real file),
//! the `SimDisk` simulated device, I/O statistics, and the unified
//! priority I/O scheduler that serves every read stream in the system.
//!
//! Paper mapping: §2.3 (Fig. 2 bandwidth-vs-block-size behaviour) is
//! produced by `DiskProfile`; every offloading policy's I/O goes through
//! `SimDisk` so the benches can attribute logical/physical bytes and busy
//! time uniformly; §3.3's read orchestration lives in [`coalesce`] and
//! [`sched`], and the overlap of preloads with compute in [`prefetch`].
//!
//! ## Pipeline shape
//!
//! All three read streams submit to one [`IoScheduler`] through priority
//! lanes and share its worker pool, buffer pool, retry budget, and
//! circuit breaker:
//!
//! ```text
//!  decode prefetch ──Critical──▶ ┌─────────────────────┐
//!  (Prefetcher)                  │     IoScheduler      │   coalesced
//!  store restores ──Warm──────▶ │  strict priority +   │──batched──▶ SimDisk
//!  (engine worker)              │  Background aging +  │   reads     (per device)
//!  scrub reads ────Background─▶ │ cross-plan merging   │
//!  (store maintainer)           └─────────────────────┘
//! ```
//!
//! Dispatch is strict-priority (`Critical` > `Warm` > `Background`) with
//! an aging bound that promotes a starved `Background` request, and each
//! dispatch opens a window in which gap-close extents from *other*
//! queued plans — same device only — merge into one sequential read
//! (`cross_plan_merges`). Per-lane service counters surface through
//! [`PrefetchSummary`] and the serve API's `stats` line.
//!
//! Public API shape:
//!
//! * everything here returns [`DiskResult`] / [`DiskError`] — typed
//!   errors callers can match on; conversion to a generic error type
//!   happens only at the engine boundary;
//! * multi-extent access goes through [`Backend::read_batch`] (with
//!   per-backend submission strategies), fed by the coalescer so the
//!   "merge small reads into big ones" logic exists in exactly one place
//!   ([`sched::read_group`] — [`prefetch::read_coalesced`] is the same
//!   path applied to a single-plan group);
//! * [`StorageBackend`] selects where bytes live (RAM, a real file, or a
//!   caller-supplied backend) without the engine knowing the difference.
//!
//! ## Failure model & degradation ladder
//!
//! On-device storage is treated as *unreliable by design*: reads may fail
//! transiently (`EIO`, short reads), stall (latency spikes), fail
//! persistently (a bad extent), or — worst — succeed with wrong bytes.
//! [`fault`] can inject every one of these deterministically for tests
//! and benches. Recovery is layered, each rung strictly cheaper than the
//! one below it, and applies identically to every lane:
//!
//! 1. **Detect** — every `SimDisk` write stamps an FNV-1a checksum
//!    ([`integrity`]); staging re-verifies exact-extent reads, turning
//!    silent corruption into a typed, retryable [`DiskError::Corrupt`].
//! 2. **Retry** — the scheduler's group read re-issues failed runs with
//!    bounded exponential backoff + jitter ([`retry`]), guided by
//!    [`DiskError::is_retryable`]. Budgets stay per-plan: each member of
//!    a merged dispatch group draws its own, so riders cannot starve the
//!    plan they merged into.
//! 3. **Contain** — scheduler worker panics are caught and surfaced as
//!    `DiskError::WorkerPanic` to every plan in the dispatch group; dead
//!    workers are respawned; locks recover from poisoning instead of
//!    cascading panics.
//! 4. **Degrade** — past `breaker_threshold` consecutive threaded
//!    failures (on any lane) a circuit breaker degrades the *whole
//!    scheduler* to synchronous routing: `submit` hands back an inline
//!    ticket and the read runs on the caller's thread at `wait` time
//!    (half-open probes recover once the device heals). A plan that
//!    still fails makes the *engine* fall back to attention over the
//!    resident critical cache for that layer and counts a degraded step
//!    in the metrics instead of aborting. The persistent store's
//!    warm-start restores degrade the same way but at *chunk*
//!    granularity: a torn record during a pipelined restore discards
//!    only the warm region from that prefill chunk onward — everything
//!    restored before the tear stays reused, and recompute (always
//!    bit-identical to the restore) covers the rest.
//!
//! Only non-retryable errors (`OutOfBounds` logic bugs, `QueueClosed`
//! shutdown) propagate out of the ladder.

pub mod backend;
pub mod coalesce;
pub mod error;
pub mod fault;
pub mod integrity;
pub mod prefetch;
pub mod profile;
pub mod retry;
pub mod sched;
pub mod sim;
pub mod stats;

pub use backend::{Backend, FileBackend, MemBackend, ReadReq, StorageBackend};
pub use coalesce::{coalesce, Run};
pub use error::{DiskError, DiskResult};
pub use fault::{Fault, FaultBackend, FaultSnapshot};
pub use integrity::{fnv1a64, IntegrityMap};
pub use prefetch::{
    BufferPool, PlannedExtent, Prefetcher, PreloadPlan, PrefetchSummary, StagedLoad,
};
pub use profile::DiskProfile;
pub use retry::{RetryBudget, RetryPolicy};
pub use sched::{
    BreakerState, IoCompletion, IoRequest, IoScheduler, Lane, LaneSummary, Ticket, N_LANES,
};
pub use sim::SimDisk;
pub use stats::{DiskSnapshot, DiskStats};

/// Lock a mutex, recovering the guard when a previous holder panicked.
/// The disk layer's shared state (buffer pool, fault scripts, checksum
/// stamps, backend images) stays valid across a worker panic — every
/// mutation is complete-or-absent — so propagating the poison would only
/// convert one contained failure into an engine-thread panic.
pub(crate) fn relock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}
