//! Disk substrate: device timing profiles (NVMe/eMMC/UFS/SD) with
//! page-granule read amplification, byte backends (memory / real file),
//! the `SimDisk` simulated device, I/O statistics, and the asynchronous
//! prefetch pipeline.
//!
//! Paper mapping: §2.3 (Fig. 2 bandwidth-vs-block-size behaviour) is
//! produced by `DiskProfile`; every offloading policy's I/O goes through
//! `SimDisk` so the benches can attribute logical/physical bytes and busy
//! time uniformly; §3.3's read orchestration lives in [`coalesce`] and
//! the overlap of preloads with compute in [`prefetch`].
//!
//! Public API shape:
//!
//! * everything here returns [`DiskResult`] / [`DiskError`] — typed
//!   errors callers can match on; conversion to a generic error type
//!   happens only at the engine boundary;
//! * multi-extent access goes through [`Backend::read_batch`] (with
//!   per-backend submission strategies), fed by the coalescer so the
//!   "merge small reads into big ones" logic exists in exactly one place;
//! * [`StorageBackend`] selects where bytes live (RAM, a real file, or a
//!   caller-supplied backend) without the engine knowing the difference.

pub mod backend;
pub mod coalesce;
pub mod error;
pub mod prefetch;
pub mod profile;
pub mod sim;
pub mod stats;

pub use backend::{Backend, FileBackend, MemBackend, ReadReq, StorageBackend};
pub use coalesce::{coalesce, Run};
pub use error::{DiskError, DiskResult};
pub use prefetch::{
    BufferPool, PlannedExtent, Prefetcher, PreloadPlan, PrefetchSummary, StagedLoad,
};
pub use profile::DiskProfile;
pub use sim::SimDisk;
pub use stats::{DiskSnapshot, DiskStats};
