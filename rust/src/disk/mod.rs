//! Disk substrate: device timing profiles (NVMe/eMMC/UFS/SD) with
//! page-granule read amplification, byte backends (memory / real file),
//! the `SimDisk` simulated device, and I/O statistics.
//!
//! Paper mapping: §2.3 (Fig. 2 bandwidth-vs-block-size behaviour) is
//! produced by `DiskProfile`; every offloading policy's I/O goes through
//! `SimDisk` so the benches can attribute logical/physical bytes and busy
//! time uniformly.

pub mod backend;
pub mod profile;
pub mod sim;
pub mod stats;

pub use backend::{Backend, FileBackend, MemBackend};
pub use profile::DiskProfile;
pub use sim::SimDisk;
pub use stats::{DiskSnapshot, DiskStats};
