//! Grouped critical-KV predictor (paper §3.3) — the Rust half.
//!
//! The dense math (approximate low-rank scores, Eq. 1) runs in the HLO
//! `predict` artifact; this module owns the control flow: per-group
//! ReduceMax, Top-M selection, cross-step overlap statistics (Fig. 8),
//! and the per-head variant used by the InfiniGen baseline.

use crate::util::mathx;

/// Select the top-M groups from head-summed token scores.
///
/// * `scores`    — [ncap] token scores (NEG_INF beyond `n_flushed`).
/// * `n_flushed` — tokens present in the compressed cache (on disk).
/// * `group`     — G.
/// * `m`         — number of groups to select.
///
/// Returns group ids, score-descending (paper: ReduceMax + TopK).
pub fn select_groups(scores: &[f32], n_flushed: usize, group: usize, m: usize) -> Vec<u32> {
    let n = n_flushed.min(scores.len());
    if n == 0 || m == 0 {
        return Vec::new();
    }
    let gmax = mathx::group_max(&scores[..n], group);
    // only complete groups are on disk
    let n_complete = n / group;
    let gmax = &gmax[..n_complete];
    mathx::top_k_indices(gmax, m)
        .into_iter()
        .map(|i| i as u32)
        .collect()
}

/// Per-head token selection (InfiniGen-style, no head aggregation):
/// each head picks its own top tokens; the union is loaded. Produces the
/// fragmented access pattern the paper criticizes (§3.3 "prior work
/// predicts on individual heads or tokens").
pub fn select_tokens_per_head(
    head_scores: &[Vec<f32>],
    n_flushed: usize,
    per_head: usize,
) -> Vec<u32> {
    let mut sel: Vec<u32> = Vec::new();
    for hs in head_scores {
        let n = n_flushed.min(hs.len());
        for idx in mathx::top_k_indices(&hs[..n], per_head) {
            sel.push(idx as u32);
        }
    }
    sel.sort_unstable();
    sel.dedup();
    sel
}

/// Head-aggregated token selection (InfiniGen* / Loki baselines: token
/// granularity, G=1 equivalent).
pub fn select_tokens(scores: &[f32], n_flushed: usize, k: usize) -> Vec<u32> {
    select_groups(scores, n_flushed, 1, k)
}

/// Cross-step overlap tracking (paper §3.4.2, Fig. 8): the fraction of
/// step-j critical groups that were also critical at step j-1 — the
/// statistic that justifies the reuse buffer.
#[derive(Debug, Default, Clone)]
pub struct OverlapTracker {
    prev: Vec<u32>,
    pub ratios: Vec<f64>,
    /// Selection frequency per group id (Fig. 8 histogram).
    pub freq: std::collections::HashMap<u32, u64>,
}

impl OverlapTracker {
    pub fn record(&mut self, selection: &[u32]) {
        for &g in selection {
            *self.freq.entry(g).or_insert(0) += 1;
        }
        if !self.prev.is_empty() && !selection.is_empty() {
            let prev: std::collections::HashSet<u32> = self.prev.iter().cloned().collect();
            let overlap = selection.iter().filter(|g| prev.contains(g)).count();
            self.ratios.push(overlap as f64 / selection.len() as f64);
        }
        self.prev = selection.to_vec();
    }

    pub fn mean_overlap(&self) -> f64 {
        if self.ratios.is_empty() {
            0.0
        } else {
            self.ratios.iter().sum::<f64>() / self.ratios.len() as f64
        }
    }

    /// Fraction of distinct groups accounting for `mass` of all
    /// selections (Fig. 8: "fewer than 22% of groups account for 80%").
    pub fn head_mass_fraction(&self, mass: f64) -> f64 {
        if self.freq.is_empty() {
            return 0.0;
        }
        let mut counts: Vec<u64> = self.freq.values().cloned().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = counts.iter().sum();
        let target = (total as f64 * mass) as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (i + 1) as f64 / counts.len() as f64;
            }
        }
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn select_groups_picks_peak_groups() {
        // 8 tokens, G=2: scores peak in groups 1 and 3
        let scores = vec![0.0, 0.1, 5.0, 0.0, 0.2, 0.1, 0.0, 9.0];
        assert_eq!(select_groups(&scores, 8, 2, 2), vec![3, 1]);
        assert_eq!(select_groups(&scores, 8, 2, 1), vec![3]);
    }

    #[test]
    fn select_groups_ignores_unflushed_and_partial_tail() {
        let scores = vec![0.0, 0.1, 5.0, 0.0, 9.0, 9.0, 9.0];
        // only 4 flushed tokens -> 2 complete groups; the 9.0s invisible
        let sel = select_groups(&scores, 4, 2, 2);
        assert_eq!(sel, vec![1, 0]);
        // n_flushed=5 with G=2 -> still only 2 complete groups
        let sel2 = select_groups(&scores, 5, 2, 4);
        assert_eq!(sel2.len(), 2);
    }

    #[test]
    fn select_groups_empty_cases() {
        assert!(select_groups(&[], 0, 4, 8).is_empty());
        assert!(select_groups(&[1.0, 2.0], 2, 4, 8).is_empty()); // no complete group
        assert!(select_groups(&[1.0, 2.0], 2, 1, 0).is_empty());
    }

    #[test]
    fn per_head_union_is_fragmented() {
        let h0 = vec![9.0, 0.0, 0.0, 8.0];
        let h1 = vec![0.0, 9.0, 0.0, 8.0];
        let sel = select_tokens_per_head(&[h0, h1], 4, 2);
        assert_eq!(sel, vec![0, 1, 3]); // union, deduped, sorted
    }

    #[test]
    fn overlap_tracker_ratio() {
        let mut t = OverlapTracker::default();
        t.record(&[1, 2, 3, 4]);
        t.record(&[3, 4, 5, 6]); // overlap 2/4
        t.record(&[3, 4, 5, 6]); // overlap 4/4
        assert_eq!(t.ratios, vec![0.5, 1.0]);
        assert!((t.mean_overlap() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn head_mass_fraction_skewed() {
        let mut t = OverlapTracker::default();
        // group 0 selected 80 times, groups 1..=19 once each
        for _ in 0..80 {
            t.record(&[0]);
        }
        for g in 1..20 {
            t.record(&[g]);
        }
        // one group (5% of 20) carries 80% of mass
        assert!(t.head_mass_fraction(0.8) <= 0.05 + 1e-9);
    }

    #[test]
    fn prop_selection_valid_and_sorted_by_score() {
        proptest::check("select-groups", 200, |rng: &mut Rng| {
            let g = rng.range(1, 8);
            let n = rng.range(0, 128);
            let m = rng.range(0, 16);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let sel = select_groups(&scores, n, g, m);
            let n_complete = n / g;
            crate::prop_assert!(sel.len() == m.min(n_complete), "len");
            let gmax = mathx::group_max(&scores[..n.min(scores.len())], g);
            for w in sel.windows(2) {
                crate::prop_assert!(
                    gmax[w[0] as usize] >= gmax[w[1] as usize],
                    "not score-descending"
                );
            }
            for &gid in &sel {
                crate::prop_assert!((gid as usize) < n_complete, "gid out of range");
            }
            // no group outside the selection beats the worst selected
            if let Some(&last) = sel.last() {
                let worst = gmax[last as usize];
                for (i, &v) in gmax[..n_complete].iter().enumerate() {
                    if !sel.contains(&(i as u32)) {
                        crate::prop_assert!(v <= worst + 1e-6, "missed a better group");
                    }
                }
            }
            Ok(())
        });
    }
}
