//! KV-cache manager (paper §3.4.4): owns all per-sequence KV state —
//! the on-disk full cache, the in-memory compressed K cache, rolling and
//! reuse buffers — and assembles the contiguous attention inputs through
//! the mapping table.

use std::collections::HashMap;
use std::sync::Arc;

use super::layout::DiskLayout;
use super::lowrank::LowRankStore;
use super::mapping::{SlotMap, SlotSource};
use super::reuse::ReuseBuffer;
use super::rolling::{FlushedGroup, RollingBuffer};
use crate::disk::SimDisk;
use crate::runtime::tensor::Tensor;

/// Per-(sequence, layer) KV state.
pub struct LayerState {
    pub klr: LowRankStore,
    pub rolling: RollingBuffer,
    pub reuse: ReuseBuffer,
    /// Selection used for the step in flight (for overlap stats).
    pub last_selection: Vec<u32>,
}

/// Per-sequence KV state across layers.
pub struct SeqState {
    pub seq_slot: usize,
    /// Total tokens in context (flushed + rolling pending).
    pub n_tokens: usize,
    pub layers: Vec<LayerState>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ManagerConfig {
    pub group: usize,
    pub rank: usize,
    pub reuse_slots: usize,
    pub rb_visible: usize,
    /// Attention slots reserved for selected groups (M*G).
    pub sel_region: usize,
    /// Total attention width P.
    pub p: usize,
    /// Insert freshly flushed groups straight into the reuse buffer
    /// (avoids an immediate disk round-trip when they get selected).
    pub cache_flushed: bool,
    /// Expose rolling-buffer entries to attention. Disabling this is the
    /// paper's App. Tab. 3 ablation: fresh entries stay invisible until
    /// their group flushes AND the predictor selects it.
    pub expose_rolling: bool,
}

pub struct KvManager {
    pub layout: DiskLayout,
    pub disk: Arc<SimDisk>,
    pub cfg: ManagerConfig,
}

/// A pending disk load for one group.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupLoad {
    pub gid: u32,
    pub offset: u64,
    pub len: usize,
}

impl KvManager {
    pub fn new(layout: DiskLayout, disk: Arc<SimDisk>, cfg: ManagerConfig) -> KvManager {
        assert!(cfg.sel_region % cfg.group == 0, "sel_region must be a multiple of G");
        assert!(cfg.sel_region + cfg.rb_visible <= cfg.p);
        KvManager { layout, disk, cfg }
    }

    pub fn new_seq(&self, seq_slot: usize) -> SeqState {
        let hd = self.layout.hd;
        SeqState {
            seq_slot,
            n_tokens: 0,
            layers: (0..self.layout.n_layers)
                .map(|_| LayerState {
                    klr: LowRankStore::new(self.cfg.rank),
                    rolling: RollingBuffer::new(hd, self.cfg.group, self.cfg.rb_visible),
                    reuse: ReuseBuffer::new(
                        self.cfg.reuse_slots,
                        2 * self.cfg.group * hd,
                    ),
                    last_selection: Vec::new(),
                })
                .collect(),
        }
    }

    /// Ingest one layer's prefill KV (token-major rows, post-RoPE):
    /// writes complete groups to disk (layer-by-layer streaming, §3.4),
    /// builds the initial compressed K cache, and parks the tail in the
    /// rolling buffer. `adapter` is this layer's A [hd, rank].
    pub fn ingest_prefill(
        &self,
        seq: &mut SeqState,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        adapter: &Tensor,
    ) -> anyhow::Result<()> {
        let hd = self.layout.hd;
        let g = self.cfg.group;
        assert_eq!(k_rows.len(), v_rows.len());
        assert_eq!(k_rows.len() % hd, 0);
        let n = k_rows.len() / hd;
        let full_groups = n / g;
        for gi in 0..full_groups {
            let span = gi * g * hd..(gi + 1) * g * hd;
            let rec = self.layout.encode_group(&k_rows[span.clone()], &v_rows[span]);
            let off = self.layout.offset(seq.seq_slot, layer, gi);
            self.disk.write(off, &rec)?;
        }
        let st = &mut seq.layers[layer];
        st.klr
            .append_compressed(&k_rows[..full_groups * g * hd], hd, adapter);
        let tail_k: Vec<Vec<f32>> = (full_groups * g..n)
            .map(|t| k_rows[t * hd..(t + 1) * hd].to_vec())
            .collect();
        let tail_v: Vec<Vec<f32>> = (full_groups * g..n)
            .map(|t| v_rows[t * hd..(t + 1) * hd].to_vec())
            .collect();
        st.rolling.init_tail(full_groups * g, tail_k, tail_v);
        if layer == self.layout.n_layers - 1 {
            seq.n_tokens = n;
        }
        Ok(())
    }

    /// Append a freshly generated KV entry for one layer; on group
    /// completion offloads to disk + extends K_lr (+ optionally seeds the
    /// reuse buffer). Returns the flushed group id if any.
    pub fn append_token(
        &self,
        seq: &mut SeqState,
        layer: usize,
        k_row: Vec<f32>,
        v_row: Vec<f32>,
        adapter: &Tensor,
    ) -> anyhow::Result<Option<u32>> {
        let hd = self.layout.hd;
        let st = &mut seq.layers[layer];
        let flushed: Option<FlushedGroup> = st.rolling.push(k_row, v_row);
        let Some(fg) = flushed else {
            return Ok(None);
        };
        let rec = self.layout.encode_group(&fg.k_rows, &fg.v_rows);
        let off = self.layout.offset(seq.seq_slot, layer, fg.group_idx);
        self.disk.write(off, &rec)?;
        st.klr.append_compressed(&fg.k_rows, hd, adapter);
        if self.cfg.cache_flushed && self.cfg.reuse_slots > 0 {
            let mut payload = fg.k_rows.clone();
            payload.extend_from_slice(&fg.v_rows);
            st.reuse.insert(fg.group_idx as u32, &payload);
        }
        Ok(Some(fg.group_idx as u32))
    }

    /// Diff a selection against the reuse buffer: which groups need disk
    /// loads. Counts reuse hits/misses (paper Tab. 5 statistics) and pins
    /// the selection so this step's inserts cannot evict its own hits.
    pub fn plan_loads(&self, seq: &mut SeqState, layer: usize, selection: &[u32]) -> Vec<GroupLoad> {
        let seq_slot = seq.seq_slot;
        let st = &mut seq.layers[layer];
        st.reuse.unpin_all();
        st.reuse.pin_many(selection);
        let len = self.layout.group_payload_bytes() as usize;
        selection
            .iter()
            .filter(|gid| st.reuse.lookup(**gid).is_none())
            .map(|&gid| GroupLoad {
                gid,
                offset: self.layout.offset(seq_slot, layer, gid as usize),
                len,
            })
            .collect()
    }

    /// Insert a completed disk load into the reuse buffer (or return it
    /// for staging when reuse is disabled).
    pub fn commit_load(
        &self,
        seq: &mut SeqState,
        layer: usize,
        gid: u32,
        bytes: &[u8],
        staging: &mut HashMap<u32, Vec<f32>>,
    ) {
        let (k, v) = self.layout.decode_group(bytes);
        let mut payload = k;
        payload.extend_from_slice(&v);
        let st = &mut seq.layers[layer];
        if self.cfg.reuse_slots == 0 || st.reuse.insert(gid, &payload).is_none() {
            // reuse disabled or all slots pinned: stage for this step only
            staging.insert(gid, payload);
        }
    }

    /// Commit a whole batch of staged prefetch loads (the handoff from
    /// the prefetch pipeline): each `(gid, bytes)` pair lands in the
    /// reuse buffer or the per-step staging map.
    pub fn commit_staged(
        &self,
        seq: &mut SeqState,
        layer: usize,
        loads: Vec<(u32, Vec<u8>)>,
        staging: &mut HashMap<u32, Vec<f32>>,
    ) {
        for (gid, bytes) in loads {
            self.commit_load(seq, layer, gid, &bytes, staging);
        }
    }

    /// Build the slot map for this layer's attention call.
    pub fn slot_map(&self, seq: &SeqState, layer: usize, selection: &[u32]) -> SlotMap {
        let st = &seq.layers[layer];
        let rb_len = if self.cfg.expose_rolling {
            st.rolling.visible_len()
        } else {
            0
        };
        let rb_start = st.rolling.unflushed_pos() + st.rolling.pending() - rb_len;
        SlotMap::build(
            selection,
            self.cfg.group,
            self.cfg.sel_region,
            self.cfg.p,
            rb_start,
            rb_len,
        )
    }

    /// Fill one batch row of the attention inputs ([Hkv, P, d] slices +
    /// mask [P]) from the slot map. `staging` holds payloads when the
    /// reuse buffer is disabled.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        &self,
        seq: &mut SeqState,
        layer: usize,
        slot_map: &SlotMap,
        hkv: usize,
        d: usize,
        staging: &HashMap<u32, Vec<f32>>,
        k_out: &mut [f32],
        v_out: &mut [f32],
        mask_out: &mut [f32],
    ) {
        let p = self.cfg.p;
        let g = self.cfg.group;
        let hd = self.layout.hd;
        debug_assert_eq!(hd, hkv * d);
        debug_assert_eq!(k_out.len(), hkv * p * d);
        debug_assert_eq!(mask_out.len(), p);
        slot_map.fill_mask(mask_out);

        // collect rolling rows up-front (borrow split)
        let st = &mut seq.layers[layer];
        let rb_rows: HashMap<u32, (Vec<f32>, Vec<f32>)> = st
            .rolling
            .visible_entries()
            .map(|(pos, k, v)| (pos as u32, (k.to_vec(), v.to_vec())))
            .collect();

        for (slot, src) in slot_map.slots.iter().enumerate() {
            match src {
                SlotSource::Invalid => {}
                SlotSource::Rolling { pos } => {
                    let (k, v) = rb_rows
                        .get(pos)
                        .unwrap_or_else(|| panic!("rolling pos {pos} not visible"));
                    for gh in 0..hkv {
                        let dst = gh * p * d + slot * d;
                        k_out[dst..dst + d].copy_from_slice(&k[gh * d..(gh + 1) * d]);
                        v_out[dst..dst + d].copy_from_slice(&v[gh * d..(gh + 1) * d]);
                    }
                }
                SlotSource::Group { gid, member } => {
                    // payload layout: [k rows: G*hd][v rows: G*hd]
                    let payload: &[f32] = st
                        .reuse
                        .get(*gid)
                        .or_else(|| staging.get(gid).map(|v| v.as_slice()))
                        .unwrap_or_else(|| panic!("group {gid} in neither reuse nor staging"));
                    let m = *member as usize;
                    let krow = &payload[m * hd..(m + 1) * hd];
                    let vrow = &payload[g * hd + m * hd..g * hd + (m + 1) * hd];
                    for gh in 0..hkv {
                        let dst = gh * p * d + slot * d;
                        k_out[dst..dst + d].copy_from_slice(&krow[gh * d..(gh + 1) * d]);
                        v_out[dst..dst + d].copy_from_slice(&vrow[gh * d..(gh + 1) * d]);
                    }
                }
            }
        }
    }

    /// Number of complete (selectable) groups for a layer.
    pub fn n_groups(&self, seq: &SeqState, layer: usize) -> usize {
        seq.layers[layer].klr.len() / self.cfg.group
    }

    /// Integrity scrub: read every flushed group record of `seq` back
    /// through the verifying disk path without touching any cache state.
    /// Returns the number of records that verified clean; the first
    /// record whose bytes no longer match their write-time checksum
    /// surfaces as [`DiskError::Corrupt`](crate::disk::DiskError).
    ///
    /// This is an offline maintenance pass (the hot path verifies at
    /// staging time already) — useful after a crash, before reusing a
    /// cache file, or in tests that corrupt the backend on purpose.
    pub fn scrub(&self, seq: &SeqState) -> crate::disk::DiskResult<usize> {
        let len = self.layout.group_payload_bytes() as usize;
        let mut buf = vec![0u8; len];
        let mut clean = 0usize;
        for layer in 0..self.layout.n_layers {
            for gi in 0..self.n_groups(seq, layer) {
                let off = self.layout.offset(seq.seq_slot, layer, gi);
                self.disk.read(off, &mut buf)?;
                clean += 1;
            }
        }
        Ok(clean)
    }

    /// In-memory management bytes for one sequence (the paper's
    /// "KV cache management memory", Fig. 3a / Tab. 1).
    pub fn management_bytes(&self, seq: &SeqState) -> u64 {
        let hd = self.layout.hd as u64;
        seq.layers
            .iter()
            .map(|st| {
                st.klr.bytes()
                    + st.reuse.bytes()
                    + (st.rolling.visible_len() as u64 + st.rolling.pending() as u64)
                        * 2 * hd * 4
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::disk::{DiskProfile, SimDisk};
    use crate::util::rng::Rng;

    fn setup(g: usize, reuse_slots: usize) -> (KvManager, SeqState, Tensor) {
        let hd = 8;
        let layout = DiskLayout::new(hd, g, 256, 2, 0);
        let disk = Arc::new(SimDisk::in_memory(DiskProfile::nvme()));
        let cfg = ManagerConfig {
            group: g,
            rank: 4,
            reuse_slots,
            rb_visible: 4,
            sel_region: 4 * g,
            p: 4 * g + 6,
            cache_flushed: false,
            expose_rolling: true,
        };
        let m = KvManager::new(layout, disk, cfg);
        let seq = m.new_seq(0);
        // adapter: first 4 dims selector
        let mut a = Tensor::zeros(&[hd, 4]);
        for i in 0..4 {
            *a.at_mut(&[i, i]) = 1.0;
        }
        (m, seq, a)
    }

    fn rows(n: usize, hd: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let k: Vec<f32> = (0..n * hd).map(|_| rng.normal_f32(1.0)).collect();
        let v: Vec<f32> = (0..n * hd).map(|_| rng.normal_f32(1.0)).collect();
        (k, v)
    }

    #[test]
    fn prefill_roundtrips_through_disk() {
        let (m, mut seq, a) = setup(4, 8);
        let (k, v) = rows(10, 8, 1);
        m.ingest_prefill(&mut seq, 0, &k, &v, &a).unwrap();
        // 2 full groups on disk, 2 tail entries in RB, klr has 8 rows
        assert_eq!(seq.layers[0].klr.len(), 8);
        assert_eq!(seq.layers[0].rolling.pending(), 2);
        // read back group 1 from disk
        let mut buf = vec![0u8; m.layout.group_payload_bytes() as usize];
        m.disk.read(m.layout.offset(0, 0, 1), &mut buf).unwrap();
        let (k2, _v2) = m.layout.decode_group(&buf);
        assert_eq!(&k2[..], &k[4 * 8..8 * 8]);
    }

    #[test]
    fn append_token_flush_writes_disk_and_klr() {
        let (m, mut seq, a) = setup(2, 8);
        let (k, v) = rows(2, 8, 2);
        assert!(m
            .append_token(&mut seq, 0, k[..8].to_vec(), v[..8].to_vec(), &a)
            .unwrap()
            .is_none());
        let gid = m
            .append_token(&mut seq, 0, k[8..].to_vec(), v[8..].to_vec(), &a)
            .unwrap();
        assert_eq!(gid, Some(0));
        assert_eq!(seq.layers[0].klr.len(), 2);
        // klr row 0 = first 4 dims of k row 0 (selector adapter)
        assert_eq!(seq.layers[0].klr.row(0), &k[..4]);
    }

    #[test]
    fn plan_loads_respects_reuse_buffer() {
        let (m, mut seq, a) = setup(2, 8);
        let (k, v) = rows(8, 8, 3);
        m.ingest_prefill(&mut seq, 0, &k, &v, &a).unwrap();
        let loads = m.plan_loads(&mut seq, 0, &[0, 2]);
        assert_eq!(loads.len(), 2);
        // simulate loading both
        let mut staging = HashMap::new();
        for l in &loads {
            let mut buf = vec![0u8; l.len];
            m.disk.read(l.offset, &mut buf).unwrap();
            m.commit_load(&mut seq, 0, l.gid, &buf, &mut staging);
        }
        // now both are reuse hits
        let loads2 = m.plan_loads(&mut seq, 0, &[0, 2]);
        assert!(loads2.is_empty());
        let (hits, misses) = seq.layers[0].reuse.counters();
        assert_eq!((hits, misses), (2, 2));
    }

    #[test]
    fn assemble_produces_exact_rows() {
        let (m, mut seq, a) = setup(2, 8);
        let hd = 8;
        let (k, v) = rows(9, hd, 4); // 4 groups flushed + 1 tail
        m.ingest_prefill(&mut seq, 0, &k, &v, &a).unwrap();
        let selection = vec![1u32, 3u32];
        let mut staging = HashMap::new();
        for l in m.plan_loads(&mut seq, 0, &selection) {
            let mut buf = vec![0u8; l.len];
            m.disk.read(l.offset, &mut buf).unwrap();
            m.commit_load(&mut seq, 0, l.gid, &buf, &mut staging);
        }
        let sm = m.slot_map(&seq, 0, &selection);
        let (hkv, d) = (2, 4);
        let p = m.cfg.p;
        let mut k_out = vec![0.0; hkv * p * d];
        let mut v_out = vec![0.0; hkv * p * d];
        let mut mask = vec![0.0; p];
        m.assemble(&mut seq, 0, &sm, hkv, d, &staging, &mut k_out, &mut v_out, &mut mask);
        // slot 0 = group 1 member 0 = token 2
        let tok = 2;
        for gh in 0..hkv {
            assert_eq!(
                &k_out[gh * p * d..gh * p * d + d],
                &k[tok * hd + gh * d..tok * hd + gh * d + d]
            );
        }
        // rolling slot: sel_region=4 -> covers visible entries; the last
        // visible entry is token 8 (the tail)
        let rb_len = seq.layers[0].rolling.visible_len();
        let last_rb_slot = m.cfg.sel_region + rb_len - 1;
        for gh in 0..hkv {
            let dst = gh * p * d + last_rb_slot * d;
            assert_eq!(
                &k_out[dst..dst + d],
                &k[8 * hd + gh * d..8 * hd + gh * d + d]
            );
        }
        assert_eq!(mask[0], 0.0);
        assert_eq!(mask[p - 1], -1e9);
    }

    #[test]
    fn management_memory_grows_with_context() {
        let (m, mut seq, a) = setup(4, 8);
        let (k, v) = rows(64, 8, 5);
        m.ingest_prefill(&mut seq, 0, &k, &v, &a).unwrap();
        m.ingest_prefill(&mut seq, 1, &k, &v, &a).unwrap();
        let b1 = m.management_bytes(&seq);
        let (k2, v2) = rows(64, 8, 6);
        let mut seq2 = m.new_seq(1);
        let kk = [k, k2].concat();
        let vv = [v, v2].concat();
        m.ingest_prefill(&mut seq2, 0, &kk, &vv, &a).unwrap();
        m.ingest_prefill(&mut seq2, 1, &kk, &vv, &a).unwrap();
        let b2 = m.management_bytes(&seq2);
        assert!(b2 > b1);
        // and both are far below the full cache
        let full = 64u64 * 2 * 8 * 4 * 2; // tokens * K+V * hd * f32 * layers
        assert!(b1 < full, "mgmt {b1} vs full {full}");
    }

    #[test]
    fn scrub_detects_silent_backend_corruption() {
        use crate::disk::{Backend, DiskError, MemBackend};
        let hd = 8;
        let layout = DiskLayout::new(hd, 4, 256, 2, 0);
        // keep a raw handle to the backend so corruption can bypass the
        // stamping write path entirely
        let backend = Arc::new(MemBackend::new());
        let disk = Arc::new(SimDisk::new(DiskProfile::nvme(), backend.clone(), None));
        let cfg = ManagerConfig {
            group: 4,
            rank: 4,
            reuse_slots: 8,
            rb_visible: 4,
            sel_region: 16,
            p: 22,
            cache_flushed: false,
            expose_rolling: true,
        };
        let m = KvManager::new(layout, disk, cfg);
        let mut seq = m.new_seq(0);
        let mut a = Tensor::zeros(&[hd, 4]);
        for i in 0..4 {
            *a.at_mut(&[i, i]) = 1.0;
        }
        let (k, v) = rows(16, hd, 9); // 4 full groups per layer
        m.ingest_prefill(&mut seq, 0, &k, &v, &a).unwrap();
        m.ingest_prefill(&mut seq, 1, &k, &v, &a).unwrap();
        assert_eq!(m.scrub(&seq).unwrap(), 8, "4 groups x 2 layers, all clean");

        // flip one byte of layer 1 / group 2 behind the manager's back
        let off = m.layout.offset(0, 1, 2);
        let mut b = [0u8; 1];
        backend.read_at(off + 3, &mut b).unwrap();
        backend.write_at(off + 3, &[b[0] ^ 0x10]).unwrap();
        let err = m.scrub(&seq).unwrap_err();
        assert!(matches!(err, DiskError::Corrupt { offset, .. } if offset == off), "{err}");

        // a legitimate rewrite through the manager's disk re-stamps and
        // the scrub comes back clean
        let span = 2 * 4 * hd..3 * 4 * hd;
        let rec = m.layout.encode_group(&k[span.clone()], &v[span]);
        m.disk.write(off, &rec).unwrap();
        assert_eq!(m.scrub(&seq).unwrap(), 8);
    }
}
