//! Rolling buffer (paper §3.4.1).
//!
//! Newly generated KV entries cannot be judged by the grouped predictor
//! until they complete a group of G, so they are held in memory and
//! always exposed to attention. When a full group accumulates it is
//! flushed (offloaded to disk + appended to the compressed K cache), but
//! the most recent `visible` entries stay attendable regardless — the
//! App. Tab. 3 ablation shows dropping them collapses accuracy.

#[derive(Debug, Clone)]
pub struct RollingBuffer {
    hd: usize,
    group: usize,
    /// How many trailing entries attention may see.
    visible: usize,
    /// All entries since the last flush boundary PLUS the retained
    /// visibility window; ring-compacted on flush.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Absolute token position of entry 0 in `k`/`v`.
    base_pos: usize,
    /// Number of entries already flushed to disk (prefix of `k`).
    flushed: usize,
}

/// A completed group ready for offload.
#[derive(Debug, Clone)]
pub struct FlushedGroup {
    pub group_idx: usize,
    pub k_rows: Vec<f32>,
    pub v_rows: Vec<f32>,
}

impl RollingBuffer {
    pub fn new(hd: usize, group: usize, visible: usize) -> RollingBuffer {
        assert!(group > 0);
        RollingBuffer {
            hd,
            group,
            visible: visible.max(group),
            k: Vec::new(),
            v: Vec::new(),
            base_pos: 0,
            flushed: 0,
        }
    }

    /// Initialize after prefill: `tail_k/v` are the last `n % G` entries
    /// that did not complete a group, starting at absolute pos `base_pos`.
    pub fn init_tail(&mut self, base_pos: usize, tail_k: Vec<Vec<f32>>, tail_v: Vec<Vec<f32>>) {
        assert_eq!(tail_k.len(), tail_v.len());
        self.base_pos = base_pos;
        self.k = tail_k;
        self.v = tail_v;
        self.flushed = 0;
    }

    /// Number of entries attention should see right now.
    pub fn visible_len(&self) -> usize {
        self.k.len().min(self.visible)
    }

    /// (absolute position, k row, v row) of each visible entry.
    pub fn visible_entries(&self) -> impl Iterator<Item = (usize, &[f32], &[f32])> {
        let n = self.k.len();
        let start = n - self.visible_len();
        (start..n).map(move |i| {
            (
                self.base_pos + i,
                self.k[i].as_slice(),
                self.v[i].as_slice(),
            )
        })
    }

    /// Absolute position of the first *unflushed* entry.
    pub fn unflushed_pos(&self) -> usize {
        self.base_pos + self.flushed
    }

    pub fn pending(&self) -> usize {
        self.k.len() - self.flushed
    }

    /// Append a freshly generated KV entry; returns a completed group if
    /// the append filled one (caller offloads it and extends K_lr).
    pub fn push(&mut self, k_row: Vec<f32>, v_row: Vec<f32>) -> Option<FlushedGroup> {
        assert_eq!(k_row.len(), self.hd);
        assert_eq!(v_row.len(), self.hd);
        self.k.push(k_row);
        self.v.push(v_row);
        if self.pending() < self.group {
            return None;
        }
        // flush the completed group
        let start = self.flushed;
        let gpos = self.base_pos + start;
        debug_assert_eq!(gpos % self.group, 0, "group boundary misaligned");
        let mut k_rows = Vec::with_capacity(self.group * self.hd);
        let mut v_rows = Vec::with_capacity(self.group * self.hd);
        for i in start..start + self.group {
            k_rows.extend_from_slice(&self.k[i]);
            v_rows.extend_from_slice(&self.v[i]);
        }
        self.flushed += self.group;
        self.compact();
        Some(FlushedGroup {
            group_idx: gpos / self.group,
            k_rows,
            v_rows,
        })
    }

    /// Drop flushed entries that are no longer in the visibility window.
    fn compact(&mut self) {
        let keep_from = self.k.len().saturating_sub(self.visible).min(self.flushed);
        if keep_from == 0 {
            return;
        }
        self.k.drain(..keep_from);
        self.v.drain(..keep_from);
        self.base_pos += keep_from;
        self.flushed -= keep_from;
    }

    pub fn group(&self) -> usize {
        self.group
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn row(hd: usize, tag: f32) -> Vec<f32> {
        (0..hd).map(|i| tag * 100.0 + i as f32).collect()
    }

    #[test]
    fn flushes_exactly_at_group_boundaries() {
        let mut rb = RollingBuffer::new(8, 4, 8);
        for t in 0..3 {
            assert!(rb.push(row(8, t as f32), row(8, -(t as f32))).is_none());
        }
        let g = rb.push(row(8, 3.0), row(8, -3.0)).unwrap();
        assert_eq!(g.group_idx, 0);
        assert_eq!(g.k_rows.len(), 4 * 8);
        assert_eq!(&g.k_rows[..8], row(8, 0.0).as_slice());
        assert_eq!(&g.k_rows[24..32], row(8, 3.0).as_slice());
        // next flush is group 1 at tokens 4..8
        for t in 4..7 {
            assert!(rb.push(row(8, t as f32), row(8, 0.0)).is_none());
        }
        let g1 = rb.push(row(8, 7.0), row(8, 0.0)).unwrap();
        assert_eq!(g1.group_idx, 1);
    }

    #[test]
    fn visibility_window_spans_flush_boundary() {
        let mut rb = RollingBuffer::new(4, 4, 6);
        for t in 0..8 {
            rb.push(row(4, t as f32), row(4, t as f32));
        }
        // all 8 flushed; window keeps last 6
        let vis: Vec<usize> = rb.visible_entries().map(|(p, _, _)| p).collect();
        assert_eq!(vis, vec![2, 3, 4, 5, 6, 7]);
        rb.push(row(4, 8.0), row(4, 8.0));
        let vis: Vec<usize> = rb.visible_entries().map(|(p, _, _)| p).collect();
        assert_eq!(vis, vec![3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn init_tail_after_prefill() {
        let mut rb = RollingBuffer::new(4, 4, 4);
        // prefill length 10, G=4 -> groups 0,1 flushed; tail = tokens 8,9
        rb.init_tail(8, vec![row(4, 8.0), row(4, 9.0)], vec![row(4, 8.0), row(4, 9.0)]);
        assert_eq!(rb.unflushed_pos(), 8);
        assert_eq!(rb.pending(), 2);
        assert!(rb.push(row(4, 10.0), row(4, 10.0)).is_none());
        let g = rb.push(row(4, 11.0), row(4, 11.0)).unwrap();
        assert_eq!(g.group_idx, 2);
        assert_eq!(&g.k_rows[..4], row(4, 8.0).as_slice());
    }

    #[test]
    fn prop_rolling_buffer_invariants() {
        proptest::check("rolling-invariants", 200, |rng| {
            let hd = 4;
            let g = rng.range(1, 6);
            let vis = rng.range(1, 12);
            let mut rb = RollingBuffer::new(hd, g, vis);
            let mut flushed_tokens = Vec::new();
            let total = rng.range(1, 64);
            for t in 0..total {
                if let Some(fg) = rb.push(row(hd, t as f32), row(hd, t as f32)) {
                    // flushed groups are consecutive and aligned
                    flushed_tokens.push(fg.group_idx);
                    crate::prop_assert!(
                        fg.k_rows.len() == g * hd,
                        "bad flush size"
                    );
                }
                // visibility window always covers the most recent entry
                let vis_pos: Vec<usize> = rb.visible_entries().map(|(p, _, _)| p).collect();
                crate::prop_assert!(
                    vis_pos.last() == Some(&t),
                    "latest token {t} not visible: {vis_pos:?}"
                );
                // visible entries are consecutive positions
                for w in vis_pos.windows(2) {
                    crate::prop_assert!(w[1] == w[0] + 1, "gap in window {vis_pos:?}");
                }
                // pending never reaches a full group after push handling
                crate::prop_assert!(rb.pending() < g.max(1), "pending {} >= g {g}", rb.pending());
            }
            // flushed groups are 0,1,2,... in order
            for (i, gi) in flushed_tokens.iter().enumerate() {
                crate::prop_assert!(*gi == i, "flush order broken {flushed_tokens:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn flushed_group_content_preserves_token_order() {
        proptest::check("rolling-order", 50, |rng| {
            let g = rng.range(1, 5);
            let mut rb = RollingBuffer::new(2, g, 4);
            for t in 0..(3 * g) {
                if let Some(fg) = rb.push(vec![t as f32, 0.0], vec![0.0, t as f32]) {
                    for m in 0..g {
                        let tok = fg.group_idx * g + m;
                        crate::prop_assert!(
                            fg.k_rows[m * 2] == tok as f32,
                            "k order broken in group {}",
                            fg.group_idx
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
