//! Compressed K-cache store (paper §3.2): per (sequence, layer) rows of
//! `K_lr = flatten(K) @ A`, rank r. This is the *only* per-token
//! in-memory state KVSwap keeps — it is what makes prediction feasible
//! without the full K cache. Appended group-wise when the rolling buffer
//! flushes; read as a padded [ncap, r] tensor for the predict artifact.

use crate::runtime::tensor::Tensor;
use crate::util::mathx;

#[derive(Debug, Clone)]
pub struct LowRankStore {
    rank: usize,
    rows: Vec<f32>,
    n: usize,
}

impl LowRankStore {
    pub fn new(rank: usize) -> LowRankStore {
        LowRankStore {
            rank,
            rows: Vec::new(),
            n: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Compress and append `count` K rows (each `hd` floats) with adapter
    /// A [hd, rank] (row-major).
    pub fn append_compressed(&mut self, k_rows: &[f32], hd: usize, adapter: &Tensor) {
        assert_eq!(adapter.shape, vec![hd, self.rank]);
        assert_eq!(k_rows.len() % hd, 0);
        let count = k_rows.len() / hd;
        let old_len = self.rows.len();
        self.rows.resize(old_len + count * self.rank, 0.0);
        mathx::matmul(
            k_rows,
            &adapter.data,
            count,
            hd,
            self.rank,
            &mut self.rows[old_len..],
        );
        self.n += count;
    }

    /// Append already-compressed rows.
    pub fn append_raw(&mut self, rows: &[f32]) {
        assert_eq!(rows.len() % self.rank, 0);
        self.rows.extend_from_slice(rows);
        self.n += rows.len() / self.rank;
    }

    /// Overwrite one compressed row in place (needle planting).
    pub fn patch_row(&mut self, i: usize, row: &[f32]) {
        assert_eq!(row.len(), self.rank);
        assert!(i < self.n, "patch_row {i} >= {}", self.n);
        self.rows[i * self.rank..(i + 1) * self.rank].copy_from_slice(row);
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.rank..(i + 1) * self.rank]
    }

    /// Copy into a zero-padded [ncap, rank] destination slice (one batch
    /// row of the predict artifact's k_lr input).
    pub fn fill_padded(&self, dst: &mut [f32], ncap: usize) {
        assert_eq!(dst.len(), ncap * self.rank);
        let n = self.n.min(ncap);
        dst[..n * self.rank].copy_from_slice(&self.rows[..n * self.rank]);
        dst[n * self.rank..].fill(0.0);
    }

    /// Memory footprint in bytes.
    pub fn bytes(&self) -> u64 {
        (self.rows.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_compressed_matches_matmul() {
        let hd = 4;
        let rank = 2;
        // adapter columns = selector of dims 0 and 2
        let adapter = Tensor::from_vec(
            &[hd, rank],
            vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0],
        );
        let mut s = LowRankStore::new(rank);
        let k_rows = vec![
            1.0, 2.0, 3.0, 4.0, // row 0
            5.0, 6.0, 7.0, 8.0, // row 1
        ];
        s.append_compressed(&k_rows, hd, &adapter);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(0), &[1.0, 3.0]);
        assert_eq!(s.row(1), &[5.0, 7.0]);
    }

    #[test]
    fn fill_padded_zero_tail() {
        let mut s = LowRankStore::new(2);
        s.append_raw(&[1.0, 2.0, 3.0, 4.0]);
        let mut dst = vec![9.0; 8];
        s.fill_padded(&mut dst, 4);
        assert_eq!(dst, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn incremental_appends_accumulate() {
        let mut s = LowRankStore::new(3);
        s.append_raw(&[1.0; 3]);
        s.append_raw(&[2.0; 6]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.row(2), &[2.0; 3]);
        assert_eq!(s.bytes(), 36);
    }
}
