//! Mapping table (paper §3.4.4): gives the attention kernel a contiguous
//! logical view over heterogeneous memory regions — reuse-buffer slots,
//! freshly loaded groups, and rolling-buffer entries — "similar to OS
//! virtual memory", and is what makes the layout PagedAttention-
//! compatible. Rebuilt before every attention call as reuse patterns
//! shift.

/// Where one attention slot's KV entry comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotSource {
    /// Token `member` of selected group `gid` (resident in reuse buffer
    /// or fresh staging).
    Group { gid: u32, member: u16 },
    /// Rolling-buffer entry at absolute position `pos`.
    Rolling { pos: u32 },
    /// Padding — masked out of attention.
    Invalid,
}

#[derive(Debug, Clone)]
pub struct SlotMap {
    pub slots: Vec<SlotSource>,
    /// Number of valid (attendable) slots.
    pub n_valid: usize,
}

impl SlotMap {
    /// Build the logical view for one (sequence, layer) attention call.
    ///
    /// * `selection`  — selected group ids (≤ M), score-descending.
    /// * `group`      — G.
    /// * `sel_region` — attention slots reserved for selected groups (M*G).
    /// * `p`          — total attention width P.
    /// * `rb_start`   — absolute position of the first rolling-buffer-
    ///                  visible token; group tokens at/after this position
    ///                  are masked to avoid double counting.
    /// * `rb_len`     — rolling-buffer visible entries.
    pub fn build(
        selection: &[u32],
        group: usize,
        sel_region: usize,
        p: usize,
        rb_start: usize,
        rb_len: usize,
    ) -> SlotMap {
        assert!(sel_region + rb_len <= p, "P too small: {sel_region}+{rb_len} > {p}");
        let mut slots = vec![SlotSource::Invalid; p];
        let mut n_valid = 0;
        for (si, &gid) in selection.iter().enumerate() {
            if (si + 1) * group > sel_region {
                break;
            }
            for m in 0..group {
                let pos = gid as usize * group + m;
                if pos < rb_start {
                    slots[si * group + m] = SlotSource::Group {
                        gid,
                        member: m as u16,
                    };
                    n_valid += 1;
                }
            }
        }
        for j in 0..rb_len {
            slots[sel_region + j] = SlotSource::Rolling {
                pos: (rb_start + j) as u32,
            };
            n_valid += 1;
        }
        SlotMap { slots, n_valid }
    }

    /// Additive attention mask row (0 valid / NEG_INF invalid).
    pub fn fill_mask(&self, mask_row: &mut [f32]) {
        assert_eq!(mask_row.len(), self.slots.len());
        for (m, s) in mask_row.iter_mut().zip(&self.slots) {
            *m = if *s == SlotSource::Invalid { -1e9 } else { 0.0 };
        }
    }

    /// Absolute token positions covered (for tests / recall metrics).
    pub fn covered_positions(&self, group: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .slots
            .iter()
            .filter_map(|s| match s {
                SlotSource::Group { gid, member } => {
                    Some(*gid as usize * group + *member as usize)
                }
                SlotSource::Rolling { pos } => Some(*pos as usize),
                SlotSource::Invalid => None,
            })
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn basic_layout() {
        // G=2, selection [3,0], sel_region 4, P 8, rb covers pos >= 10, 2 entries
        let sm = SlotMap::build(&[3, 0], 2, 4, 8, 10, 2);
        assert_eq!(
            sm.slots[0],
            SlotSource::Group { gid: 3, member: 0 }
        );
        assert_eq!(
            sm.slots[3],
            SlotSource::Group { gid: 0, member: 1 }
        );
        assert_eq!(sm.slots[4], SlotSource::Rolling { pos: 10 });
        assert_eq!(sm.slots[5], SlotSource::Rolling { pos: 11 });
        assert_eq!(sm.slots[6], SlotSource::Invalid);
        assert_eq!(sm.n_valid, 6);
    }

    #[test]
    fn group_tokens_overlapping_rb_window_are_masked() {
        // G=4, group 2 covers tokens 8..12; rb_start=10 -> members 2,3 masked
        let sm = SlotMap::build(&[2], 4, 4, 8, 10, 3);
        assert_eq!(sm.slots[0], SlotSource::Group { gid: 2, member: 0 }); // pos 8
        assert_eq!(sm.slots[1], SlotSource::Group { gid: 2, member: 1 }); // pos 9
        assert_eq!(sm.slots[2], SlotSource::Invalid); // pos 10 via RB
        assert_eq!(sm.slots[3], SlotSource::Invalid);
        // no double coverage
        let cov = sm.covered_positions(4);
        assert_eq!(cov, vec![8, 9, 10, 11, 12]);
    }

    #[test]
    fn mask_matches_slots() {
        let sm = SlotMap::build(&[0], 2, 2, 5, 100, 1);
        let mut mask = vec![0.0f32; 5];
        sm.fill_mask(&mut mask);
        assert_eq!(mask, vec![0.0, 0.0, 0.0, -1e9, -1e9]);
    }

    #[test]
    fn prop_no_position_covered_twice_and_all_selected_covered() {
        proptest::check("mapping-coverage", 200, |rng| {
            let g = rng.range(1, 6);
            let m_region = rng.range(1, 8) * g;
            let rb_len = rng.range(0, 8);
            let p = m_region + rb_len + rng.below(4);
            let n_groups_flushed = rng.range(4, 40);
            let rb_start = n_groups_flushed * g - rng.below((g * 2).min(n_groups_flushed * g));
            // random distinct selection
            let n_sel = rng.range(0, (m_region / g) + 1);
            let sel: Vec<u32> = rng
                .sample_indices(n_groups_flushed, n_sel.min(n_groups_flushed))
                .into_iter()
                .map(|x| x as u32)
                .collect();
            let sm = SlotMap::build(&sel, g, m_region, p, rb_start, rb_len);
            let cov = sm.covered_positions(g);
            let mut dedup = cov.clone();
            dedup.dedup();
            crate::prop_assert!(dedup.len() == cov.len(), "position covered twice: {cov:?}");
            // every selected-group token below rb_start is covered
            for &gid in &sel {
                for mm in 0..g {
                    let pos = gid as usize * g + mm;
                    if pos < rb_start {
                        crate::prop_assert!(
                            cov.binary_search(&pos).is_ok(),
                            "selected pos {pos} not covered"
                        );
                    }
                }
            }
            // n_valid consistent
            crate::prop_assert!(sm.n_valid == cov.len(), "n_valid mismatch");
            Ok(())
        });
    }
}
