//! On-disk KV cache layout.
//!
//! The full KV cache lives on disk (paper §3: "stores the complete KV
//! cache on disk"). Entries are stored in *groups* of G consecutive
//! tokens so one prediction group = one contiguous disk extent, aligned
//! to the storage page granule — this is the paper's core I/O design
//! (§3.3: "groups G consecutive KV entries to align with the block-read
//! characteristics").
//!
//! Group record layout (row-major f32):
//!   [ K rows: G x (Hkv*d) | V rows: G x (Hkv*d) ]
//! padded up to the next multiple of `page_align` bytes.
//!
//! Address = seq_slot * seq_stride + layer * layer_stride + group * gstride.

#[derive(Debug, Clone, PartialEq)]
pub struct DiskLayout {
    /// Flattened KV row size (Hkv * d floats).
    pub hd: usize,
    /// Tokens per group (G).
    pub group: usize,
    /// Max groups per (seq, layer) — capacity for max context.
    pub max_groups: usize,
    /// Number of layers.
    pub n_layers: usize,
    /// Group record alignment in bytes (storage page granule).
    pub page_align: u64,
}

impl DiskLayout {
    pub fn new(
        hd: usize,
        group: usize,
        max_context: usize,
        n_layers: usize,
        page_align: u64,
    ) -> DiskLayout {
        DiskLayout {
            hd,
            group,
            max_groups: max_context.div_ceil(group),
            n_layers,
            page_align,
        }
    }

    /// Payload bytes of one group record (K+V rows).
    pub fn group_payload_bytes(&self) -> u64 {
        (2 * self.group * self.hd * 4) as u64
    }

    /// On-disk stride of one group record (payload padded to page align).
    pub fn group_stride(&self) -> u64 {
        let p = self.group_payload_bytes();
        if self.page_align == 0 {
            p
        } else {
            p.div_ceil(self.page_align) * self.page_align
        }
    }

    pub fn layer_stride(&self) -> u64 {
        self.max_groups as u64 * self.group_stride()
    }

    pub fn seq_stride(&self) -> u64 {
        self.n_layers as u64 * self.layer_stride()
    }

    /// Disk offset of a group record.
    pub fn offset(&self, seq_slot: usize, layer: usize, group_idx: usize) -> u64 {
        assert!(layer < self.n_layers, "layer {layer}");
        assert!(
            group_idx < self.max_groups,
            "group {group_idx} >= {}",
            self.max_groups
        );
        seq_slot as u64 * self.seq_stride()
            + layer as u64 * self.layer_stride()
            + group_idx as u64 * self.group_stride()
    }

    /// Which group holds token `t`, and its index within the group.
    pub fn locate(&self, token: usize) -> (usize, usize) {
        (token / self.group, token % self.group)
    }

    /// Serialize one group's K/V rows into a disk record (payload only).
    pub fn encode_group(&self, k_rows: &[f32], v_rows: &[f32]) -> Vec<u8> {
        assert_eq!(k_rows.len(), self.group * self.hd);
        assert_eq!(v_rows.len(), self.group * self.hd);
        let mut out = Vec::with_capacity(self.group_payload_bytes() as usize);
        for v in k_rows.iter().chain(v_rows.iter()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Decode a group record into (k_rows, v_rows).
    pub fn decode_group(&self, bytes: &[u8]) -> (Vec<f32>, Vec<f32>) {
        let n = self.group * self.hd;
        assert!(bytes.len() >= 2 * n * 4, "short group record");
        let mut vals = bytes
            .chunks_exact(4)
            .take(2 * n)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        let k: Vec<f32> = vals.by_ref().take(n).collect();
        let v: Vec<f32> = vals.collect();
        (k, v)
    }

    /// Total disk footprint of `n_seqs` sequences.
    pub fn total_bytes(&self, n_seqs: usize) -> u64 {
        n_seqs as u64 * self.seq_stride()
    }

    /// Content checksum of one encoded group record — the same FNV-1a the
    /// disk layer stamps at write time, so callers (e.g. `KvManager::
    /// scrub`) can compare independently-computed sums against what the
    /// storage returns.
    pub fn record_checksum(&self, record: &[u8]) -> u64 {
        crate::disk::fnv1a64(record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn layout() -> DiskLayout {
        DiskLayout::new(128, 4, 2048, 4, 4096)
    }

    #[test]
    fn group_sizes_page_aligned() {
        let l = layout();
        assert_eq!(l.group_payload_bytes(), 4096); // 4*2*128*4
        assert_eq!(l.group_stride(), 4096);
        // eMMC-style 16K alignment pads
        let l2 = DiskLayout::new(128, 4, 2048, 4, 16384);
        assert_eq!(l2.group_stride(), 16384);
        // no alignment
        let l3 = DiskLayout::new(128, 3, 2048, 4, 0);
        assert_eq!(l3.group_stride(), l3.group_payload_bytes());
    }

    #[test]
    fn offsets_disjoint_and_ordered() {
        let l = layout();
        assert_eq!(l.offset(0, 0, 0), 0);
        assert_eq!(l.offset(0, 0, 1), l.group_stride());
        assert_eq!(l.offset(0, 1, 0), l.layer_stride());
        assert_eq!(l.offset(1, 0, 0), l.seq_stride());
        assert_eq!(l.max_groups, 512);
    }

    #[test]
    fn locate_tokens() {
        let l = layout();
        assert_eq!(l.locate(0), (0, 0));
        assert_eq!(l.locate(3), (0, 3));
        assert_eq!(l.locate(4), (1, 0));
        assert_eq!(l.locate(11), (2, 3));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let l = layout();
        let n = l.group * l.hd;
        let k: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let v: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let rec = l.encode_group(&k, &v);
        assert_eq!(rec.len() as u64, l.group_payload_bytes());
        let (k2, v2) = l.decode_group(&rec);
        assert_eq!(k2, k);
        assert_eq!(v2, v);
    }

    #[test]
    fn record_checksum_tracks_content() {
        let l = layout();
        let n = l.group * l.hd;
        let k: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        let v: Vec<f32> = (0..n).map(|i| 1.0 - i as f32).collect();
        let rec = l.encode_group(&k, &v);
        let sum = l.record_checksum(&rec);
        assert_eq!(sum, crate::disk::fnv1a64(&rec), "delegates to disk FNV");
        // encoding is deterministic, so the sum is too
        assert_eq!(sum, l.record_checksum(&l.encode_group(&k, &v)));
        // any content change moves the checksum
        let mut flipped = rec.clone();
        flipped[5] ^= 0x01;
        assert_ne!(sum, l.record_checksum(&flipped));
    }

    #[test]
    fn prop_no_two_records_overlap() {
        proptest::check("layout-disjoint", 100, |rng| {
            let hd = [32, 64, 128][rng.below(3)];
            let g = [1, 2, 4, 8][rng.below(4)];
            let layers = rng.range(1, 6);
            let l = DiskLayout::new(hd, g, 256, layers, [0u64, 512, 4096][rng.below(3)]);
            // two random distinct records
            let a = (rng.below(3), rng.below(layers), rng.below(l.max_groups));
            let b = (rng.below(3), rng.below(layers), rng.below(l.max_groups));
            if a == b {
                return Ok(());
            }
            let (oa, ob) = (l.offset(a.0, a.1, a.2), l.offset(b.0, b.1, b.2));
            let s = l.group_stride();
            crate::prop_assert!(
                oa + s <= ob || ob + s <= oa,
                "records overlap: {a:?}@{oa} vs {b:?}@{ob} stride {s}"
            );
            Ok(())
        });
    }
}
