//! Reuse buffer (paper §3.4.3): fixed memory slots caching recently
//! loaded KV groups across decode steps, exploiting the temporal locality
//! of predicted critical groups (§3.4.2, Fig. 8). FIFO replacement, slot
//! table for O(1) lookup. Hit/miss counters feed Tab. 5.

use std::collections::{HashMap, HashSet, VecDeque};

#[derive(Debug)]
pub struct ReuseBuffer {
    /// Capacity in slots (C in the paper), each holding one group.
    capacity: usize,
    /// group payload floats per slot (2 * G * Hkv*d).
    slot_floats: usize,
    /// Flat slot storage: slot s at [s*slot_floats, (s+1)*slot_floats).
    data: Vec<f32>,
    /// Slot table: group id -> slot index.
    table: HashMap<u32, usize>,
    /// FIFO order of resident group ids.
    fifo: VecDeque<u32>,
    free: Vec<usize>,
    /// Groups pinned for the in-flight step (unevictable): the current
    /// selection must survive inserts of its own misses.
    pinned: HashSet<u32>,
    hits: u64,
    misses: u64,
}

impl ReuseBuffer {
    pub fn new(capacity: usize, slot_floats: usize) -> ReuseBuffer {
        ReuseBuffer {
            capacity,
            slot_floats,
            data: vec![0.0; capacity * slot_floats],
            table: HashMap::with_capacity(capacity),
            fifo: VecDeque::with_capacity(capacity),
            free: (0..capacity).rev().collect(),
            pinned: HashSet::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Pin groups for the in-flight step; pinned groups are never evicted.
    pub fn pin_many(&mut self, gids: &[u32]) {
        self.pinned.extend(gids.iter().cloned());
    }

    pub fn unpin_all(&mut self) {
        self.pinned.clear();
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// Look up a group; counts a hit or miss. Returns the slot payload
    /// (k_rows ++ v_rows) if resident.
    pub fn lookup(&mut self, gid: u32) -> Option<&[f32]> {
        match self.table.get(&gid) {
            Some(&slot) => {
                self.hits += 1;
                Some(&self.data[slot * self.slot_floats..(slot + 1) * self.slot_floats])
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Peek without counting (used by planners to diff selections).
    pub fn contains(&self, gid: u32) -> bool {
        self.table.contains_key(&gid)
    }

    /// Fetch without touching hit/miss counters (assembly path — the
    /// hit/miss decision was already counted at plan time).
    pub fn get(&self, gid: u32) -> Option<&[f32]> {
        self.table
            .get(&gid)
            .map(|&slot| &self.data[slot * self.slot_floats..(slot + 1) * self.slot_floats])
    }

    /// Insert a loaded group (k_rows ++ v_rows), evicting the FIFO-oldest
    /// *unpinned* group if full. Returns the slot index, or None when no
    /// slot can be claimed (capacity 0, or everything pinned) — the
    /// caller then stages the payload for this step only.
    pub fn insert(&mut self, gid: u32, payload: &[f32]) -> Option<usize> {
        if self.capacity == 0 {
            return None;
        }
        assert_eq!(payload.len(), self.slot_floats, "payload size");
        if let Some(&slot) = self.table.get(&gid) {
            // refresh contents (e.g. group rewritten after RB flush)
            self.data[slot * self.slot_floats..(slot + 1) * self.slot_floats]
                .copy_from_slice(payload);
            return Some(slot);
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                // rotate past pinned entries (bounded by fifo length)
                let mut victim = None;
                for _ in 0..self.fifo.len() {
                    let g = self.fifo.pop_front().expect("fifo empty but no free slot");
                    if self.pinned.contains(&g) {
                        self.fifo.push_back(g);
                    } else {
                        victim = Some(g);
                        break;
                    }
                }
                let victim = victim?;
                self.table.remove(&victim).expect("victim not in table")
            }
        };
        self.data[slot * self.slot_floats..(slot + 1) * self.slot_floats]
            .copy_from_slice(payload);
        self.table.insert(gid, slot);
        self.fifo.push_back(gid);
        Some(slot)
    }

    /// Invalidate a group (its disk contents changed and the caller does
    /// not have the fresh payload at hand).
    pub fn invalidate(&mut self, gid: u32) {
        if let Some(slot) = self.table.remove(&gid) {
            self.free.push(slot);
            self.fifo.retain(|g| *g != gid);
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Bytes of slot storage (for memory accounting).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn payload(n: usize, tag: f32) -> Vec<f32> {
        vec![tag; n]
    }

    #[test]
    fn hit_miss_and_contents() {
        let mut rb = ReuseBuffer::new(2, 4);
        assert!(rb.lookup(5).is_none());
        rb.insert(5, &payload(4, 5.0));
        assert_eq!(rb.lookup(5).unwrap(), payload(4, 5.0).as_slice());
        assert_eq!(rb.counters(), (1, 1));
        assert!((rb.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fifo_eviction_order() {
        let mut rb = ReuseBuffer::new(2, 1);
        rb.insert(1, &[1.0]);
        rb.insert(2, &[2.0]);
        rb.insert(3, &[3.0]); // evicts 1 (FIFO, not LRU)
        assert!(!rb.contains(1));
        assert!(rb.contains(2) && rb.contains(3));
        // touching 2 does NOT protect it (FIFO)
        rb.lookup(2);
        rb.insert(4, &[4.0]); // evicts 2
        assert!(!rb.contains(2));
        assert!(rb.contains(3) && rb.contains(4));
    }

    #[test]
    fn reinsert_refreshes_payload() {
        let mut rb = ReuseBuffer::new(2, 2);
        rb.insert(7, &[1.0, 1.0]);
        rb.insert(7, &[9.0, 9.0]);
        assert_eq!(rb.lookup(7).unwrap(), &[9.0, 9.0]);
        assert_eq!(rb.len(), 1);
    }

    #[test]
    fn invalidate_frees_slot() {
        let mut rb = ReuseBuffer::new(1, 1);
        rb.insert(1, &[1.0]);
        rb.invalidate(1);
        assert!(rb.is_empty());
        rb.insert(2, &[2.0]);
        assert!(rb.contains(2));
    }

    #[test]
    fn capacity_zero_disables_reuse() {
        let mut rb = ReuseBuffer::new(0, 4);
        assert!(rb.insert(1, &payload(4, 1.0)).is_none());
        assert!(rb.lookup(1).is_none());
    }

    #[test]
    fn prop_never_exceeds_capacity_and_table_consistent() {
        proptest::check("reuse-capacity", 200, |rng| {
            let cap = rng.range(1, 8);
            let mut rb = ReuseBuffer::new(cap, 2);
            for _ in 0..100 {
                let gid = rng.below(20) as u32;
                if rng.chance(0.7) {
                    rb.insert(gid, &[gid as f32, 0.0]);
                } else if rng.chance(0.5) {
                    rb.lookup(gid);
                } else {
                    rb.invalidate(gid);
                }
                crate::prop_assert!(rb.len() <= cap, "len {} > cap {cap}", rb.len());
                // every resident gid's payload is intact
                let resident: Vec<u32> = rb.fifo.iter().cloned().collect();
                crate::prop_assert!(
                    resident.len() == rb.len(),
                    "fifo/table desync: {} vs {}",
                    resident.len(),
                    rb.len()
                );
                for g in resident {
                    let p = rb.table[&g];
                    crate::prop_assert!(
                        rb.data[p * 2] == g as f32,
                        "slot payload corrupted for {g}"
                    );
                }
            }
            Ok(())
        });
    }
}
