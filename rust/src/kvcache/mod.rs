//! KV-cache subsystem — the paper's §3.4 runtime state:
//! disk layout (grouped, page-aligned records), rolling buffer for fresh
//! entries, FIFO reuse buffer with slot table, compressed K-cache store,
//! mapping table, and the manager that orchestrates them.

pub mod layout;
pub mod lowrank;
pub mod manager;
pub mod mapping;
pub mod reuse;
pub mod rolling;

pub use layout::DiskLayout;
pub use lowrank::LowRankStore;
pub use manager::{GroupLoad, KvManager, ManagerConfig, SeqState};
pub use mapping::{SlotMap, SlotSource};
pub use reuse::ReuseBuffer;
pub use rolling::{FlushedGroup, RollingBuffer};
