//! Budget-matched baseline configurations (paper §4.3).
//!
//! Setting A constrains every offloading method to the same *per-batch*
//! KV memory budget: relaxed = 1/13 of the full cache, tight = 1/34
//! ("-t" variants). The knobs differ per method — KVSwap adjusts σ/C,
//! ShadowKV its K rank, Loki its key dimensionality, InfiniGen its
//! partial-weight ratio — mirrored here on our scale.

use crate::config::KvSwapConfig;
use crate::coordinator::Policy;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Budget {
    /// 1/13 of the full KV cache per batch row.
    Relaxed,
    /// 1/34 of the full KV cache per batch row ("-t").
    Tight,
}

impl Budget {
    pub fn fraction(&self) -> f64 {
        match self {
            Budget::Relaxed => 1.0 / 13.0,
            Budget::Tight => 1.0 / 34.0,
        }
    }

    pub fn suffix(&self) -> &'static str {
        match self {
            Budget::Relaxed => "",
            Budget::Tight => "-t",
        }
    }
}

/// The benchmark roster of §4.2 (order matches the paper's tables).
pub fn roster() -> Vec<Policy> {
    vec![
        Policy::FlexGen,
        Policy::InfiniGen {
            head_agg: false,
            reuse: false,
        },
        Policy::InfiniGen {
            head_agg: true,
            reuse: false,
        },
        Policy::InfiniGen {
            head_agg: true,
            reuse: true,
        },
        Policy::Loki,
        Policy::ShadowKv { chunk: 8, rank: 32 },
        Policy::KvSwap,
        Policy::FullMemory,
    ]
}

/// Budget-matched (policy, runtime config) for one method. `group` is
/// the tuned KVSwap group size for the disk (G=4 NVMe / G=8 eMMC).
pub fn configure(policy: &Policy, budget: Budget, group: usize) -> (Policy, KvSwapConfig) {
    let mut kv = KvSwapConfig::default();
    kv.group_size = group;
    kv.n_groups = kv.selected_entries().max(256) / group; // keep MG = 256
    kv.n_groups = 256 / group;
    match (policy, budget) {
        (Policy::KvSwap, Budget::Relaxed) => {
            kv.rank = 16; // sigma = 8
            kv.reuse_slots = 96 / group * 4;
        }
        (Policy::KvSwap, Budget::Tight) => {
            kv.rank = 4; // sigma = 32 (the paper's sigma_max)
            kv.reuse_slots = 32 / group * 4;
        }
        (Policy::ShadowKv { .. }, _) => {
            // chunk-granular; its rank knob lives in the policy itself
            kv.group_size = 8;
            kv.n_groups = 32;
        }
        (Policy::InfiniGen { .. } | Policy::Loki, Budget::Relaxed) => {
            kv.rank = 16;
        }
        (Policy::InfiniGen { .. } | Policy::Loki, Budget::Tight) => {
            kv.rank = 4;
        }
        _ => {}
    }
    let policy = match (policy, budget) {
        // ShadowKV's rank buys *reconstruction* fidelity (it rebuilds K
        // from K_lr for attention), so the budget caps it hard:
        // relaxed 1/13 of K cache -> rank 16; tight 1/34 -> rank 4,
        // below the K cache's effective rank — quality collapses
        // (the paper's §3.2 contrast with KVSwap's index-only use).
        (Policy::ShadowKv { chunk, .. }, Budget::Relaxed) => Policy::ShadowKv {
            chunk: *chunk,
            rank: 16,
        },
        (Policy::ShadowKv { chunk, .. }, Budget::Tight) => Policy::ShadowKv {
            chunk: *chunk,
            rank: 4,
        },
        (p, _) => p.clone(),
    };
    (policy, kv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_matches_paper_lineup() {
        let names: Vec<String> = roster().iter().map(|p| p.name()).collect();
        assert_eq!(
            names,
            vec![
                "flexgen",
                "infinigen",
                "infinigen*",
                "infinigen*+ru",
                "loki",
                "shadowkv",
                "kvswap",
                "vllm-like"
            ]
        );
    }

    #[test]
    fn budgets() {
        assert!((Budget::Relaxed.fraction() - 1.0 / 13.0).abs() < 1e-12);
        assert!((Budget::Tight.fraction() - 1.0 / 34.0).abs() < 1e-12);
        assert_eq!(Budget::Tight.suffix(), "-t");
    }

    #[test]
    fn tight_budget_shrinks_ranks() {
        let (p_r, kv_r) = configure(&Policy::KvSwap, Budget::Relaxed, 4);
        let (p_t, kv_t) = configure(&Policy::KvSwap, Budget::Tight, 4);
        assert_eq!(p_r, p_t);
        assert!(kv_t.rank < kv_r.rank);
        assert!(kv_t.reuse_slots < kv_r.reuse_slots);
        // MG stays constant (Appendix A.2)
        assert_eq!(kv_r.selected_entries(), kv_t.selected_entries());

        let (s_r, _) = configure(&Policy::ShadowKv { chunk: 8, rank: 32 }, Budget::Relaxed, 4);
        let (s_t, _) = configure(&Policy::ShadowKv { chunk: 8, rank: 32 }, Budget::Tight, 4);
        match (s_r, s_t) {
            (Policy::ShadowKv { rank: r1, .. }, Policy::ShadowKv { rank: r2, .. }) => {
                assert_eq!((r1, r2), (16, 4))
            }
            _ => panic!(),
        }
    }

    #[test]
    fn group_size_respected_and_mg_held() {
        for g in [1, 2, 4, 8] {
            let (_, kv) = configure(&Policy::KvSwap, Budget::Relaxed, g);
            assert_eq!(kv.group_size, g);
            assert_eq!(kv.selected_entries(), 256);
        }
    }
}
