//! TCP serving front: newline-delimited JSON over a socket.
//!
//! Request:  {"id": 1, "context": 512, "decode": 32, "seed": 7}
//!           (synthetic prompt derived from `seed`; or pass explicit
//!            "tokens": [...])
//! Response: {"id": 1, "tokens": [...], "latency_ms": 12.3, "batch": 4}
//!           (a request whose wave failed gets "tokens": [] plus an
//!            "error" field — the session keeps serving)
//!
//! Control lines: "flush" dispatches queued requests immediately,
//! "stats" returns a one-line health JSON (circuit-breaker state,
//! io_overlap_ratio, degraded_steps, persistent-store counters), and
//! "quit" ends the connection.
//!
//! The server forwards to the `Router` (engine thread) and streams
//! completions back on the same connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use crate::coordinator::router::{Completion, Router};
use crate::util::json::Json;
use crate::workload::tracegen::Request;

pub fn parse_request(line: &str, fallback_id: u64) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let tokens = match j.get("tokens") {
        None | Some(Json::Null) => None,
        Some(t) => {
            let arr = t.as_arr().ok_or_else(|| "tokens must be an array".to_string())?;
            let mut toks = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                let n = v
                    .as_f64()
                    .filter(|f| f.fract() == 0.0 && f.is_finite())
                    .ok_or_else(|| format!("tokens[{i}] must be an integer"))?;
                toks.push(n as i32);
            }
            Some(toks)
        }
    };
    // Explicit tokens pin the context length; "context" only sizes the
    // seeded synthetic prompt.
    let context = match &tokens {
        Some(t) => t.len(),
        None => j.usize_or("context", 512),
    };
    Ok(Request {
        id: j.usize_or("id", fallback_id as usize) as u64,
        context,
        decode: j.usize_or("decode", 16),
        arrival_s: 0.0,
        seed: j.usize_or("seed", fallback_id as usize) as u64,
        tokens,
    })
}

pub fn completion_to_json(c: &Completion) -> Json {
    let mut j = Json::from_pairs(vec![
        ("id", (c.id as usize).into()),
        (
            "tokens",
            Json::Arr(c.tokens.iter().map(|t| Json::Num(*t as f64)).collect()),
        ),
        ("latency_ms", c.latency_ms.into()),
        ("batch", c.batch.into()),
    ]);
    // only failed waves carry an error field, so healthy responses keep
    // their existing shape
    if let Some(e) = &c.error {
        j.set("error", e.as_str().into());
    }
    j
}

/// Serve one connection: read requests until EOF (or "flush"/"quit"
/// lines), forward to the router, write completions back.
pub fn handle_conn(stream: TcpStream, router: &Router) -> anyhow::Result<usize> {
    let mut out = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut submitted = 0usize;
    for (i, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if trimmed == "quit" {
            break;
        }
        if trimmed == "flush" {
            router.flush();
            continue;
        }
        if trimmed == "stats" {
            match router.stats() {
                Some(j) => writeln!(out, "{j}")?,
                None => {
                    let err = Json::from_pairs(vec![("error", "stats unavailable".into())]);
                    writeln!(out, "{err}")?;
                }
            }
            continue;
        }
        match parse_request(trimmed, i as u64) {
            Ok(req) => {
                router.submit(req);
                submitted += 1;
            }
            Err(e) => {
                let err = Json::from_pairs(vec![("error", e.as_str().into())]);
                writeln!(out, "{err}")?;
            }
        }
    }
    router.flush();
    for _ in 0..submitted {
        let Some(c) = router.recv_timeout(std::time::Duration::from_secs(600)) else {
            break;
        };
        writeln!(out, "{}", completion_to_json(&c))?;
    }
    Ok(submitted)
}

/// Accept loop (single connection at a time; the engine is the serial
/// resource anyway).
pub fn serve(addr: &str, router: &Router, max_conns: Option<usize>) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::log_info!("listening on {addr}");
    let mut served = 0;
    for stream in listener.incoming() {
        let n = handle_conn(stream?, router)?;
        crate::log_info!("connection done: {n} requests");
        served += 1;
        if let Some(m) = max_conns {
            if served >= m {
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full_and_defaults() {
        let r = parse_request(r#"{"id": 3, "context": 256, "decode": 8, "seed": 9}"#, 0).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.context, 256);
        assert_eq!(r.decode, 8);
        assert_eq!(r.seed, 9);
        assert_eq!(r.tokens, None);
        let d = parse_request("{}", 42).unwrap();
        assert_eq!(d.id, 42);
        assert_eq!(d.context, 512);
        assert!(parse_request("not json", 0).is_err());
    }

    #[test]
    fn parse_request_malformed_json() {
        // truncated object, bare value, and trailing garbage all fail
        // without panicking
        assert!(parse_request("{", 0).is_err());
        assert!(parse_request(r#"{"id": }"#, 0).is_err());
        assert!(parse_request("", 0).is_err());
    }

    #[test]
    fn parse_request_explicit_tokens() {
        let r = parse_request(r#"{"id": 1, "tokens": [5, 6, 7], "decode": 4}"#, 0).unwrap();
        assert_eq!(r.tokens, Some(vec![5, 6, 7]));
        // explicit tokens pin context to their length, overriding any
        // "context" field
        assert_eq!(r.context, 3);
        let r2 = parse_request(r#"{"tokens": [1, 2], "context": 999}"#, 0).unwrap();
        assert_eq!(r2.context, 2);
        // JSON null is the same as absent
        let r3 = parse_request(r#"{"tokens": null, "context": 64}"#, 0).unwrap();
        assert_eq!(r3.tokens, None);
        assert_eq!(r3.context, 64);
    }

    #[test]
    fn parse_request_rejects_bad_tokens_payloads() {
        // non-array tokens
        assert!(parse_request(r#"{"tokens": 5}"#, 0).is_err());
        assert!(parse_request(r#"{"tokens": "abc"}"#, 0).is_err());
        // non-integer entries
        assert!(parse_request(r#"{"tokens": [1, "a", 3]}"#, 0).is_err());
        assert!(parse_request(r#"{"tokens": [1.5]}"#, 0).is_err());
        // empty array is legal (zero-length prompt, padded by the wave)
        let r = parse_request(r#"{"tokens": []}"#, 0).unwrap();
        assert_eq!(r.tokens, Some(vec![]));
        assert_eq!(r.context, 0);
    }

    #[test]
    fn parse_request_missing_field_fallbacks() {
        let r = parse_request(r#"{"context": 128}"#, 7).unwrap();
        assert_eq!(r.id, 7); // fallback id
        assert_eq!(r.seed, 7); // seed falls back to the same line id
        assert_eq!(r.decode, 16);
        assert_eq!(r.context, 128);
    }

    #[test]
    fn completion_json_shape() {
        let c = Completion {
            id: 7,
            tokens: vec![1, 2, 3],
            latency_ms: 4.5,
            batch: 2,
            error: None,
        };
        let j = completion_to_json(&c);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.usize_or("id", 0), 7);
        assert_eq!(back.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(back.f64_or("latency_ms", 0.0), 4.5);
        // a clean completion has no error field at all
        assert!(back.get("error").is_none());
    }

    #[test]
    fn completion_json_carries_wave_error() {
        let c = Completion {
            id: 9,
            tokens: vec![],
            latency_ms: 1.0,
            batch: 4,
            error: Some("prompt too long for prefill artifact".into()),
        };
        let back = Json::parse(&completion_to_json(&c).to_string()).unwrap();
        assert_eq!(back.usize_or("id", 0), 9);
        assert_eq!(back.get("tokens").unwrap().as_arr().unwrap().len(), 0);
        assert!(back
            .get("error")
            .and_then(|e| e.as_str())
            .is_some_and(|e| e.contains("prompt too long")));
    }
}
