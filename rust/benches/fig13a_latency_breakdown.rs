//! Fig. 13a — decoding latency breakdown of a single transformer block
//! on NVMe (paper: FlexGen is I/O-bound; InfiniGen* still I/O-dominant;
//! KVSwap w/o reuse cuts latency 1.5×; with reuse I/O drops 4.3× more,
//! total 6.9 ms with ~1 ms reuse overhead).

use kvswap::baselines::{configure, Budget};
use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::config::{FaultConfig, PrefetchConfig};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::{Phase, Table};
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 6);
    let batch = args.usize_or("batch", 8);
    banner(
        "Fig. 13a — per-block decode latency breakdown (NVMe, ms)",
        "io_wait = unhidden I/O stall; compute = attention + predict",
    );
    let rt = runtime()?;
    let layers = rt.manifest.presets["nano"].spec.n_layers as f64;

    let roster: Vec<(&str, Policy, bool)> = vec![
        ("flexgen", Policy::FlexGen, true),
        (
            "infinigen*",
            Policy::InfiniGen {
                head_agg: true,
                reuse: false,
            },
            true,
        ),
        (
            "infinigen*+ru",
            Policy::InfiniGen {
                head_agg: true,
                reuse: true,
            },
            true,
        ),
        ("kvswap wo/reu", Policy::KvSwap, false),
        ("kvswap sync-io", Policy::KvSwap, true),
        ("kvswap", Policy::KvSwap, true),
        ("kvswap 5%fault", Policy::KvSwap, true),
    ];
    let mut t = Table::new(&["method", "io_wait", "attn", "predict", "gather", "reuse_mgmt", "total/block"]);
    for (name, policy, reuse) in roster {
        let (p, mut kv) = configure(&policy, Budget::Relaxed, 4);
        if !reuse && matches!(p, Policy::KvSwap) {
            kv.use_reuse = false;
        }
        let mut cfg = engine_cfg("nano", batch, p, kv, DiskProfile::nvme(), context);
        if name == "kvswap sync-io" {
            // ablation: same policy, no prefetch pipeline — every device
            // read charges the decode loop in full
            cfg.prefetch = PrefetchConfig::synchronous();
        }
        let faulty = name == "kvswap 5%fault";
        if faulty {
            // ablation: 5% transient read faults + 2% silent bit flips —
            // latency under the retry/checksum recovery machinery
            cfg.fault = FaultConfig {
                rate: 0.05,
                corruption_rate: 0.02,
                seed: 7,
                persistent: false,
            };
        }
        let (stats, _) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
        if faulty {
            println!(
                "  [5%fault recovery: {} retries, {} corrupt extents detected, \
                 {} degraded layer-steps]",
                stats.prefetch.io_retries,
                stats.prefetch.corrupt_detected,
                stats.degraded_steps
            );
        }
        let per_block = |ph: Phase| stats.breakdown.per_step_ms(ph) / layers;
        let total = [
            Phase::IoWait,
            Phase::Attention,
            Phase::Predict,
            Phase::Gather,
            Phase::ReuseMgmt,
            Phase::Select,
        ]
        .iter()
        .map(|&p| per_block(p))
        .sum::<f64>();
        t.row(vec![
            name.to_string(),
            format!("{:.2}", per_block(Phase::IoWait)),
            format!("{:.2}", per_block(Phase::Attention)),
            format!("{:.2}", per_block(Phase::Predict)),
            format!("{:.2}", per_block(Phase::Gather)),
            format!("{:.2}", per_block(Phase::ReuseMgmt)),
            format!("{:.2}", total),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper shape: FlexGen's block time is all I/O; selective loading \
         (InfiniGen*) helps but I/O still dominates; KVSwap w/o reuse \
         better utilizes bandwidth; reuse removes most remaining I/O at \
         ~1 ms management overhead"
    );
    Ok(())
}
