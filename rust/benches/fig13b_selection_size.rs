//! Fig. 13b — accuracy/throughput trade-off across the number of
//! selected KV entries MG (paper: accuracy gains flatten past MG=400
//! while throughput keeps dropping; MG=400 is the default). Our compiled
//! attention width caps MG at 256 (the scaled default).

use std::rc::Rc;

use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::evaluate_policy;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 6);
    let batch = args.usize_or("batch", 8);
    banner(
        "Fig. 13b — selected entries (MG) vs fidelity and throughput",
        "MG sweep at G=4; attention width P=272 caps MG at 256",
    );
    let rt = runtime()?;
    let mut t = Table::new(&["MG", "fidelity", "nvme tok/s", "emmc tok/s"]);
    for mg in [32usize, 64, 128, 192, 256] {
        let mut kv = KvSwapConfig::default();
        kv.n_groups = mg / kv.group_size;
        let mut cells = vec![mg.to_string()];
        let qcfg = engine_cfg("nano", 1, Policy::KvSwap, kv.clone(), DiskProfile::nvme(), 2048);
        let q = evaluate_policy(Rc::clone(&rt), qcfg, 1792, 4, 5)?;
        cells.push(format!("{:.3}", q.fidelity));
        for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
            let cfg = engine_cfg("nano", batch, Policy::KvSwap, kv.clone(), disk, context);
            let (stats, _) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
            cells.push(format!("{:.1}", stats.tokens_per_sec()));
        }
        t.row(cells);
    }
    println!("{}", t.render());
    println!(
        "paper shape: fidelity rises with MG then saturates; throughput \
         falls monotonically — the knee is the tuned default"
    );
    Ok(())
}
