//! Tab. 4 (+ App. Tab. 2) — THE throughput grid: tokens/s for every
//! method × disk × batch × context length (paper: KVSwap beats every
//! offloading baseline everywhere; eMMC saturates at large batch; KVSwap
//! can pass vLLM-like at scale; throughput ~flat in context).
//!
//! Default runs a representative subset; pass --full for the whole grid.

use kvswap::baselines::{configure, roster, Budget};
use kvswap::bench::{banner, engine_cfg, paper_context_label, run_throughput, runtime};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let full = args.flag("full");
    let steps = args.usize_or("steps", 5);
    let batches = args.usize_list_or("batches", if full { &[1, 2, 4, 8, 16] } else { &[1, 4, 8] });
    let contexts =
        args.usize_list_or("contexts", if full { &[1024, 2048, 4096, 8192] } else { &[2048, 8192] });
    banner(
        "Tab. 4 — decode throughput grid (tokens/s)",
        "context labels show the paper-scale equivalent (nano 4x)",
    );
    let rt = runtime()?;
    let methods: Vec<Policy> = roster()
        .into_iter()
        .filter(|p| {
            full || !matches!(
                p,
                Policy::InfiniGen {
                    head_agg: false,
                    ..
                }
            )
        })
        .collect();

    for disk in [DiskProfile::emmc(), DiskProfile::nvme()] {
        let group = if disk.name == "emmc" { 8 } else { 4 };
        for &context in &contexts {
            let mut header: Vec<String> = vec!["method".into()];
            header.extend(batches.iter().map(|b| format!("b={b}")));
            let mut t = Table::new(
                &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            );
            for policy in &methods {
                if matches!(policy, Policy::FullMemory) && disk.name == "emmc" {
                    continue; // vLLM row is disk-independent; print once
                }
                let mut cells = vec![policy.name()];
                for &b in &batches {
                    if !rt.manifest.presets["nano"].batches.contains(&b) {
                        cells.push("-".into());
                        continue;
                    }
                    // FlexGen at big contexts is pathologically slow by
                    // design; trim its steps to keep the bench bounded
                    let st = if matches!(policy, Policy::FlexGen) { 2 } else { steps };
                    let (p, kv) = configure(policy, Budget::Relaxed, group);
                    let cfg = engine_cfg("nano", b, p, kv, disk.clone(), context);
                    match run_throughput(rt.clone(), cfg, context - 64, 1, st) {
                        Ok((stats, _)) => cells.push(format!("{:.1}", stats.tokens_per_sec())),
                        Err(e) => {
                            cells.push("!".into());
                            eprintln!("[warn] {}: {e}", policy.name());
                        }
                    }
                }
                t.row(cells);
            }
            println!(
                "--- disk {} | context {} ---",
                disk.name,
                paper_context_label(context)
            );
            println!("{}", t.render());
        }
    }
    println!(
        "paper shape: per-token methods (infinigen/loki) are I/O-crippled; \
         grouped KVSwap scales with batch; eMMC saturates by b=8-16; \
         KVSwap's throughput is ~flat in context length; vllm-like wins \
         small but KVSwap closes/overtakes at scale"
    );
    Ok(())
}
