//! Tab. 5 — reuse-rate and throughput statistics with vs without the
//! reuse buffer across disks and workload seeds (paper: reuse 75-81%,
//! stable across inputs; throughput ×2.0-2.1 on NVMe, ×3.8-4.0 on eMMC).

use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::config::{KvSwapConfig, StoreConfig};
use kvswap::coordinator::{Engine, Policy};
use kvswap::disk::DiskProfile;
use kvswap::metrics::{latency_summary, Phase, Table};
use kvswap::util::cli::Args;
use kvswap::util::mathx::summarize;
use kvswap::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 8);
    let batch = args.usize_or("batch", 4);
    let n_inputs = args.usize_or("inputs", 4);
    banner(
        "Tab. 5 — reuse ratio and throughput, w/ vs w/o the reuse buffer",
        "several random workloads per cell (paper: 100 inputs, QMSum+MuSiQue)",
    );
    let rt = runtime()?;
    let mut t = Table::new(&[
        "disk", "reuse min", "reuse max", "reuse std", "reuse avg", "tok/s w/", "tok/s w/o", "speedup",
    ]);
    for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
        let group = if disk.name == "emmc" { 8 } else { 4 };
        let mut rates = Vec::new();
        let mut tps_with = Vec::new();
        let mut tps_without = Vec::new();
        for seed in 0..n_inputs {
            let mut kv = KvSwapConfig::default();
            kv.group_size = group;
            kv.n_groups = 256 / group;
            let mut cfg = engine_cfg("nano", batch, Policy::KvSwap, kv.clone(), disk.clone(), context);
            cfg.seed = 1000 + seed as u64;
            let (stats, _) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
            rates.push(stats.reuse_rate.unwrap_or(0.0) * 100.0);
            tps_with.push(stats.tokens_per_sec());

            let mut kv2 = kv.clone();
            kv2.use_reuse = false;
            let mut cfg2 = engine_cfg("nano", batch, Policy::KvSwap, kv2, disk.clone(), context);
            cfg2.seed = 1000 + seed as u64;
            let (stats2, _) = run_throughput(rt.clone(), cfg2, context - 64, 1, steps)?;
            tps_without.push(stats2.tokens_per_sec());
        }
        let r = summarize(&rates);
        let w = summarize(&tps_with);
        let wo = summarize(&tps_without);
        t.row(vec![
            disk.name.to_string(),
            format!("{:.1}", r.min),
            format!("{:.1}", r.max),
            format!("{:.1}", r.std),
            format!("{:.1}", r.mean),
            format!("{:.1}", w.mean),
            format!("{:.1}", wo.mean),
            format!("{:.1}x", w.mean / wo.mean.max(1e-9)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper shape: reuse rates high and input-invariant (std <= 1.1%); \
         speedup larger on the slower disk (2.0-2.1x NVMe, 3.8-4.0x eMMC)"
    );

    // ---- cross-request warm start via the persistent KV store ----
    // Same prompt, three engines sharing one store: the cold run
    // computes and persists every chunk; the blocking warm run restores
    // the stored prefix up front before any compute; the pipelined warm
    // run streams the restore under prefill compute and reports how much
    // of the store's read time the overlap hid (bit-identical all three
    // ways).
    banner(
        "Warm-start prefill — cold vs blocking vs pipelined restore",
        "one prompt, shared in-memory store across engine instances",
    );
    let info = &rt.manifest.presets["nano"].clone();
    let (chunk, pncap, vocab) = (info.prefill_chunk, info.prefill_ncap, info.spec.vocab);
    let s_len = (context.min(pncap) / chunk).max(2) * chunk;
    let mut rng = Rng::new(42);
    let prompt: Vec<i32> = (0..s_len).map(|_| rng.below(vocab) as i32).collect();

    let mut cfg = engine_cfg(
        "nano",
        1,
        Policy::KvSwap,
        KvSwapConfig::default(),
        DiskProfile::nvme(),
        s_len.max(context),
    );
    cfg.store = StoreConfig {
        enabled: true,
        ..Default::default()
    };

    let mut cold = Engine::new(rt.clone(), cfg.clone())?;
    let t0 = std::time::Instant::now();
    let first_cold = cold.prefill(&[prompt.clone()])?;
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut blk_cfg = cfg.clone();
    blk_cfg.store.pipelined_restore = false;
    let mut warm_blk = Engine::with_store(rt.clone(), blk_cfg, cold.store())?;
    let t1 = std::time::Instant::now();
    let first_blk = warm_blk.prefill(&[prompt.clone()])?;
    let blk_ms = t1.elapsed().as_secs_f64() * 1e3;
    let blk_reused = warm_blk.reused_prefix_tokens() as usize;

    let mut warm_pipe = Engine::with_store(rt.clone(), cfg, cold.store())?;
    let t2 = std::time::Instant::now();
    let first_pipe = warm_pipe.prefill(&[prompt.clone()])?;
    let pipe_ms = t2.elapsed().as_secs_f64() * 1e3;
    let pipe_reused = warm_pipe.reused_prefix_tokens() as usize;

    let overlap = |r: Option<f64>| match r {
        Some(v) => format!("{:.0}%", v * 100.0),
        None => "-".into(),
    };
    let mut wt = Table::new(&[
        "mode", "prefill ms", "reused tokens", "prefill overlap", "saved",
    ]);
    wt.row(vec![
        "cold".into(),
        format!("{cold_ms:.1}"),
        "0".into(),
        overlap(cold.prefill_io_overlap_ratio()),
        "-".into(),
    ]);
    wt.row(vec![
        "warm (blocking)".into(),
        format!("{blk_ms:.1}"),
        format!("{blk_reused}/{s_len}"),
        overlap(warm_blk.prefill_io_overlap_ratio()),
        format!("{:.1}%", (1.0 - blk_ms / cold_ms.max(1e-9)) * 100.0),
    ]);
    wt.row(vec![
        "warm (pipelined)".into(),
        format!("{pipe_ms:.1}"),
        format!("{pipe_reused}/{s_len}"),
        overlap(warm_pipe.prefill_io_overlap_ratio()),
        format!("{:.1}%", (1.0 - pipe_ms / cold_ms.max(1e-9)) * 100.0),
    ]);
    println!("{}", wt.render());
    println!(
        "first token identical across modes: {}",
        first_cold == first_blk && first_blk == first_pipe
    );

    // ---- shared scheduler vs separate pools, store active ----
    // Same warm prompt, then a short decode: one row restores and
    // decodes with per-stream pools (restore reads direct, one op per
    // record), the other through the unified scheduler's priority lanes.
    banner(
        "Shared I/O scheduler — one disk service for preload + restore + scrub",
        "store coalescing, prefill overlap, and decode IoWait percentiles",
    );
    // the pipelined warm engine attached its scheduler to the shared
    // store; drop it so the rows below control the store's routing
    drop(warm_pipe);
    drop(warm_blk);
    let store = cold.store().expect("store enabled");
    let sched_steps = args.usize_or("sched-steps", 12);
    let mut st = Table::new(&[
        "pools", "store coalesce", "merges", "prefill overlap", "IoWait p50 ms", "IoWait p99 ms",
    ]);
    for (label, unified) in [("separate", false), ("unified", true)] {
        let mut c = engine_cfg(
            "nano",
            1,
            Policy::KvSwap,
            KvSwapConfig::default(),
            DiskProfile::nvme(),
            s_len.max(context),
        );
        c.store = StoreConfig {
            enabled: true,
            ..Default::default()
        };
        c.prefetch.workers = 1;
        c.prefetch.queue_depth = 8;
        c.prefetch.unified_io = unified;
        let before = store.io_snapshot();
        let mut e = Engine::with_store(rt.clone(), c, Some(store.clone()))?;
        let _ = e.prefill(&[prompt.clone()])?;
        let after = store.io_snapshot();
        let mut waits = Vec::with_capacity(sched_steps);
        for _ in 0..sched_steps {
            let (s, _, _) = e.decode(1, false, None)?;
            waits.push(s.breakdown.per_step_ms(Phase::IoWait));
        }
        let lat = latency_summary(&waits);
        let cin = after.coalesce_extents_in - before.coalesce_extents_in;
        let cout = after.coalesce_runs_out - before.coalesce_runs_out;
        st.row(vec![
            label.into(),
            if cin > 0 {
                format!("{cin}->{cout} ({:.2}x)", cin as f64 / cout.max(1) as f64)
            } else {
                "-".into()
            },
            e.lane_summary().cross_plan_merges.to_string(),
            match e.prefill_io_overlap_ratio() {
                Some(v) => format!("{:.0}%", v * 100.0),
                None => "-".into(),
            },
            format!("{:.3}", lat.p50_ms),
            format!("{:.3}", lat.p99_ms),
        ]);
    }
    println!("{}", st.render());
    println!(
        "paper shape: one scheduler serves decode-critical, warm-restore, and \
         maintenance reads without separate pools inflating device ops"
    );
    Ok(())
}
