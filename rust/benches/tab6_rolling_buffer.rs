//! App. Tab. 3 — rolling-buffer ablation: quality with and without the
//! rolling buffer across group sizes (paper: disabling it drops accuracy
//! ≥29% because freshly generated entries can't join attention until
//! their group completes and is re-selected).

use std::rc::Rc;

use kvswap::bench::{banner, engine_cfg, runtime};
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::evaluate_policy;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 1536);
    let steps = args.usize_or("steps", 24);
    banner(
        "App. Tab. 3 — rolling-buffer ablation across group sizes",
        "fidelity vs Full-KV with the RB exposed vs hidden",
    );
    let rt = runtime()?;
    let mut t = Table::new(&["G", "with RB fid", "no RB fid", "with RB agree", "no RB agree"]);
    for g in [2usize, 4, 8, 16] {
        let mut row = vec![g.to_string()];
        let mut qs = Vec::new();
        for use_rolling in [true, false] {
            let mut kv = KvSwapConfig::default();
            kv.group_size = g;
            kv.n_groups = 256 / g;
            kv.use_rolling = use_rolling;
            let cfg = engine_cfg("nano", 1, Policy::KvSwap, kv, DiskProfile::nvme(), context.max(2048));
            qs.push(evaluate_policy(Rc::clone(&rt), cfg, context, steps, 13)?);
        }
        row.push(format!("{:.3}", qs[0].fidelity));
        row.push(format!("{:.3}", qs[1].fidelity));
        row.push(format!("{:.2}", qs[0].token_agreement));
        row.push(format!("{:.2}", qs[1].token_agreement));
        t.row(row);
    }
    println!("{}", t.render());
    println!(
        "paper shape: with-RB fidelity is stable in G; no-RB collapses, and \
         the gap widens as G grows (longer wait before fresh entries flush)"
    );
    Ok(())
}
