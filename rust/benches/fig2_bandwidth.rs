//! Fig. 2 — normalized effective read bandwidth vs block size for NVMe
//! and eMMC. Measured through the SimDisk substrate (every offloading
//! policy's I/O goes through the same path), not just the closed-form
//! profile: random aligned reads of each block size against the store.

use kvswap::bench::banner;
use kvswap::disk::{DiskProfile, SimDisk};
use kvswap::metrics::Table;
use kvswap::util::rng::Rng;

fn main() {
    banner(
        "Fig. 2 — effective bandwidth vs block size (normalized to peak)",
        "paper: at 512 B (one KV entry) effective bandwidth < 6% of peak",
    );
    let blocks: Vec<u64> = (9..=23).map(|s| 1u64 << s).collect(); // 512B..8MiB
    let mut t = Table::new(&["block", "nvme BW", "nvme norm", "emmc BW", "emmc norm"]);
    for &block in &blocks {
        let mut cells = vec![kvswap::util::fmt_bytes(block)];
        for profile in [DiskProfile::nvme(), DiskProfile::emmc()] {
            let disk = SimDisk::in_memory(profile.clone());
            // populate 64 MiB then random-read `n` blocks
            let span: u64 = 64 << 20;
            disk.write(0, &vec![0u8; span as usize]).unwrap();
            disk.stats().reset();
            let mut rng = Rng::new(7 ^ block);
            let n = 64;
            let mut buf = vec![0u8; block as usize];
            let mut total = std::time::Duration::ZERO;
            for _ in 0..n {
                let slots = span / block;
                let off = (rng.below(slots as usize) as u64) * block;
                total += disk.read(off, &mut buf).unwrap();
            }
            let bw = (n as f64 * block as f64) / total.as_secs_f64();
            cells.push(format!("{}/s", kvswap::util::fmt_bytes(bw as u64)));
            cells.push(format!("{:.3}", bw / profile.read_bw));
        }
        t.row(cells);
    }
    println!("{}", t.render());
    let nvme = DiskProfile::nvme();
    let emmc = DiskProfile::emmc();
    println!(
        "at 512 B: nvme {:.1}% / emmc {:.1}% of peak (paper: < 6% for both)",
        100.0 * nvme.effective_read_bw(512) / nvme.read_bw,
        100.0 * emmc.effective_read_bw(512) / emmc.read_bw
    );
}
