//! Fig. 1 — KV cache memory footprint of Qwen3-4B (W16A16) across batch
//! sizes and context lengths. Pure shape arithmetic: reproduces the
//! paper's absolute numbers (9 GiB at 16K/b4; 54 GiB at 32K/b12).

use kvswap::bench::banner;
use kvswap::config::paper_spec;
use kvswap::metrics::Table;
use kvswap::workload::memory_model::kv_cache_f16_bytes;

fn main() {
    banner(
        "Fig. 1 — KV cache footprint, Qwen3-4B (f16)",
        "rows: batch size; columns: context length; paper: weights alone = 7.5 GiB",
    );
    let spec = paper_spec("qwen3-4b");
    let contexts = [4096usize, 8192, 16384, 32768];
    let mut t = Table::new(&["batch", "4K", "8K", "16K", "32K"]);
    for b in [1usize, 4, 8, 12] {
        let mut row = vec![format!("b={b}")];
        for s in contexts {
            let gib = kv_cache_f16_bytes(&spec, b, s) as f64 / (1u64 << 30) as f64;
            row.push(format!("{gib:.1} GiB"));
        }
        t.row(row);
    }
    println!("{}", t.render());
    let w_gib = spec.n_params() as f64 * 2.0 / (1u64 << 30) as f64;
    println!("model weights (f16): {w_gib:.1} GiB (paper: 7.5 GiB)");
    println!(
        "paper checkpoints: 16K/b4 -> {:.1} GiB (paper ~9), 32K/b12 -> {:.1} GiB (paper ~54)",
        kv_cache_f16_bytes(&spec, 4, 16384) as f64 / (1u64 << 30) as f64,
        kv_cache_f16_bytes(&spec, 12, 32768) as f64 / (1u64 << 30) as f64,
    );
}
