//! Tab. 2 (+ App. Tab. 1) — generation quality vs Full-KV for every
//! offloading method under the relaxed (1/13) and tight (1/34) budgets.
//! Our metrics (DESIGN.md §2): teacher-forced activation fidelity and
//! free-running token agreement vs the Full-KV oracle.

use std::rc::Rc;

use kvswap::baselines::{configure, roster, Budget};
use kvswap::bench::{banner, engine_cfg, runtime};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::evaluate_policy;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 1792);
    let steps = args.usize_or("steps", 8);
    let seeds = args.usize_or("seeds", 1);
    banner(
        "Tab. 2 — quality vs Full-KV (relaxed and tight budgets)",
        "fidelity = teacher-forced activation cosine; agree = token match rate",
    );
    let rt = runtime()?;
    for budget in [Budget::Relaxed, Budget::Tight] {
        let mut t = Table::new(&["method", "nvme fid", "nvme agree", "emmc fid", "emmc agree"]);
        for policy in roster() {
            if matches!(policy, Policy::FlexGen | Policy::FullMemory) {
                continue; // exact by construction (full attention)
            }
            let mut cells = vec![format!("{}{}", policy.name(), budget.suffix())];
            for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
                let group = if disk.name == "emmc" { 8 } else { 4 };
                let (p, kv) = configure(&policy, budget, group);
                let mut fid = 0.0;
                let mut agr = 0.0;
                for s in 0..seeds {
                    let cfg = engine_cfg("nano", 1, p.clone(), kv.clone(), disk.clone(), context.max(2048));
                    let q = evaluate_policy(Rc::clone(&rt), cfg, context, steps, 31 + s as u64)?;
                    fid += q.fidelity;
                    agr += q.token_agreement;
                }
                cells.push(format!("{:.3}", fid / seeds as f64));
                cells.push(format!("{:.2}", agr / seeds as f64));
            }
            t.row(cells);
        }
        println!("--- budget: {:?} ({:.1}% of full cache) ---", budget, budget.fraction() * 100.0);
        println!("{}", t.render());
    }
    println!(
        "paper shape (RULER/LongBench): KVSwap's loss is small at both \
         budgets; InfiniGen worst; Loki/ShadowKV acceptable at relaxed but \
         collapse at tight; eMMC (G=8) slightly worse than NVMe (G=4)"
    );
    Ok(())
}
