//! Fig. 3a — KV-cache *management* memory of prior offloading schemes vs
//! KVSwap on LLaMA3-8B at batch 8 (paper: InfiniGen ~4 GiB and ShadowKV
//! ~2.7 GiB at 16K, far beyond a tight on-device budget).

use kvswap::bench::banner;
use kvswap::config::paper_spec;
use kvswap::metrics::Table;
use kvswap::workload::memory_model::mgmt;

fn main() {
    banner(
        "Fig. 3a — KV management memory, LLaMA3-8B, batch 8 (f16)",
        "paper: at 16K context InfiniGen ~4 GiB, ShadowKV ~2.7 GiB",
    );
    let spec = paper_spec("llama3-8b");
    let b = 8;
    let gib = |x: u64| format!("{:.2} GiB", x as f64 / (1u64 << 30) as f64);
    let mut t = Table::new(&["context", "full-KV", "infinigen", "shadowkv", "kvswap", "kvswap-t"]);
    for s in [4096usize, 8192, 16384, 32768] {
        t.row(vec![
            format!("{}K", s / 1024),
            gib(mgmt::full(&spec, b, s)),
            gib(mgmt::infinigen(&spec, b, s, 0.5)),
            gib(mgmt::shadowkv(&spec, b, s, 160)),
            gib(mgmt::kvswap(&spec, b, s, 8.0, 48, 8, 16, 400)),
            gib(mgmt::kvswap(&spec, b, s, 32.0, 24, 8, 16, 400)),
        ]);
    }
    println!("{}", t.render());
    let s = 32768;
    println!(
        "reduction vs full at 32K: kvswap-t {:.1}x (paper: >30x vs 8x for 2-bit KV)",
        mgmt::full(&spec, b, s) as f64 / mgmt::kvswap(&spec, b, s, 32.0, 24, 8, 16, 400) as f64
    );
}
