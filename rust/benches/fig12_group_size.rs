//! Fig. 12 — the three-way trade-off across KV prediction group sizes:
//! accuracy (fidelity), throughput (without reuse, isolating grouping)
//! and I/O utilization (paper: G↑ ⇒ accuracy drifts down slowly while
//! throughput and I/O utilization climb steeply; G=0/1 are unusable).
//! "G=0" (no head aggregation) maps to the per-head InfiniGen selector.

use std::rc::Rc;

use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::evaluate_policy;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 6);
    let batch = args.usize_or("batch", 8);
    banner(
        "Fig. 12 — group size vs accuracy / throughput / I/O utilization",
        "reuse disabled to isolate the grouping effect (paper does the same)",
    );
    let rt = runtime()?;
    let mut t = Table::new(&["G", "fidelity", "nvme tok/s", "nvme util", "emmc tok/s", "emmc util"]);

    let mut run_for = |label: String, policy: Policy, kv: KvSwapConfig| -> anyhow::Result<()> {
        let mut cells = vec![label];
        let qcfg = engine_cfg("nano", 1, policy.clone(), kv.clone(), DiskProfile::nvme(), 2048);
        let q = evaluate_policy(Rc::clone(&rt), qcfg, 1792, 4, 9)?;
        cells.push(format!("{:.3}", q.fidelity));
        for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
            let cfg = engine_cfg("nano", batch, policy.clone(), kv.clone(), disk, context);
            let (stats, _) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
            cells.push(format!("{:.1}", stats.tokens_per_sec()));
            cells.push(format!("{:.2}", stats.io_utilization));
        }
        t.row(cells);
        Ok(())
    };

    // G = 0: no grouping, no head aggregation (per-head InfiniGen)
    let mut kv0 = KvSwapConfig::default();
    kv0.use_reuse = false;
    run_for(
        "0".into(),
        Policy::InfiniGen {
            head_agg: false,
            reuse: false,
        },
        kv0,
    )?;
    for g in [1usize, 2, 4, 8, 16] {
        let mut kv = KvSwapConfig::default();
        kv.group_size = g;
        kv.n_groups = 256 / g;
        kv.use_reuse = false;
        run_for(g.to_string(), Policy::KvSwap, kv)?;
    }
    println!("{}", t.render());
    println!(
        "paper shape: accuracy decays gently with G (88.8% -> 83.3%); \
         throughput rises sharply (NVMe 1.8 -> 19.1, eMMC 0.1 -> 4.2 tok/s \
         w/o reuse); I/O utilization rises with G"
    );
    Ok(())
}
