//! Fig. 11 — setting B: fixed *total* memory budget; every method runs
//! its best-case configuration at the largest batch it can fit, and we
//! report throughput + quality (paper: KVSwap trades ≤2.4% accuracy for
//! 3.3–8.6× ShadowKV throughput and ~1.1× vLLM with 15.9–39.7× less
//! memory).

use std::rc::Rc;

use kvswap::baselines::{configure, Budget};
use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::evaluate_policy;
use kvswap::util::cli::Args;

/// Per-batch-row management bytes of a method's best-case config.
fn per_row_bytes(policy: &Policy, kv: &KvSwapConfig, spec: &kvswap::config::ModelSpec, ctx: usize) -> u64 {
    match policy {
        Policy::FullMemory => spec.kv_cache_bytes(1, ctx),
        Policy::ShadowKv { rank, .. } => {
            // in-memory K_lr at its conservative rank + reuse-ish staging
            (ctx * rank * 4) as u64 * spec.n_layers as u64 * 2
        }
        Policy::InfiniGen { .. } => {
            // partial-weight ratio 0.5 -> half the K cache resident
            spec.kv_cache_bytes(1, ctx) / 4
        }
        _ => kv.management_bytes_per_seq(spec, ctx),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 6);
    // our scaled totals standing in for the paper's 2000/800 MiB
    let totals_mib = [16.0f64, 6.0];
    banner(
        "Fig. 11 — best-case configs under a fixed TOTAL memory budget",
        "each method runs the largest batch its per-row memory allows",
    );
    let rt = runtime()?;
    let spec = rt.manifest.presets["nano"].spec.clone();
    let batches = rt.manifest.presets["nano"].batches.clone();

    for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
        for &total in &totals_mib {
            let budget = (total * 1024.0 * 1024.0) as u64;
            let mut t = Table::new(&["method", "b", "mem/row", "tok/s", "fidelity"]);
            let roster: Vec<Policy> = vec![
                Policy::Loki,
                Policy::ShadowKv { chunk: 8, rank: 32 },
                Policy::KvSwap,
                Policy::FullMemory,
            ];
            for policy in roster {
                let group = if disk.name == "emmc" { 8 } else { 4 };
                let (p, kv) = configure(&policy, Budget::Relaxed, group);
                let row_bytes = per_row_bytes(&p, &kv, &spec, context).max(1);
                let max_b = *batches
                    .iter()
                    .filter(|&&b| b as u64 * row_bytes <= budget && b <= 8)
                    .max()
                    .unwrap_or(&1);
                let cfg = engine_cfg("nano", max_b, p.clone(), kv.clone(), disk.clone(), context);
                let (stats, _) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
                // quality at b=1 (budget-independent fidelity estimate)
                let qcfg = engine_cfg("nano", 1, p.clone(), kv, disk.clone(), context);
                let q = evaluate_policy(Rc::clone(&rt), qcfg, 512, 4, 3)?;
                t.row(vec![
                    p.name(),
                    max_b.to_string(),
                    kvswap::util::fmt_bytes(row_bytes),
                    format!("{:.1}", stats.tokens_per_sec()),
                    format!("{:.3}", q.fidelity),
                ]);
            }
            println!("--- disk {} | total budget {:.0} MiB ---", disk.name, total);
            println!("{}", t.render());
        }
    }
    println!(
        "paper shape: vLLM/ShadowKV/Loki top accuracy but need large memory \
         or deliver low throughput; KVSwap wins throughput+memory with \
         marginal quality loss"
    );
    Ok(())
}
