//! Fig. 9 — Needle-in-a-haystack heatmap under the tight budget:
//! context length (x) × needle depth (y) retrieval scores for KVSwap-t
//! vs Loki-t and ShadowKV-t (paper: only KVSwap-t retains capability at
//! all positions).

use std::rc::Rc;

use kvswap::baselines::{configure, Budget};
use kvswap::bench::{banner, engine_cfg, runtime};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::niah_cell;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let contexts = args.usize_list_or("contexts", &[512, 1024]);
    let n_depths = args.usize_or("depths", 3);
    let strength = args.f64_or("strength", 10.0) as f32;
    banner(
        "Fig. 9 — NIAH heatmap (tight budget, NVMe)",
        "cells: retrieval score (1.0 = oracle); rows: depth fraction; cols: context",
    );
    let rt = runtime()?;
    let methods: Vec<(&str, Policy)> = vec![
        ("kvswap-t", Policy::KvSwap),
        ("loki-t", Policy::Loki),
        ("shadowkv-t", Policy::ShadowKv { chunk: 8, rank: 32 }),
    ];
    for (name, policy) in methods {
        let mut t = Table::new(
            &std::iter::once("depth\\ctx".to_string())
                .chain(contexts.iter().map(|c| format!("{c}")))
                .map(|s| Box::leak(s.into_boxed_str()) as &str)
                .collect::<Vec<&str>>(),
        );
        let mut total = 0.0;
        let mut n = 0;
        for di in 0..n_depths {
            let frac = di as f64 / (n_depths - 1).max(1) as f64;
            let mut row = vec![format!("{:.0}%", frac * 100.0)];
            for &context in &contexts {
                let (p, kv) = configure(&policy, Budget::Tight, 4);
                let cfg = engine_cfg("nano", 1, p, kv, DiskProfile::nvme(), context.max(2048));
                let score = niah_cell(Rc::clone(&rt), cfg, context, frac, 23, strength)?;
                row.push(format!("{score:.2}"));
                total += score;
                n += 1;
            }
            t.row(row);
        }
        println!("--- {name} (mean {:.3}) ---", total / n as f64);
        println!("{}", t.render());
    }
    println!(
        "paper shape: the KVSwap-t grid stays bright everywhere; Loki-t and \
         ShadowKV-t develop dark regions (lost needles) under the same budget"
    );
    Ok(())
}
