//! Tab. 3 — quality across model scales (the paper's reasoning and video
//! LMs; our small/med presets stand in, DESIGN.md §2): Loki / ShadowKV /
//! KVSwap at both budgets, with KVSwap-t the only usable tight method.

use std::rc::Rc;

use kvswap::baselines::{configure, Budget};
use kvswap::bench::{banner, engine_cfg, runtime};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality::evaluate_policy;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 1792);
    let steps = args.usize_or("steps", 6);
    banner(
        "Tab. 3 — quality across model scales (fidelity vs Full-KV)",
        "presets: nano(~'4B') small(~'8B') med(~'14B'); NVMe, G=4",
    );
    let rt = runtime()?;
    let roster: Vec<Policy> = vec![
        Policy::Loki,
        Policy::ShadowKv { chunk: 8, rank: 32 },
        Policy::KvSwap,
    ];
    for budget in [Budget::Relaxed, Budget::Tight] {
        let mut t = Table::new(&["method", "nano", "small", "med"]);
        for policy in &roster {
            let mut cells = vec![format!("{}{}", policy.name(), budget.suffix())];
            for preset in ["nano", "small", "med"] {
                if !rt.manifest.presets[preset].batches.contains(&1) {
                    cells.push("-".into());
                    continue;
                }
                let (p, kv) = configure(policy, budget, 4);
                let cfg = engine_cfg(preset, 1, p, kv, DiskProfile::nvme(), context.max(2048));
                let q = evaluate_policy(Rc::clone(&rt), cfg, context, steps, 17)?;
                cells.push(format!("{:.3}", q.fidelity));
            }
            t.row(cells);
        }
        println!("--- budget {:?} ---", budget);
        println!("{}", t.render());
    }
    println!(
        "paper shape: KVSwap best at every scale; at the tight budget only \
         KVSwap-t stays usable (others lose >=45% accuracy); its advantage \
         grows with model size"
    );
    Ok(())
}
