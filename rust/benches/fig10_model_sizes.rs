//! Fig. 10 — throughput across model sizes at long context, b ∈ {1, 8}:
//! KVSwap vs ShadowKV vs vLLM-like on both disks (paper: KVSwap ≥1.8×
//! ShadowKV on eMMC at b=1, ≥2.9× at b=8; beats vLLM on larger models).
//! Size mapping (DESIGN.md §2): nano→"3B", small→"8B", med→"14B".

use kvswap::baselines::{configure, Budget};
use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let steps = args.usize_or("steps", 6);
    let context = args.usize_or("context", 2048);
    banner(
        "Fig. 10 — throughput (tok/s) across model sizes",
        "presets nano/small/med stand in for the paper's 3B/8B/14B",
    );
    let rt = runtime()?;
    let presets = ["nano", "small", "med"];
    for batch in [1usize, 8] {
        let mut t = Table::new(&[
            "preset",
            "kvswap nvme",
            "shadowkv nvme",
            "kvswap emmc",
            "shadowkv emmc",
            "vllm-like",
        ]);
        for preset in presets {
            if !rt.manifest.presets[preset].batches.contains(&batch) {
                continue;
            }
            let mut cells = vec![preset.to_string()];
            for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
                let group = if disk.name == "emmc" { 8 } else { 4 };
                for policy in [Policy::KvSwap, Policy::ShadowKv { chunk: 8, rank: 32 }] {
                    let (p, kv) = configure(&policy, Budget::Relaxed, group);
                    let cfg = engine_cfg(preset, batch, p, kv, disk.clone(), context);
                    let (stats, _) =
                        run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
                    cells.push(format!("{:.1}", stats.tokens_per_sec()));
                }
            }
            let (p, kv) = configure(&Policy::FullMemory, Budget::Relaxed, 4);
            let cfg = engine_cfg(preset, batch, p, kv, DiskProfile::nvme(), context);
            let (stats, _) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
            cells.push(format!("{:.1}", stats.tokens_per_sec()));
            t.row(cells);
        }
        println!("--- batch {batch} ---");
        println!("{}", t.render());
    }
    println!(
        "paper shape: KVSwap > ShadowKV on both disks (gap widest on eMMC \
         and at b=8); KVSwap approaches/exceeds vLLM as the model grows"
    );
    Ok(())
}
