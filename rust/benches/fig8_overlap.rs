//! Fig. 8 — frequency and overlap ratio of predicted critical KV groups
//! over a long decode (paper: 300 steps; <22% of groups account for 80%
//! of selections; adjacent steps overlap strongly).
//!
//! Part 2 measures *I/O* overlap on a real file-backed disk: the same
//! decode with the synchronous read path vs the threaded prefetcher,
//! reporting how much device read time each hides behind compute.

use kvswap::bench::{banner, engine_cfg, runtime};
use kvswap::config::{KvSwapConfig, PrefetchConfig, StoreConfig};
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::{DiskProfile, StorageBackend};
use kvswap::metrics::{Phase, Table};
use kvswap::util::cli::Args;
use kvswap::util::mathx::summarize;
use kvswap::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let steps = args.usize_or("steps", 120);
    let context = args.usize_or("context", 1024);
    banner(
        "Fig. 8 — frequency and overlap of predicted critical groups",
        "paper: <22% of groups carry 80% of selections; strong adjacent-step overlap",
    );
    let rt = runtime()?;
    let cfg = engine_cfg(
        "nano",
        1,
        Policy::KvSwap,
        KvSwapConfig::default(),
        DiskProfile::nvme(),
        context + steps + 64,
    );
    let mut e = Engine::new(rt, cfg)?;
    e.ingest_synthetic(&[context])?;
    let (_, _, _) = e.decode(steps, false, None)?;

    let mut t = Table::new(&["layer", "mean OLR", "std", "min", "80%-mass group frac"]);
    for layer in [1usize, 2, 3] {
        let tr = &e.overlap[0][layer];
        let s = summarize(&tr.ratios);
        t.row(vec![
            layer.to_string(),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.std),
            format!("{:.2}", s.min),
            format!("{:.1}%", tr.head_mass_fraction(0.8) * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper shape: overlap ratio high and stable across steps; a small \
         fraction of distinct groups dominates the selection histogram"
    );

    // ---- Part 2: I/O overlap, sync vs threaded prefetch (real file) ----
    banner(
        "Fig. 8b — I/O overlap on a real FileBackend",
        "overlap = fraction of device read time hidden behind compute",
    );
    let io_steps = args.usize_or("io-steps", 8);
    let io_context = args.usize_or("io-context", 512);
    let rt2 = runtime()?;
    let path = std::env::temp_dir().join(format!("kvswap_fig8_{}.kv", std::process::id()));
    let run = |prefetch: PrefetchConfig| -> anyhow::Result<(f64, f64)> {
        let cfg = EngineConfig::builder()
            .preset("nano")
            .batch(1)
            .policy(Policy::KvSwap)
            .kv(KvSwapConfig::default())
            .disk(DiskProfile::nvme())
            .storage(StorageBackend::File(path.clone()))
            .prefetch(prefetch)
            .real_time(true)
            .time_scale(1.0)
            .max_context(io_context.max(512) + io_steps + 64)
            .build()?;
        let mut e = Engine::new(rt2.clone(), cfg)?;
        e.ingest_synthetic(&[io_context])?;
        let (stats, _, _) = e.decode(io_steps, false, None)?;
        Ok((
            e.io_overlap_ratio(),
            stats.breakdown.per_step_ms(Phase::IoWait),
        ))
    };
    let (sync_ratio, sync_wait) = run(PrefetchConfig::synchronous())?;
    let (pf_ratio, pf_wait) = run(PrefetchConfig::default())?;
    let _ = std::fs::remove_file(&path);
    let mut t2 = Table::new(&["pipeline", "io overlap", "io_wait ms/step"]);
    t2.row(vec![
        "synchronous".into(),
        format!("{sync_ratio:.3}"),
        format!("{sync_wait:.3}"),
    ]);
    t2.row(vec![
        "prefetch".into(),
        format!("{pf_ratio:.3}"),
        format!("{pf_wait:.3}"),
    ]);
    println!("{}", t2.render());
    anyhow::ensure!(
        pf_ratio > sync_ratio,
        "prefetch overlap {pf_ratio:.3} not above synchronous {sync_ratio:.3}"
    );
    println!(
        "threaded prefetch hides {:.0}% of device read time (sync baseline {:.0}%)",
        pf_ratio * 100.0,
        sync_ratio * 100.0
    );

    // ---- Part 3: unified I/O scheduler under an active warm restore ----
    // One prompt persisted cold, then restored twice through the
    // pipelined warm-start path: once with separate pools (restore reads
    // hit the store device directly, one op per record) and once through
    // the shared scheduler's Warm lane, where the submit-ahead window
    // lets queued chunk plans merge into sequential runs.
    banner(
        "Fig. 8c — warm restore through the unified scheduler",
        "separate pools vs shared Warm lane; fewer, larger store reads",
    );
    let rt3 = runtime()?;
    let info = rt3.manifest.presets["nano"].clone();
    let (chunk, pncap, vocab) = (info.prefill_chunk, info.prefill_ncap, info.spec.vocab);
    let warm_len = (io_context.max(512).min(pncap) / chunk).max(2) * chunk;
    let mut rng = Rng::new(7);
    let prompt: Vec<i32> = (0..warm_len).map(|_| rng.below(vocab) as i32).collect();
    let mut base = engine_cfg(
        "nano",
        1,
        Policy::KvSwap,
        KvSwapConfig::default(),
        DiskProfile::nvme(),
        warm_len.max(512),
    );
    base.store = StoreConfig {
        enabled: true,
        ..Default::default()
    };
    // one worker + a deep queue: the Warm lane fills ahead of the
    // dispatcher, maximizing the cross-plan window it can coalesce over
    base.prefetch.workers = 1;
    base.prefetch.queue_depth = 8;

    let mut cold = Engine::new(rt3.clone(), base.clone())?;
    let _ = cold.prefill(&[prompt.clone()])?;
    let store = cold.store().expect("store enabled");

    // (mode label, unified?) — separate first so its run cannot see a
    // scheduler attached by the unified engine
    let mut rows = Vec::new();
    for (label, unified) in [("separate pools", false), ("unified sched", true)] {
        let mut cfg = base.clone();
        cfg.prefetch.unified_io = unified;
        let before = store.io_snapshot();
        let mut warm = Engine::with_store(rt3.clone(), cfg, Some(store.clone()))?;
        let _ = warm.prefill(&[prompt.clone()])?;
        let after = store.io_snapshot();
        let lanes = warm.lane_summary();
        rows.push((
            label,
            after.read_ops - before.read_ops,
            after.coalesce_extents_in - before.coalesce_extents_in,
            after.coalesce_runs_out - before.coalesce_runs_out,
            lanes.cross_plan_merges,
            warm.reused_prefix_tokens(),
        ));
    }
    let mut t3 = Table::new(&[
        "mode", "store read ops", "coalesce in->out", "cross-plan merges", "reused tokens",
    ]);
    for &(label, ops, cin, cout, merges, reused) in &rows {
        t3.row(vec![
            label.into(),
            ops.to_string(),
            if cin > 0 {
                format!("{cin}->{cout} ({:.2}x)", cin as f64 / cout.max(1) as f64)
            } else {
                "-".into()
            },
            merges.to_string(),
            format!("{reused}/{warm_len}"),
        ]);
    }
    println!("{}", t3.render());
    let (sep_ops, uni_ops) = (rows[0].1, rows[1].1);
    let uni_merges = rows[1].4;
    anyhow::ensure!(
        rows[0].5 > 0 && rows[0].5 == rows[1].5,
        "warm restores disagree on reused tokens ({} separate vs {} unified)",
        rows[0].5,
        rows[1].5
    );
    anyhow::ensure!(
        uni_merges > 0,
        "unified scheduler merged no cross-plan reads under an active warm restore"
    );
    anyhow::ensure!(
        uni_ops <= sep_ops,
        "unified scheduler issued more store reads ({uni_ops}) than separate pools ({sep_ops})"
    );
    println!(
        "unified Warm lane served the same records in {uni_ops} device reads \
         vs {sep_ops} separate-pool reads ({uni_merges} cross-plan merges)"
    );
    Ok(())
}
