//! Fig. 8 — frequency and overlap ratio of predicted critical KV groups
//! over a long decode (paper: 300 steps; <22% of groups account for 80%
//! of selections; adjacent steps overlap strongly).

use kvswap::bench::{banner, engine_cfg, runtime};
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::{Engine, Policy};
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::util::cli::Args;
use kvswap::util::mathx::summarize;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let steps = args.usize_or("steps", 120);
    let context = args.usize_or("context", 1024);
    banner(
        "Fig. 8 — frequency and overlap of predicted critical groups",
        "paper: <22% of groups carry 80% of selections; strong adjacent-step overlap",
    );
    let rt = runtime()?;
    let cfg = engine_cfg(
        "nano",
        1,
        Policy::KvSwap,
        KvSwapConfig::default(),
        DiskProfile::nvme(),
        context + steps + 64,
    );
    let mut e = Engine::new(rt, cfg)?;
    e.ingest_synthetic(&[context])?;
    let (_, _, _) = e.decode(steps, false, None)?;

    let mut t = Table::new(&["layer", "mean OLR", "std", "min", "80%-mass group frac"]);
    for layer in [1usize, 2, 3] {
        let tr = &e.overlap[0][layer];
        let s = summarize(&tr.ratios);
        t.row(vec![
            layer.to_string(),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.std),
            format!("{:.2}", s.min),
            format!("{:.1}%", tr.head_mass_fraction(0.8) * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper shape: overlap ratio high and stable across steps; a small \
         fraction of distinct groups dominates the selection histogram"
    );
    Ok(())
}
