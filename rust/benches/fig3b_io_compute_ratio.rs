//! Fig. 3b — decoding-latency ratio of I/O to compute for FlexGen,
//! InfiniGen and ShadowKV at long context, batch 8 (paper: all ≫ 1, up
//! to >100; ShadowKV still 13.0 on eMMC / 2.3 on NVMe). Measured on the
//! live engine: modeled disk time vs measured PJRT compute.

use kvswap::baselines::{configure, Budget};
use kvswap::bench::{banner, engine_cfg, run_throughput, runtime};
use kvswap::coordinator::Policy;
use kvswap::disk::DiskProfile;
use kvswap::metrics::{Phase, Table};
use kvswap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let context = args.usize_or("context", 2048);
    let steps = args.usize_or("steps", 6);
    banner(
        "Fig. 3b — I/O : compute latency ratio (batch 8)",
        "raw I/O demand: ratios use unoverlapped modeled I/O time, like the paper's breakdown",
    );
    let rt = runtime()?;
    let roster: Vec<Policy> = vec![
        Policy::FlexGen,
        Policy::InfiniGen {
            head_agg: true,
            reuse: false,
        },
        Policy::ShadowKv { chunk: 8, rank: 32 },
        Policy::KvSwap,
    ];
    let mut t = Table::new(&["method", "nvme io:compute", "emmc io:compute"]);
    for policy in roster {
        let mut cells = vec![policy.name()];
        for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
            let group = if disk.name == "emmc" { 8 } else { 4 };
            let (p, kv) = configure(&policy, Budget::Relaxed, group);
            let cfg = engine_cfg("nano", 8, p, kv, disk.clone(), context);
            let (stats, engine) = run_throughput(rt.clone(), cfg, context - 64, 1, steps)?;
            // raw I/O demand = modeled busy time of the disk (before
            // pipeline overlap), compute = attention + predict + embed +
            // logits measured
            let snap = engine.disk.stats().snapshot();
            let io = snap.read_busy.as_secs_f64();
            let compute = (stats.breakdown.get(Phase::Attention)
                + stats.breakdown.get(Phase::Predict)
                + stats.breakdown.get(Phase::Embed)
                + stats.breakdown.get(Phase::Logits))
            .as_secs_f64();
            cells.push(format!("{:.1}", io / compute.max(1e-9)));
        }
        t.row(cells);
    }
    println!("{}", t.render());
    println!(
        "paper shape: FlexGen/InfiniGen far above 1 (some >100); ShadowKV \
         lowest of the baselines but still 2.3 (NVMe) / 13.0 (eMMC); \
         KVSwap designed to drive this toward <= 1"
    );
    Ok(())
}
