#!/usr/bin/env bash
# Repo CI gate: formatting, lints, and the full test suite.
# Integration tests that need the AOT artifacts self-skip when
# `make artifacts` has not been run.
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo test -q
