#!/usr/bin/env bash
# Repo CI gate: formatting, lints, and the full test suite.
# Integration tests that need the AOT artifacts self-skip when
# `make artifacts` has not been run.
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo test -q

# fault-matrix smoke: the CLI decode path under a 5% flaky disk (seeded,
# reproducible) must complete and recover, not crash (needs artifacts)
ARTIFACTS="${KVSWAP_ARTIFACTS:-artifacts}"
if [ -f "$ARTIFACTS/manifest.json" ]; then
  cargo run --release -q -- run --policy kvswap --context 512 --steps 8 \
    --fault-rate 0.05 --fault-corrupt-rate 0.02 --fault-seed 7 --io-retries 5
fi
