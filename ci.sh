#!/usr/bin/env bash
# Repo CI gate: formatting, lints, and the full test suite.
# Integration tests that need the AOT artifacts self-skip when
# `make artifacts` has not been run.
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo test -q

# fault matrix: the CLI decode path under a seeded flaky disk must
# complete and recover at every (rate, seed) point, not crash
# (needs artifacts)
ARTIFACTS="${KVSWAP_ARTIFACTS:-artifacts}"
if [ -f "$ARTIFACTS/manifest.json" ]; then
  for rate in 0.01 0.05 0.20; do
    for seed in 7 11; do
      cargo run --release -q -- run --policy kvswap --context 512 --steps 8 \
        --fault-rate "$rate" --fault-corrupt-rate 0.02 --fault-seed "$seed" \
        --io-retries 5
    done
  done
  # persistent-fault run with the KV store enabled: deterministic device
  # corruption must drive the scrub path to quarantine poisoned entries
  # (store eviction), not wedge the run
  cargo run --release -q -- run --policy kvswap --context 512 --steps 8 \
    --fault-rate 0.05 --fault-corrupt-rate 0.05 --fault-seed 7 --io-retries 5 \
    --fault-persistent --store-mem --store-capacity 64
fi
