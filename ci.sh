#!/usr/bin/env bash
# Repo CI gate: formatting, lints, and the full test suite.
# Integration tests that need the AOT artifacts self-skip when
# `make artifacts` has not been run.
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo test -q

# fault matrix: the CLI decode path under a seeded flaky disk must
# complete and recover at every (rate, seed) point, not crash
# (needs artifacts)
ARTIFACTS="${KVSWAP_ARTIFACTS:-artifacts}"
if [ -f "$ARTIFACTS/manifest.json" ]; then
  for rate in 0.01 0.05 0.20; do
    for seed in 7 11; do
      cargo run --release -q -- run --policy kvswap --context 512 --steps 8 \
        --fault-rate "$rate" --fault-corrupt-rate 0.02 --fault-seed "$seed" \
        --io-retries 5
    done
  done
  # persistent-fault run with the KV store enabled: deterministic device
  # corruption must drive the scrub path to quarantine poisoned entries
  # (store eviction), not wedge the run
  cargo run --release -q -- run --policy kvswap --context 512 --steps 8 \
    --fault-rate 0.05 --fault-corrupt-rate 0.05 --fault-seed 7 --io-retries 5 \
    --fault-persistent --store-mem --store-capacity 64

  # unified-scheduler smoke: the fig8 bench's Part 3 restores a warm
  # prompt through the shared Warm lane and asserts cross_plan_merges > 0
  # and device read ops <= the separate-pool baseline; a run with
  # --separate-io must still work (store reads revert to direct)
  cargo bench --bench fig8_overlap -- --steps 40 --io-steps 4
  cargo run --release -q -- run --policy kvswap --context 512 --steps 8 \
    --separate-io --store-mem --store-capacity 64

  # serve-mode fault smoke: a session with mid-stream faults and one
  # doomed (oversized) request must keep emitting completions — the
  # failed wave gets an "error" completion, the flanking requests real
  # tokens, and the stats line stays consistent (wave_errors counted,
  # store counters present)
  PORT=$((20000 + RANDOM % 20000))
  cargo run --release -q -- serve --addr 127.0.0.1:"$PORT" --policy kvswap \
    --max-context 1024 --batch-max-context 1048576 --max-conns 2 \
    --fault-rate 0.02 --fault-seed 7 --io-retries 5 --store-mem &
  SERVE_PID=$!
  for _ in $(seq 1 50); do
    if exec 3<>/dev/tcp/127.0.0.1/"$PORT" 2>/dev/null; then break; fi
    sleep 0.2
  done
  {
    echo '{"id": 1, "context": 128, "decode": 2}'
    echo 'flush'
    echo '{"id": 2, "context": 1048576, "decode": 2}'
    echo 'flush'
    echo '{"id": 3, "context": 128, "decode": 2}'
    echo 'quit'
  } >&3
  CONN1=$(cat <&3)
  exec 3>&-
  echo "$CONN1"
  echo "$CONN1" | grep -q '"id":1,"tokens":\[[0-9-]' \
    || { echo "FAIL: request 1 got no tokens"; kill $SERVE_PID; exit 1; }
  echo "$CONN1" | grep -q '"id":2,.*"error"' \
    || { echo "FAIL: oversized request 2 lacks an error completion"; kill $SERVE_PID; exit 1; }
  echo "$CONN1" | grep -q '"id":3,"tokens":\[[0-9-]' \
    || { echo "FAIL: request 3 got no tokens after the failed wave"; kill $SERVE_PID; exit 1; }
  exec 4<>/dev/tcp/127.0.0.1/"$PORT"
  printf 'stats\nquit\n' >&4
  STATS=$(cat <&4)
  exec 4>&-
  echo "$STATS"
  echo "$STATS" | grep -q '"wave_errors":1' \
    || { echo "FAIL: failed wave not counted in stats"; kill $SERVE_PID; exit 1; }
  echo "$STATS" | grep -q '"store"' \
    || { echo "FAIL: stats lost the store counters"; kill $SERVE_PID; exit 1; }
  wait $SERVE_PID
fi
