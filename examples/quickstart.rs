//! Quickstart: serve one batch of long-context requests with KVSwap on a
//! simulated NVMe disk and print throughput + the per-phase breakdown.
//!
//!     cargo run --release --example quickstart -- [--disk emmc] [--batch 4]
//!
//! Everything runs through the AOT artifacts (`make artifacts` first):
//! the prompt is prefilled through the Pallas prefill kernel, the KV
//! cache is written to the simulated disk, and decode runs the full
//! grouped-prediction / reuse-buffer / overlapped-I/O pipeline.

use std::rc::Rc;

use kvswap::config::KvSwapConfig;
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::DiskProfile;
use kvswap::runtime::{default_artifacts_dir, Manifest, PjrtRuntime};
use kvswap::util::cli::Args;
use kvswap::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let disk = DiskProfile::by_name(&args.str_or("disk", "nvme")).expect("disk");
    let batch = args.usize_or("batch", 2);
    let context = args.usize_or("context", 1024);
    let steps = args.usize_or("steps", 32);

    let rt = Rc::new(PjrtRuntime::new(Manifest::load(default_artifacts_dir())?)?);
    let cfg = EngineConfig::builder()
        .preset("nano")
        .batch(batch)
        .policy(Policy::KvSwap)
        .kv(KvSwapConfig::default())
        .disk(disk.clone())
        .max_context(context.max(2048))
        .seed(1)
        .build()?;
    println!(
        "kvswap quickstart: preset=nano batch={batch} context={context} disk={}",
        disk.name
    );

    let mut engine = Engine::new(rt, cfg)?;

    // real prompts -> real prefill through the artifacts
    let vocab = engine.spec().vocab;
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|i| {
            let mut rng = Rng::new(42 + i as u64);
            (0..context).map(|_| rng.below(vocab) as i32).collect()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let first = engine.prefill(&prompts)?;
    println!(
        "prefill: {} tokens x {} seqs in {:.2}s; first tokens {:?}",
        context,
        batch,
        t0.elapsed().as_secs_f64(),
        first
    );

    let (stats, _, tokens) = engine.decode(steps, false, None)?;
    println!(
        "\ndecode: {:.2} tokens/s  ({} tokens, {:.2}s virtual incl. modeled {} I/O)",
        stats.tokens_per_sec(),
        stats.tokens,
        stats.seconds,
        disk.name
    );
    println!("bytes loaded from disk: {}", kvswap::util::fmt_bytes(stats.bytes_loaded));
    println!("reuse rate: {:.1}%", stats.reuse_rate.unwrap_or(0.0) * 100.0);
    println!("selection overlap: {:.1}%", stats.mean_overlap * 100.0);
    println!(
        "KV management memory: {} (full cache would be {})",
        kvswap::util::fmt_bytes(engine.management_bytes()),
        kvswap::util::fmt_bytes(engine.spec().kv_cache_bytes(batch, context))
    );
    println!("\nper-phase latency:\n{}", stats.breakdown.report());
    let sample: Vec<i32> = tokens.iter().map(|step| step[0]).take(16).collect();
    println!("sample generated tokens (seq 0): {sample:?}");
    Ok(())
}
