//! Offline parameter tuning end-to-end (paper §3.5 / Appendix A): build
//! the lookup tables, profile live engine points, run the greedy solver
//! for NVMe and eMMC, then *validate* the chosen configs by running them
//! and checking the solver's overlap prediction against measurement.
//!
//!     cargo run --release --example tune_offline

use kvswap::bench;
use kvswap::config::KvSwapConfig;
use kvswap::coordinator::{Engine, EngineConfig, Policy};
use kvswap::disk::DiskProfile;
use kvswap::metrics::{Phase, Table};
use kvswap::tuner::{self, DelayModel, ProfileSample, SolverConfig};

fn main() -> anyhow::Result<()> {
    let rt = bench::runtime()?;
    let spec = rt.manifest.presets["nano"].spec.clone();
    let table = tuner::tables::ReuseTable::from_locality_model(
        64,
        0.77,
        &[0, 16, 32, 64, 128, 256, 512],
    );

    let mut results = Table::new(&[
        "disk", "G", "rank", "C", "pred_unhidden", "meas_tok/s", "meas_io_wait_ms",
    ]);
    for disk in [DiskProfile::nvme(), DiskProfile::emmc()] {
        // 1. profile the live engine at a few (b, S) points
        let mut delays = DelayModel::default();
        for (b, s) in [(1usize, 2048usize), (4, 2048)] {
            let mut e = Engine::new(
                rt.clone(),
                EngineConfig::builder()
                    .preset("nano")
                    .batch(b)
                    .policy(Policy::KvSwap)
                    .kv(KvSwapConfig::default())
                    .disk(disk.clone())
                    .max_context(s)
                    .build()?,
            )?;
            e.ingest_synthetic(&vec![s - 64; b])?;
            let (stats, _, _) = e.decode(6, false, None)?;
            let per = stats.steps as f64 * spec.n_layers as f64;
            delays.add(ProfileSample {
                batch: b,
                context: s,
                group: 4,
                rank: 16,
                reuse_slots: KvSwapConfig::default().reuse_slots,
                t_io: stats.breakdown.get(Phase::IoWait).as_secs_f64() / per,
                t_compute: (stats.breakdown.get(Phase::Attention)
                    + stats.breakdown.get(Phase::Predict))
                .as_secs_f64()
                    / per,
            });
            println!("[profile] disk={} b={b} S={s} done", disk.name);
        }

        // 2. solve under a 2 MiB/row budget
        let solver_cfg = SolverConfig {
            budget_bytes: 2 << 20,
            s_max: 2048,
            b_max: 4,
            ..Default::default()
        };
        let sol = tuner::solver::solve_point(
            &spec, &disk, &table, &delays, &solver_cfg, 4, 2048,
        );
        println!(
            "[solve] disk={}: G={} rank={} C={} unhidden={:.2} feasible={}",
            disk.name, sol.group, sol.rank, sol.reuse_slots, sol.unhidden_io, sol.feasible
        );

        // 3. validate: run the tuned config and measure
        let kv = sol.to_kvswap_config(&KvSwapConfig::default());
        let mut e = Engine::new(
            rt.clone(),
            EngineConfig::builder()
                .preset("nano")
                .batch(4)
                .policy(Policy::KvSwap)
                .kv(kv)
                .disk(disk.clone())
                .max_context(2048)
                .build()?,
        )?;
        e.ingest_synthetic(&vec![2048 - 64; 4])?;
        let (stats, _, _) = e.decode(10, false, None)?;
        results.row(vec![
            disk.name.to_string(),
            sol.group.to_string(),
            sol.rank.to_string(),
            sol.reuse_slots.to_string(),
            format!("{:.2}", sol.unhidden_io),
            format!("{:.1}", stats.tokens_per_sec()),
            format!("{:.1}", stats.breakdown.per_step_ms(Phase::IoWait)),
        ]);
    }
    println!("\n=== tuned configurations, validated ===");
    println!("{}", results.render());
    Ok(())
}
