//! Needle-in-a-haystack quality driver: plants needles in KV space and
//! compares KVSwap against budget-matched baselines and the Full-KV
//! oracle (paper Fig. 9 mechanism, see DESIGN.md §2 for the
//! random-weights substitution).
//!
//!     cargo run --release --example needle_e2e -- [--contexts 512,1024]

use std::rc::Rc;

use kvswap::baselines::{configure, Budget};
use kvswap::bench;
use kvswap::coordinator::{EngineConfig, Policy};
use kvswap::disk::DiskProfile;
use kvswap::metrics::Table;
use kvswap::quality;
use kvswap::util::cli::Args;
use kvswap::workload::needle::depth_positions;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let contexts = args.usize_list_or("contexts", &[512, 1024]);
    let depths = args.usize_or("depths", 3);
    let strength = args.f64_or("strength", 10.0) as f32;
    let rt = bench::runtime()?;

    let methods: Vec<(&str, Policy, Budget)> = vec![
        ("kvswap", Policy::KvSwap, Budget::Relaxed),
        ("kvswap-t", Policy::KvSwap, Budget::Tight),
        ("loki-t", Policy::Loki, Budget::Tight),
        (
            "shadowkv-t",
            Policy::ShadowKv { chunk: 8, rank: 32 },
            Budget::Tight,
        ),
    ];

    let mut table = Table::new(&["method", "context", "depth", "retrieval"]);
    let mut means: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for (name, policy, budget) in &methods {
        for &context in &contexts {
            for (di, _) in depth_positions(context, depths).iter().enumerate() {
                let frac = di as f64 / (depths.saturating_sub(1).max(1)) as f64;
                let (p, kv) = configure(policy, *budget, 4);
                let cfg = EngineConfig::builder()
                    .preset("nano")
                    .batch(1)
                    .policy(p)
                    .kv(kv)
                    .disk(DiskProfile::nvme())
                    .max_context(context.max(2048))
                    .seed(5)
                    .build()?;
                let score =
                    quality::niah_cell(Rc::clone(&rt), cfg, context, frac, 11, strength)?;
                table.row(vec![
                    name.to_string(),
                    context.to_string(),
                    format!("{:.0}%", frac * 100.0),
                    format!("{score:.3}"),
                ]);
                means.entry(name).or_default().push(score);
            }
        }
    }
    println!("\n=== NIAH retrieval scores (1.0 = oracle-equivalent) ===");
    println!("{}", table.render());
    println!("means:");
    for (name, scores) in &means {
        let m = scores.iter().sum::<f64>() / scores.len() as f64;
        println!("  {name:<11} {m:.3}");
    }
    // the paper's Fig. 9 shape: KVSwap-t retains retrieval everywhere;
    // the tight baselines lose it
    let kvswap_mean =
        means["kvswap-t"].iter().sum::<f64>() / means["kvswap-t"].len() as f64;
    println!(
        "\nKVSwap-t mean retrieval {kvswap_mean:.3} — paper Fig. 9: only \
         KVSwap-t maintains full capability at all positions"
    );
    Ok(())
}
