//! End-to-end serving driver (the repo's E2E validation, EXPERIMENTS.md):
//! starts the TCP serving front backed by the router/engine thread,
//! fires a trace of long-context requests at it over a real socket, and
//! reports latency percentiles + aggregate throughput.
//!
//!     cargo run --release --example serve_batch -- \
//!         [--requests 12] [--disk nvme] [--policy kvswap]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use kvswap::baselines::{configure, Budget};
use kvswap::coordinator::batcher::BatcherConfig;
use kvswap::coordinator::router::Router;
use kvswap::coordinator::{EngineConfig, Policy};
use kvswap::disk::DiskProfile;
use kvswap::metrics::latency_summary;
use kvswap::runtime::default_artifacts_dir;
use kvswap::util::cli::Args;
use kvswap::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 12);
    let disk = DiskProfile::by_name(&args.str_or("disk", "nvme")).expect("disk");
    let policy = Policy::by_name(&args.str_or("policy", "kvswap")).expect("policy");
    let (policy, kv) = configure(&policy, Budget::Relaxed, 4);
    let addr = args.str_or("addr", "127.0.0.1:7471");

    let engine_cfg = EngineConfig::builder()
        .preset("nano")
        .batch(1) // router resizes per wave
        .policy(policy)
        .kv(kv)
        .disk(disk)
        .max_context(2048)
        .seed(3)
        .build()?;
    let batcher_cfg = BatcherConfig {
        supported: vec![1, 2, 4],
        linger_s: 0.05,
        max_context: 2048,
    };
    let router = Router::spawn(default_artifacts_dir(), engine_cfg, batcher_cfg);

    // server thread (accepts one connection then exits)
    let addr2 = addr.clone();
    let server = std::thread::spawn(move || -> anyhow::Result<Router> {
        kvswap::server::serve(&addr2, &router, Some(1))?;
        Ok(router)
    });
    std::thread::sleep(std::time::Duration::from_millis(200));

    // client: submit the trace over the socket
    println!("client: sending {n_requests} requests to {addr}");
    let t0 = std::time::Instant::now();
    let mut sock = TcpStream::connect(&addr)?;
    for i in 0..n_requests {
        let context = [512usize, 1024, 1536][i % 3];
        let decode = 16 + (i % 3) * 8;
        writeln!(
            sock,
            r#"{{"id": {i}, "context": {context}, "decode": {decode}, "seed": {i}}}"#
        )?;
    }
    writeln!(sock, "quit")?;

    let reader = BufReader::new(sock.try_clone()?);
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let mut batches = std::collections::BTreeMap::<usize, usize>::new();
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
        if j.get("error").is_some() {
            anyhow::bail!("server error: {line}");
        }
        latencies.push(j.f64_or("latency_ms", 0.0));
        tokens += j.get("tokens").and_then(|t| t.as_arr()).map(|a| a.len()).unwrap_or(0);
        *batches.entry(j.usize_or("batch", 0)).or_insert(0) += 1;
        println!(
            "  completion id={} tokens={} latency={:.0}ms (batch {})",
            j.usize_or("id", 0),
            j.get("tokens").and_then(|t| t.as_arr()).map(|a| a.len()).unwrap_or(0),
            j.f64_or("latency_ms", 0.0),
            j.usize_or("batch", 0),
        );
        if latencies.len() == n_requests {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let router = server.join().map_err(|_| anyhow::anyhow!("server panicked"))??;
    router.stop()?;

    let summary = latency_summary(&latencies);
    println!("\n=== serve_batch summary ===");
    println!("requests completed: {}/{n_requests}", summary.n);
    println!("generated tokens:   {tokens}");
    println!("wall time:          {wall:.2}s  ({:.2} tok/s end-to-end)", tokens as f64 / wall);
    println!(
        "latency ms: p50={:.0} p90={:.0} p99={:.0} mean={:.0}",
        summary.p50_ms, summary.p90_ms, summary.p99_ms, summary.mean_ms
    );
    println!("batch-size histogram: {batches:?}");
    anyhow::ensure!(summary.n == n_requests, "lost completions");
    Ok(())
}
