"""Pallas kernels (L1) + pure-jnp oracles for the KVSwap stack."""
from . import attention, prefill, ref, score  # noqa: F401
