"""L1 Pallas kernels: low-rank approximate attention scoring (paper §3.3).

Two variants:

* ``token_scores`` — emits head-summed per-token scores [b, N]; the Rust
  coordinator performs the per-group ReduceMax + Top-M selection. This is
  the variant the AOT manifest exports by default: it keeps the group size
  G a *runtime* parameter (the paper tunes G offline per storage device,
  and our Fig. 12 bench sweeps it without recompiling artifacts).

* ``grouped_scores`` — fuses the group ReduceMax into the kernel so the
  [N]-long token-score vector never leaves VMEM (the TPU analogue of the
  paper's "ReduceMax operation within each group"). Exported for the
  default G as the ablation/perf variant.

The score matmul is [Hq, r] x [r, N]: tall-skinny on the MXU; at r=16,
N=8192 it is ~2 MiB of VMEM per batch row — comfortably resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _token_score_kernel(qlr_ref, klr_ref, len_ref, out_ref):
    qlr = qlr_ref[0]  # [Hq, r]
    klr = klr_ref[0]  # [N, r]
    n_valid = len_ref[0, 0]  # scalar i32
    # [Hq, r] x [N, r]^T, head-sum fused by summing the Hq axis after the
    # matmul (XLA folds this into a single pass in interpret mode; on TPU
    # it is one MXU matmul + VPU reduce).
    s = jax.lax.dot_general(
        qlr, klr, (((1,), (1,)), ((), ())), precision="highest"
    )  # [Hq, N]
    tok = jnp.sum(s, axis=0)  # [N]
    idx = jax.lax.iota(jnp.int32, tok.shape[0])
    out_ref[0] = jnp.where(idx < n_valid, tok, NEG_INF)


def token_scores(q_lr, k_lr, lens, *, interpret=True):
    """Pallas token-score kernel. Shapes as in ref.token_scores_ref."""
    b, hq, r = q_lr.shape
    n = k_lr.shape[1]
    lens2 = lens.reshape(b, 1).astype(jnp.int32)
    return pl.pallas_call(
        _token_score_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, hq, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), q_lr.dtype),
        interpret=interpret,
    )(q_lr, k_lr, lens2)


def _grouped_score_kernel(qlr_ref, klr_ref, len_ref, out_ref, *, group):
    qlr = qlr_ref[0]
    klr = klr_ref[0]
    n_valid = len_ref[0, 0]
    s = jax.lax.dot_general(
        qlr, klr, (((1,), (1,)), ((), ())), precision="highest"
    )
    tok = jnp.sum(s, axis=0)
    n = tok.shape[0]
    idx = jax.lax.iota(jnp.int32, n)
    tok = jnp.where(idx < n_valid, tok, NEG_INF)
    # Fused per-group ReduceMax: token scores never leave VMEM.
    out_ref[0] = jnp.max(tok.reshape(n // group, group), axis=-1)


def grouped_scores(q_lr, k_lr, lens, group, *, interpret=True):
    """Fused grouped-score kernel. Shapes as in ref.grouped_scores_ref."""
    b, hq, r = q_lr.shape
    n = k_lr.shape[1]
    assert n % group == 0, (n, group)
    lens2 = lens.reshape(b, 1).astype(jnp.int32)
    kern = functools.partial(_grouped_score_kernel, group=int(group))
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, hq, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n // group), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n // group), q_lr.dtype),
        interpret=interpret,
    )(q_lr, k_lr, lens2)
