"""L1 Pallas kernel: GQA attention over gathered (selected) KV groups.

This is the decode hot-spot of the KVSwap system: attention computed only
over the KV entries the grouped predictor selected (reuse-buffer hits +
freshly loaded groups + rolling-buffer entries), already gathered into a
contiguous [P, d] block by the Rust KV-cache manager (paper §3.4.4 mapping
table gives the attention kernel a contiguous logical view).

Hardware adaptation (DESIGN.md §3): on a real TPU the [Hkv, P, d] selected
block is exactly one VMEM-resident tile per batch row — the BlockSpec below
expresses the HBM->VMEM schedule that the paper's disk->RAM groups express:
one *prediction group* is one tile row, so the disk-page-aligned grouping
and the MXU tiling coincide. Both matmuls ([Hq,d]x[d,P] and [Hq,P]x[P,d])
are MXU-shaped when P is a multiple of 128. interpret=True is mandatory on
this CPU-only image (Mosaic custom-calls cannot execute on the CPU plugin).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF  # noqa: F401  (re-exported for callers)


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, n_rep, scale):
    """One batch row: q [1,Hq,d], k/v [1,Hkv,P,d], mask [1,P] -> o [1,Hq,d]."""
    q = q_ref[0]  # [Hq, d]
    k = k_ref[0]  # [Hkv, P, d]
    v = v_ref[0]
    m = mask_ref[0]  # [P]
    hkv = k.shape[0]
    d = q.shape[-1]
    qg = q.reshape(hkv, n_rep, d)
    # Scores on the "MXU": one [n_rep, d] x [d, P] matmul per KV head.
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (0,))), precision="highest"
    )  # [Hkv, n_rep, P]
    s = s * scale + m[None, None, :]
    # Numerically-stable masked softmax, fused in-register.
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jax.lax.dot_general(
        w, v, (((2,), (1,)), ((0,), (0,))), precision="highest"
    )  # [Hkv, n_rep, d]
    o_ref[0] = o.reshape(hkv * n_rep, d)


def gathered_attention(q, k_sel, v_sel, mask, *, scale=None, interpret=True):
    """Pallas gathered-attention. Shapes as in ref.gathered_attention_ref."""
    b, hq, d = q.shape
    hkv, p = k_sel.shape[1], k_sel.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    kern = functools.partial(_attn_kernel, n_rep=n_rep, scale=float(scale))
    return pl.pallas_call(
        kern,
        grid=(b,),
        # One batch row per program: the whole selected block fits VMEM
        # (P*d*4B per KV head; 272*32*4 = 34 KiB/head at default config).
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hkv, p, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, p, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, p), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(q, k_sel, v_sel, mask)
