"""L1 Pallas kernel: chunked causal prefill attention.

Prefill computes attention for a chunk of T prompt tokens against the whole
cache written so far (including the chunk itself). The KVSwap runtime calls
this layer-by-layer while streaming the produced KV groups to disk
(paper §3.4: "writes it to disk in a layer-by-layer fashion").

TPU mapping: one batch row per program; scores tile is [T, S] per KV head.
T=128 keeps the tile within VMEM up to S=8K at f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _prefill_kernel(q_ref, k_ref, v_ref, start_ref, o_ref, *, n_rep, scale):
    q = q_ref[0]  # [T, Hq, d]
    k = k_ref[0]  # [Hkv, S, d]
    v = v_ref[0]
    start = start_ref[0, 0]  # scalar i32
    t, hq, d = q.shape
    hkv, s_len = k.shape[0], k.shape[1]
    qg = q.reshape(t, hkv, n_rep, d)
    # [T, Hkv, n_rep, d] x [Hkv, S, d] -> [Hkv, T, n_rep, S]
    s = jax.lax.dot_general(
        qg.transpose(1, 0, 2, 3),
        k,
        (((3,), (2,)), ((0,), (0,))),
        precision="highest",
    )  # [Hkv, T, n_rep, S]
    key_pos = jax.lax.iota(jnp.int32, s_len)  # [S]
    q_pos = start + jax.lax.iota(jnp.int32, t)  # [T]
    causal = key_pos[None, :] <= q_pos[:, None]  # [T, S]
    s = s * scale
    s = jnp.where(causal[None, :, None, :], s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jax.lax.dot_general(
        w, v, (((3,), (1,)), ((0,), (0,))), precision="highest"
    )  # [Hkv, T, n_rep, d]
    o_ref[0] = o.transpose(1, 0, 2, 3).reshape(t, hq, d)


def prefill_attention(q, k_cache, v_cache, start, *, scale=None, interpret=True):
    """Pallas chunked prefill attention. Shapes as in prefill_attention_ref."""
    b, t, hq, d = q.shape
    hkv, s_len = k_cache.shape[1], k_cache.shape[2]
    n_rep = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    start2 = start.reshape(b, 1).astype(jnp.int32)
    kern = functools.partial(_prefill_kernel, n_rep=n_rep, scale=float(scale))
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, hq, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, s_len, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, s_len, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, hq, d), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, hq, d), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, start2)
