"""Pure-jnp oracles for every Pallas kernel (the L1 correctness signal).

Each function here is the mathematical definition the kernels in
``attention.py`` / ``score.py`` / ``prefill.py`` must match; pytest
(`tests/test_kernels.py`) asserts allclose between kernel and oracle over
hypothesis-driven shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9  # additive-mask "minus infinity" that keeps softmax NaN-free


def gathered_attention_ref(q, k_sel, v_sel, mask, scale):
    """GQA attention over gathered (selected) KV entries.

    q:     [b, Hq, d]      (RoPE already applied)
    k_sel: [b, Hkv, P, d]  gathered keys (stored post-RoPE)
    v_sel: [b, Hkv, P, d]
    mask:  [b, P]          additive mask (0 = valid, NEG_INF = padding)
    -> [b, Hq, d]
    """
    b, hq, d = q.shape
    hkv, p = k_sel.shape[1], k_sel.shape[2]
    n_rep = hq // hkv
    qg = q.reshape(b, hkv, n_rep, d)
    s = jnp.einsum("bhrd,bhpd->bhrp", qg, k_sel) * scale
    s = s + mask[:, None, None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bhrp,bhpd->bhrd", w, v_sel)
    return o.reshape(b, hq, d)


def token_scores_ref(q_lr, k_lr, lens):
    """Low-rank approximate attention scores, head-summed (paper §3.3).

    q_lr: [b, Hq, r]   low-rank query vectors  Q_h A_{g(h)}
    k_lr: [b, N, r]    joint-head compressed K cache rows
    lens: [b]          number of valid rows in k_lr
    -> [b, N] per-token importance scores; invalid tokens = NEG_INF
    """
    s = jnp.einsum("bhr,bnr->bhn", q_lr, k_lr)
    tok = jnp.sum(s, axis=1)  # head-sum (paper: "summing across all heads")
    n = k_lr.shape[1]
    idx = jnp.arange(n)[None, :]
    return jnp.where(idx < lens[:, None], tok, NEG_INF)


def grouped_scores_ref(q_lr, k_lr, lens, group):
    """Fused variant: token scores -> per-group ReduceMax (paper Fig. 6).

    -> [b, N // group] representative score per group of `group`
    consecutive tokens.
    """
    tok = token_scores_ref(q_lr, k_lr, lens)
    b, n = tok.shape
    assert n % group == 0
    return jnp.max(tok.reshape(b, n // group, group), axis=-1)


def prefill_attention_ref(q, k_cache, v_cache, start, scale):
    """Chunked causal prefill attention.

    q:       [b, T, Hq, d]   RoPE-applied queries for chunk tokens
                             [start, start+T)
    k_cache: [b, Hkv, S, d]  cache with the chunk's keys already written at
                             [start, start+T) (post-RoPE)
    v_cache: [b, Hkv, S, d]
    start:   [b] i32         absolute position of the first chunk token
    -> [b, T, Hq, d]
    """
    b, t, hq, d = q.shape
    hkv, s_len = k_cache.shape[1], k_cache.shape[2]
    n_rep = hq // hkv
    qg = q.reshape(b, t, hkv, n_rep, d)
    s = jnp.einsum("bthrd,bhpd->bthrp", qg, k_cache) * scale
    key_pos = jnp.arange(s_len)[None, None, :]
    q_pos = start[:, None, None] + jnp.arange(t)[None, :, None]
    causal = key_pos <= q_pos  # [b, T, S]
    s = jnp.where(causal[:, :, None, None, :], s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bthrp,bhpd->bthrd", w, v_cache)
    return o.reshape(b, t, hq, d)
