"""Offline K-cache calibration: SVD low-rank adapters (paper §3.2).

KVSwap pre-computes, per layer, a low-rank adapter A in R^{(Hkv*d) x r}
from a flattened calibration K cache: SVD(K_ftn) = U diag(S) V^T, A = the
top-r right singular vectors. The compressed cache is K_lr = flatten(K) A.
The paper draws calibration samples from general-purpose corpora (C4 /
WikiText); with no network access, we draw random-token prompts from the
same distribution the benchmark workload generator uses — DESIGN.md §2
documents the substitution (the adapter only has to capture the K-space
geometry of *this* model, which random prompts through the real weights
do).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from . import model
from .specs import ModelSpec


def collect_calibration_k(
    spec: ModelSpec,
    weights: Dict[str, np.ndarray],
    *,
    n_batches: int = 2,
    batch: int = 2,
    seq: int = 256,
    seed: int = 1234,
) -> List[np.ndarray]:
    """Run real prefills over random-token prompts; return per-layer
    flattened K matrices [n_batches*batch*seq, Hkv*d] (post-RoPE)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    per_layer: List[List[np.ndarray]] = [[] for _ in range(spec.n_layers)]
    jw = {k: jnp.asarray(v) for k, v in weights.items()}
    for _ in range(n_batches):
        tokens = rng.integers(0, spec.vocab, size=(batch, seq))
        _, ks, _ = model.reference_prefill(spec, jw, jnp.asarray(tokens))
        for li, k in enumerate(ks):
            # [b, Hkv, S, d] -> [b*S, Hkv*d] (token-major flatten, §3.2)
            arr = np.asarray(k).transpose(0, 2, 1, 3)
            per_layer[li].append(arr.reshape(-1, spec.kv_flat_dim))
    return [np.concatenate(chunks, axis=0) for chunks in per_layer]


def svd_adapter(k_flat: np.ndarray, rank: int) -> np.ndarray:
    """Top-`rank` right singular vectors of the calibration K matrix."""
    # economy SVD; k_flat is [N, HD] with HD small (128)
    _, _, vt = np.linalg.svd(k_flat, full_matrices=False)
    return np.ascontiguousarray(vt[:rank].T.astype(np.float32))  # [HD, r]


def build_adapters(
    spec: ModelSpec,
    weights: Dict[str, np.ndarray],
    ranks: List[int],
    **collect_kw,
) -> Dict[str, np.ndarray]:
    """Return {'layer{i}.A{r}': [HD, r]} for every layer and rank."""
    k_flats = collect_calibration_k(spec, weights, **collect_kw)
    out: Dict[str, np.ndarray] = {}
    for li, k_flat in enumerate(k_flats):
        # One SVD per layer serves all ranks (nested subspaces).
        _, _, vt = np.linalg.svd(k_flat, full_matrices=False)
        for r in ranks:
            out[f"layer{li}.A{r}"] = np.ascontiguousarray(
                vt[:r].T.astype(np.float32)
            )
    return out


def reconstruction_error(k_flat: np.ndarray, a: np.ndarray) -> float:
    """Relative Frobenius error of K ≈ (K A) A^T — quality of the adapter."""
    k_lr = k_flat @ a
    k_rec = k_lr @ a.T
    return float(
        np.linalg.norm(k_flat - k_rec) / max(np.linalg.norm(k_flat), 1e-9)
    )
