"""L2: the GQA transformer compute graph, built from the L1 Pallas kernels.

Every function here is a *pure* jax function over arrays with static
shapes; ``aot.py`` lowers each to an HLO-text artifact the Rust runtime
executes via PJRT. Weights are runtime arguments (held as persistent
PjRtBuffers on the Rust side), never HLO constants.

Decode-path split of responsibilities (DESIGN.md §4): HLO owns dense math
(projections, RoPE, kernel attention, MLP); the Rust coordinator owns all
dynamic control flow (group selection, reuse-buffer diffing, gathering,
mapping-table updates).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels import attention, prefill, score
from .kernels.ref import NEG_INF
from .specs import LAYER_TENSORS, ModelSpec


# ---------------------------------------------------------------------------
# building blocks


def rmsnorm(x, g, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope(x, pos, base):
    """Rotary position embedding.

    x:   [..., H, d] with d even
    pos: broadcastable to x[..., 0, 0] — absolute token positions (i32)
    """
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(h, wg, wu, wd):
    return (jax.nn.silu(h @ wg) * (h @ wu)) @ wd


def _layer_args(weights_prefix: str = "") -> List[str]:
    return [weights_prefix + t for t in LAYER_TENSORS]


# ---------------------------------------------------------------------------
# decode path artifacts


def embed_fn(spec: ModelSpec):
    """tokens [b] i32, emb [V, D] -> x [b, D]"""

    def f(tokens, emb):
        return (jnp.take(emb, tokens, axis=0),)

    return f


def decode_block_fn(spec: ModelSpec):
    """One transformer block for a single decode step over gathered KV.

    Inputs:
      x      [b, D]           block input activations
      k_sel  [b, Hkv, P, d]   gathered selected keys (post-RoPE)
      v_sel  [b, Hkv, P, d]
      mask   [b, P]           additive validity mask for the P slots
      pos    [b] i32          absolute position of the current token
      ln1, wq, wk, wv, wo, ln2, wg, wu, wd : layer weights
    Outputs:
      x_next [b, D], k_new [b, Hkv, d] (post-RoPE), v_new [b, Hkv, d]

    The current token's K/V are computed here and appended as slot P
    (self-attention is always valid), so the kernel sees width P+1.
    """

    def f(x, k_sel, v_sel, mask, pos, ln1, wq, wk, wv, wo, ln2, wg, wu, wd):
        b = x.shape[0]
        hq, hkv, d = spec.n_q_heads, spec.n_kv_heads, spec.head_dim
        h = rmsnorm(x, ln1, spec.rms_eps)
        q = (h @ wq).reshape(b, hq, d)
        k_new = (h @ wk).reshape(b, hkv, d)
        v_new = (h @ wv).reshape(b, hkv, d)
        q = rope(q, pos, spec.rope_base)
        k_new = rope(k_new, pos, spec.rope_base)
        k_full = jnp.concatenate([k_sel, k_new[:, :, None, :]], axis=2)
        v_full = jnp.concatenate([v_sel, v_new[:, :, None, :]], axis=2)
        mask_full = jnp.concatenate(
            [mask, jnp.zeros((b, 1), dtype=mask.dtype)], axis=1
        )
        o = attention.gathered_attention(q, k_full, v_full, mask_full)
        x = x + o.reshape(b, hq * d) @ wo
        h2 = rmsnorm(x, ln2, spec.rms_eps)
        x = x + swiglu(h2, wg, wu, wd)
        return x, k_new, v_new

    return f


def predict_scores_fn(spec: ModelSpec):
    """Grouped-critical-KV predictor input math + token-score kernel.

    Approximates *next* layer i's attention scores from layer i-1's input
    x (paper §3.3 "online prediction": X_i ≈ X_{i-1}), using layer i's
    query projection and the per-layer low-rank adapter A.

    Inputs:
      x       [b, D]          input of layer i-1 (≈ input of layer i)
      k_lr    [b, N, r]       compressed K cache rows for layer i
      lens    [b] i32         valid rows in k_lr
      pos     [b] i32         current decode position (for RoPE on q̂)
      ln1_n   [D]             layer i's pre-attention norm
      wq_n    [D, Hq*d]       layer i's query projection
      a       [Hkv*d, r]      layer i's low-rank adapter
    Output:
      tscores [b, N]          head-summed token scores (NEG_INF at invalid)
    """

    def f(x, k_lr, lens, pos, ln1_n, wq_n, a):
        b = x.shape[0]
        hq, hkv, d = spec.n_q_heads, spec.n_kv_heads, spec.head_dim
        r = a.shape[1]
        h = rmsnorm(x, ln1_n, spec.rms_eps)
        q = (h @ wq_n).reshape(b, hq, d)
        q = rope(q, pos, spec.rope_base)
        # Eq. (1): q_lr[h] = Q_h A_{g(h)}; A_{g(h)} is the d-row slice of A
        # owned by query head h's shared KV head g(h).
        a_heads = a.reshape(hkv, d, r)
        qg = q.reshape(b, hkv, spec.n_rep, d)
        q_lr = jnp.einsum("bhrd,hdk->bhrk", qg, a_heads).reshape(b, hq, r)
        tok = score.token_scores(q_lr, k_lr, lens)
        return (tok,)

    return f


def grouped_predict_fn(spec: ModelSpec, group: int):
    """Fused variant: same as predict_scores_fn but returns group maxima."""

    def f(x, k_lr, lens, pos, ln1_n, wq_n, a):
        b = x.shape[0]
        hq, hkv, d = spec.n_q_heads, spec.n_kv_heads, spec.head_dim
        r = a.shape[1]
        h = rmsnorm(x, ln1_n, spec.rms_eps)
        q = (h @ wq_n).reshape(b, hq, d)
        q = rope(q, pos, spec.rope_base)
        a_heads = a.reshape(hkv, d, r)
        qg = q.reshape(b, hkv, spec.n_rep, d)
        q_lr = jnp.einsum("bhrd,hdk->bhrk", qg, a_heads).reshape(b, hq, r)
        g = score.grouped_scores(q_lr, k_lr, lens, group)
        return (g,)

    return f


def logits_argmax_fn(spec: ModelSpec):
    """x [b, D], fln [D], emb [V, D] -> (next_token [b] i32, top_logit [b])"""

    def f(x, fln, emb):
        h = rmsnorm(x, fln, spec.rms_eps)
        logits = h @ emb.T
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        top = jnp.max(logits, axis=-1)
        return tok, top

    return f


# ---------------------------------------------------------------------------
# prefill path artifacts


def embed_chunk_fn(spec: ModelSpec):
    """tokens [b, T] i32, emb [V, D] -> x [b, T, D]"""

    def f(tokens, emb):
        return (jnp.take(emb, tokens, axis=0),)

    return f


def prefill_block_fn(spec: ModelSpec):
    """One transformer block over a prefill chunk.

    Inputs:
      x        [b, T, D]
      k_cache  [b, Hkv, S, d]  keys for positions < start (post-RoPE);
                               rows >= start are ignored/overwritten
      v_cache  [b, Hkv, S, d]
      start    [b] i32         absolute position of chunk token 0
      layer weights as in decode_block_fn
    Outputs:
      x_next [b, T, D], k_chunk [b, Hkv, T, d], v_chunk [b, Hkv, T, d]

    The chunk's keys are written into the cache (dynamic-update-slice)
    before the kernel runs, so in-chunk causal attention is exact.
    """

    def f(x, k_cache, v_cache, start, ln1, wq, wk, wv, wo, ln2, wg, wu, wd):
        b, t, _ = x.shape
        hq, hkv, d = spec.n_q_heads, spec.n_kv_heads, spec.head_dim
        h = rmsnorm(x, ln1, spec.rms_eps)
        q = (h @ wq).reshape(b, t, hq, d)
        k_chunk = (h @ wk).reshape(b, t, hkv, d)
        v_chunk = (h @ wv).reshape(b, t, hkv, d)
        pos = start[:, None] + jnp.arange(t)[None, :]  # [b, T]
        q = rope(q, pos, spec.rope_base)
        k_chunk = rope(k_chunk, pos, spec.rope_base)
        k_chunk = k_chunk.transpose(0, 2, 1, 3)  # [b, Hkv, T, d]
        v_chunk = v_chunk.transpose(0, 2, 1, 3)

        def write(cache, chunk, s0):
            return jax.lax.dynamic_update_slice(
                cache, chunk, (0, s0, 0)
            )

        # Per-batch dynamic start: vmap the DUS over the batch axis.
        k_full = jax.vmap(write)(k_cache, k_chunk, start)
        v_full = jax.vmap(write)(v_cache, v_chunk, start)
        o = prefill.prefill_attention(q, k_full, v_full, start)
        x = x + o.reshape(b, t, hq * d) @ wo
        h2 = rmsnorm(x, ln2, spec.rms_eps)
        x = x + swiglu(h2, wg, wu, wd)
        return x, k_chunk, v_chunk

    return f


# ---------------------------------------------------------------------------
# whole-model reference (used by tests and calibration, never exported)


def reference_decode_step(
    spec: ModelSpec,
    weights: Dict[str, jnp.ndarray],
    x0,
    k_cache,
    v_cache,
    lens,
    pos,
):
    """Full-KV oracle decode step in pure jnp (no Pallas).

    x0 [b, D]; k_cache/v_cache [L][b, Hkv, S, d]; lens [b] i32; pos [b] i32.
    Returns (x_final [b, D], k_new [L][b, Hkv, d], v_new [L][b, Hkv, d]).
    """
    from .kernels.ref import gathered_attention_ref

    b = x0.shape[0]
    hq, hkv, d = spec.n_q_heads, spec.n_kv_heads, spec.head_dim
    s_len = k_cache[0].shape[2]
    idx = jnp.arange(s_len)[None, :]
    # Current token occupies the slot at `lens` implicitly via concat below.
    mask = jnp.where(idx < lens[:, None], 0.0, NEG_INF).astype(jnp.float32)
    x = x0
    k_news, v_news = [], []
    for i in range(spec.n_layers):
        w = {t: weights[f"layer{i}.{t}"] for t in LAYER_TENSORS}
        h = rmsnorm(x, w["ln1"], spec.rms_eps)
        q = rope((h @ w["wq"]).reshape(b, hq, d), pos, spec.rope_base)
        k_new = rope((h @ w["wk"]).reshape(b, hkv, d), pos, spec.rope_base)
        v_new = (h @ w["wv"]).reshape(b, hkv, d)
        k_full = jnp.concatenate([k_cache[i], k_new[:, :, None, :]], axis=2)
        v_full = jnp.concatenate([v_cache[i], v_new[:, :, None, :]], axis=2)
        m = jnp.concatenate([mask, jnp.zeros((b, 1), jnp.float32)], axis=1)
        o = gathered_attention_ref(q, k_full, v_full, m, 1.0 / d**0.5)
        x = x + o.reshape(b, hq * d) @ w["wo"]
        h2 = rmsnorm(x, w["ln2"], spec.rms_eps)
        x = x + swiglu(h2, w["wg"], w["wu"], w["wd"])
        k_news.append(k_new)
        v_news.append(v_new)
    return x, k_news, v_news


def reference_prefill(spec: ModelSpec, weights, tokens):
    """Full prefill in pure jnp. tokens [b, S] -> (x [b, S, D], K, V lists).

    K/V lists: per-layer [b, Hkv, S, d] post-RoPE caches.
    """
    from .kernels.ref import prefill_attention_ref

    b, s_len = tokens.shape
    hq, hkv, d = spec.n_q_heads, spec.n_kv_heads, spec.head_dim
    x = jnp.take(weights["emb"], tokens, axis=0)
    pos = jnp.broadcast_to(jnp.arange(s_len)[None, :], (b, s_len))
    start = jnp.zeros((b,), jnp.int32)
    ks, vs = [], []
    for i in range(spec.n_layers):
        w = {t: weights[f"layer{i}.{t}"] for t in LAYER_TENSORS}
        h = rmsnorm(x, w["ln1"], spec.rms_eps)
        q = rope((h @ w["wq"]).reshape(b, s_len, hq, d), pos, spec.rope_base)
        k = rope((h @ w["wk"]).reshape(b, s_len, hkv, d), pos, spec.rope_base)
        v = (h @ w["wv"]).reshape(b, s_len, hkv, d)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        o = prefill_attention_ref(q, k, v, start, 1.0 / d**0.5)
        x = x + o.reshape(b, s_len, hq * d) @ w["wo"]
        h2 = rmsnorm(x, w["ln2"], spec.rms_eps)
        x = x + swiglu(h2, w["wg"], w["wu"], w["wd"])
        ks.append(k)
        vs.append(v)
    return x, ks, vs
