"""Model specifications and weight initialization for the KVSwap stack.

The paper evaluates LLaMA3-3B/8B and Qwen3-4/8/14B class GQA models on a
Jetson Orin. Those are not runnable here (no network, CPU-only PJRT with
interpret-mode Pallas), so we define a family of small GQA transformers
with the *same dataflow* (GQA attention, per-layer KV cache, RoPE, SwiGLU
MLP, RMSNorm, tied LM head) at sizes where the whole three-layer stack is
tractable. DESIGN.md documents the substitution and the size mapping used
by the benchmark harness (`nano`→"3B", `small`→"8B", `med`→"14B").
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple

import numpy as np

# f32 everywhere: CPU PJRT path; keeps the Rust Literal plumbing simple.
DTYPE = np.float32


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static shape/config description of a GQA transformer."""

    name: str
    n_layers: int
    d_model: int
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_base: float = 10000.0
    rms_eps: float = 1e-5
    # Init gain on Wq/Wk. Random-init transformers produce near-uniform
    # attention; the paper's premise (a small fraction of tokens dominate
    # attention mass) needs spiky score distributions, so we raise the
    # query/key init scale until top-5% tokens carry most of the mass.
    # test_model.py asserts the resulting concentration is in range.
    attn_gain: float = 4.0
    # Spectral decay of Wk within each head's dim pairs. Trained LLMs have
    # sharply decaying K-cache spectra — the empirical fact ShadowKV and
    # KVSwap's low-rank compression rely on (paper §3.2). A random Wk
    # yields a *flat* spectrum that no low-rank predictor can compress, so
    # we bake the decay in: RoPE-pair p of every head is scaled by
    # exp(-p / k_decay). DESIGN.md §2 documents the substitution.
    k_decay: float = 2.5
    # Heavy-tailed token-embedding norms (lognormal sigma). Trained LLMs
    # have persistent heavy-hitter / sink tokens attended at every step -
    # the temporal locality that makes the paper's reuse buffer pay off
    # (S3.4.2, Fig. 8: ~77% step-to-step overlap). Uniform random
    # embeddings have none, so we give a heavy tail to embedding norms.
    emb_tail: float = 0.5

    @property
    def kv_flat_dim(self) -> int:
        """H_kv * d — the flattened joint-head K dimension (paper §3.2)."""
        return self.n_kv_heads * self.head_dim

    @property
    def q_flat_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def n_rep(self) -> int:
        """Query heads per KV head (GQA replication factor)."""
        assert self.n_q_heads % self.n_kv_heads == 0
        return self.n_q_heads // self.n_kv_heads

    def kv_bytes_per_token_layer(self) -> int:
        """K+V bytes for one token in one layer (f32)."""
        return 2 * self.kv_flat_dim * 4

    def kv_bytes_per_token(self) -> int:
        return self.n_layers * self.kv_bytes_per_token_layer()

    def n_params(self) -> int:
        d, hq, hkv = self.d_model, self.q_flat_dim, self.kv_flat_dim
        per_layer = (
            d  # ln1
            + d * hq  # wq
            + 2 * d * hkv  # wk, wv
            + hq * d  # wo
            + d  # ln2
            + 2 * d * self.d_ff  # wg, wu
            + self.d_ff * d  # wd
        )
        return self.n_layers * per_layer + self.vocab * d + d  # + emb + fln

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# Preset family. head_dim/kv dims chosen so H_kv*d = 128 everywhere: the
# paper's compression-ratio axis sigma = (H_kv*d)/r then spans r in
# {32,16,8,4} for sigma in {4,8,16,32} — matching its sigma_max = 32.
PRESETS: Dict[str, ModelSpec] = {
    "nano": ModelSpec(
        name="nano", n_layers=4, d_model=128, n_q_heads=8, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512,
    ),
    "small": ModelSpec(
        name="small", n_layers=8, d_model=256, n_q_heads=16, n_kv_heads=4,
        head_dim=32, d_ff=512, vocab=1024,
    ),
    "med": ModelSpec(
        name="med", n_layers=12, d_model=384, n_q_heads=12, n_kv_heads=4,
        head_dim=32, d_ff=768, vocab=1024,
    ),
}


# Per-layer weight tensor names, in the canonical serialization order the
# Rust runtime (runtime/artifacts.rs) relies on.
LAYER_TENSORS: List[str] = [
    "ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd",
]


def layer_shapes(spec: ModelSpec) -> Dict[str, Tuple[int, ...]]:
    d, f = spec.d_model, spec.d_ff
    return {
        "ln1": (d,),
        "wq": (d, spec.q_flat_dim),
        "wk": (d, spec.kv_flat_dim),
        "wv": (d, spec.kv_flat_dim),
        "wo": (spec.q_flat_dim, d),
        "ln2": (d,),
        "wg": (d, f),
        "wu": (d, f),
        "wd": (f, d),
    }


def global_shapes(spec: ModelSpec) -> Dict[str, Tuple[int, ...]]:
    return {
        "emb": (spec.vocab, spec.d_model),
        "fln": (spec.d_model,),
    }


def init_weights(spec: ModelSpec, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic random init. Keys: 'emb', 'fln', 'layer{i}.{tensor}'."""
    rng = np.random.default_rng(seed)
    w: Dict[str, np.ndarray] = {}

    def normal(shape, std):
        return rng.normal(0.0, std, size=shape).astype(DTYPE)

    d = spec.d_model
    emb = normal(global_shapes(spec)["emb"], 1.0 / np.sqrt(d))
    # heavy-tailed per-token norm scaling (persistent heavy hitters)
    scale = np.exp(rng.normal(0.0, spec.emb_tail, size=(spec.vocab, 1))).astype(DTYPE)
    w["emb"] = emb * scale
    w["fln"] = np.ones((d,), dtype=DTYPE)
    shapes = layer_shapes(spec)
    base = 1.0 / np.sqrt(d)
    qk_std = base * np.sqrt(spec.attn_gain)
    for i in range(spec.n_layers):
        for t in LAYER_TENSORS:
            shape = shapes[t]
            if t in ("ln1", "ln2"):
                w[f"layer{i}.{t}"] = np.ones(shape, dtype=DTYPE)
            elif t == "wq":
                w[f"layer{i}.{t}"] = normal(shape, qk_std)
            elif t == "wk":
                wk = normal(shape, qk_std)
                # per-head, RoPE-pair-consistent spectral decay: pair p of
                # head h spans columns (h*hd + p) and (h*hd + p + hd/2);
                # both get the same factor so rotations preserve the
                # subspace.
                hd = spec.head_dim
                half = hd // 2
                decay = np.exp(-np.arange(half) / spec.k_decay).astype(DTYPE)
                for h in range(spec.n_kv_heads):
                    wk[:, h * hd : h * hd + half] *= decay
                    wk[:, h * hd + half : (h + 1) * hd] *= decay
                w[f"layer{i}.{t}"] = wk
            elif t == "wd":
                # Scale residual-writing projections down with depth.
                w[f"layer{i}.{t}"] = normal(shape, base / np.sqrt(2 * spec.n_layers))
            elif t == "wo":
                w[f"layer{i}.{t}"] = normal(shape, base / np.sqrt(2 * spec.n_layers))
            else:
                w[f"layer{i}.{t}"] = normal(shape, base)
    return w


def serialize_weights(
    weights: Dict[str, np.ndarray],
) -> Tuple[bytes, List[dict]]:
    """Pack weights into a raw little-endian f32 blob + index entries."""
    blob = bytearray()
    index: List[dict] = []
    for name in sorted(weights.keys()):
        arr = np.ascontiguousarray(weights[name], dtype=DTYPE)
        index.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "offset": len(blob),
                "nbytes": arr.nbytes,
            }
        )
        blob.extend(arr.tobytes())
    return bytes(blob), index


def deserialize_weights(blob: bytes, index: List[dict]) -> Dict[str, np.ndarray]:
    out = {}
    for ent in index:
        start = ent["offset"]
        arr = np.frombuffer(blob, dtype=DTYPE, count=ent["nbytes"] // 4, offset=start)
        out[ent["name"]] = arr.reshape(ent["shape"]).copy()
    return out


def spec_from_json(d: dict) -> ModelSpec:
    return ModelSpec(**{k.name: d[k.name] for k in dataclasses.fields(ModelSpec)})


if __name__ == "__main__":
    for name, spec in PRESETS.items():
        print(
            f"{name}: params={spec.n_params()/1e6:.2f}M "
            f"kv_bytes/token={spec.kv_bytes_per_token()} "
            f"kv@8k,b8={8 * 8192 * spec.kv_bytes_per_token() / 2**20:.0f} MiB"
        )
    print(json.dumps(PRESETS["nano"].to_json(), indent=1))
