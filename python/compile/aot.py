"""AOT compile path: lower every L2 function to HLO-text artifacts.

Emits, per model preset:

    artifacts/<preset>/weights.bin          raw f32 weights + SVD adapters
    artifacts/<preset>/b<N>/<name>.hlo.txt  one HLO module per (fn, shapes)
    artifacts/manifest.json                 the contract rust parses

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published `xla` 0.1.6 crate links) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts` (no-op when inputs are unchanged). Python never
runs on the request path — the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate, model
from .specs import LAYER_TENSORS, PRESETS, ModelSpec, serialize_weights

F32 = jnp.float32
I32 = jnp.int32

# Tunable-default runtime parameters recorded in the manifest; the Rust
# tuner (paper §3.5 / Appendix A) can override everything that does not
# change artifact shapes (G, M, C) and picks among compiled variants for
# those that do (rank, Ncap, P).
DEFAULTS = {
    "group_size": 4,
    "n_groups": 64,  # M; M*G = 256 selected entries (paper: MG = 400)
    "rank": 16,  # sigma = 128/16 = 8
    "rb_slots": 16,  # rolling-buffer slots exposed to attention
    "p_sel": 272,  # 256 selected + 16 rolling-buffer slots
}


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(fn, args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def layer_weight_sds(spec: ModelSpec):
    from .specs import layer_shapes

    shapes = layer_shapes(spec)
    return [sds(shapes[t]) for t in LAYER_TENSORS]


class Plan:
    """Collects artifact definitions, lowers them, writes the manifest."""

    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.entries: List[dict] = []
        self.verbose = verbose
        self.t0 = time.time()

    def emit(self, preset: str, batch: int, name: str, fn, args, *,
             params: dict, weight_args: List[str], n_outputs: int):
        rel = f"{preset}/b{batch}/{name}.hlo.txt"
        path = os.path.join(self.out_dir, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        t = time.time()
        text = to_hlo_text(fn, args)
        with open(path, "w") as f:
            f.write(text)
        if self.verbose:
            print(
                f"[aot +{time.time()-self.t0:6.1f}s] {rel}"
                f" ({len(text)//1024} KiB, {time.time()-t:.1f}s)",
                flush=True,
            )
        self.entries.append(
            {
                "preset": preset,
                "batch": batch,
                "name": name,
                "params": params,
                "path": rel,
                "inputs": [
                    {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
                ],
                "weight_args": weight_args,
                "n_outputs": n_outputs,
            }
        )


def emit_preset(
    plan: Plan,
    spec: ModelSpec,
    *,
    batches: List[int],
    ncaps: List[int],
    ranks: List[int],
    full_ncaps: List[int],
    tp_only_batches: List[int],
    prefill_ncap: int,
    prefill_chunk: int,
    fused_group: int,
) -> dict:
    """Lower all artifacts for one preset; returns the manifest stanza."""
    d, hq, hkv, hd = spec.d_model, spec.n_q_heads, spec.n_kv_heads, spec.head_dim
    p_sel = DEFAULTS["p_sel"]
    lw = layer_weight_sds(spec)
    r_def = DEFAULTS["rank"]

    for b in batches:
        tp_only = b in tp_only_batches
        # --- embed / logits -------------------------------------------------
        plan.emit(
            spec.name, b, "embed", model.embed_fn(spec),
            [sds((b,), I32), sds((spec.vocab, d))],
            params={}, weight_args=["emb"], n_outputs=1,
        )
        plan.emit(
            spec.name, b, "logits_argmax", model.logits_argmax_fn(spec),
            [sds((b, d)), sds((d,)), sds((spec.vocab, d))],
            params={}, weight_args=["fln", "emb"], n_outputs=2,
        )
        # --- decode over selected KV (the KVSwap hot path) -------------------
        plan.emit(
            spec.name, b, f"decode_p{p_sel}", model.decode_block_fn(spec),
            [
                sds((b, d)),
                sds((b, hkv, p_sel, hd)),
                sds((b, hkv, p_sel, hd)),
                sds((b, p_sel)),
                sds((b,), I32),
                *lw,
            ],
            params={"p": p_sel}, weight_args=list(LAYER_TENSORS), n_outputs=3,
        )
        # --- full-attention decode (oracle + FlexGen/vLLM baselines);
        # also needed at throughput-only batches for the vLLM-like rows
        for ncap in full_ncaps:
            plan.emit(
                spec.name, b, f"decode_full_n{ncap}",
                model.decode_block_fn(spec),
                [
                    sds((b, d)),
                    sds((b, hkv, ncap, hd)),
                    sds((b, hkv, ncap, hd)),
                    sds((b, ncap)),
                    sds((b,), I32),
                    *lw,
                ],
                params={"p": ncap}, weight_args=list(LAYER_TENSORS),
                n_outputs=3,
            )
        # --- predictor ------------------------------------------------------
        for ncap in ncaps:
            plan.emit(
                spec.name, b, f"predict_n{ncap}_r{r_def}",
                model.predict_scores_fn(spec),
                [
                    sds((b, d)),
                    sds((b, ncap, r_def)),
                    sds((b,), I32),
                    sds((b,), I32),
                    sds((d,)),
                    sds((d, hq * hd)),
                    sds((hd * hkv, r_def)),
                ],
                params={"ncap": ncap, "rank": r_def},
                weight_args=["ln1", "wq", "A"], n_outputs=1,
            )
        if not tp_only:
            # quality-sweep Ncap: large enough for low-coverage contexts
            ncap_q = 2048 if 2048 in ncaps else min(ncaps)
            for r in ranks:
                if r == r_def:
                    continue
                plan.emit(
                    spec.name, b, f"predict_n{ncap_q}_r{r}",
                    model.predict_scores_fn(spec),
                    [
                        sds((b, d)),
                        sds((b, ncap_q, r)),
                        sds((b,), I32),
                        sds((b,), I32),
                        sds((d,)),
                        sds((d, hq * hd)),
                        sds((hd * hkv, r)),
                    ],
                    params={"ncap": ncap_q, "rank": r},
                    weight_args=["ln1", "wq", "A"], n_outputs=1,
                )
            # fused grouped predictor (perf/ablation variant)
            plan.emit(
                spec.name, b, f"predict_grouped_n{ncap_q}_r{r_def}_g{fused_group}",
                model.grouped_predict_fn(spec, fused_group),
                [
                    sds((b, d)),
                    sds((b, ncap_q, r_def)),
                    sds((b,), I32),
                    sds((b,), I32),
                    sds((d,)),
                    sds((d, hq * hd)),
                    sds((hd * hkv, r_def)),
                ],
                params={"ncap": ncap_q, "rank": r_def, "group": fused_group},
                weight_args=["ln1", "wq", "A"], n_outputs=1,
            )
            # --- prefill ---------------------------------------------------
            plan.emit(
                spec.name, b, f"embed_chunk_t{prefill_chunk}",
                model.embed_chunk_fn(spec),
                [sds((b, prefill_chunk), I32), sds((spec.vocab, d))],
                params={"t": prefill_chunk}, weight_args=["emb"], n_outputs=1,
            )
            plan.emit(
                spec.name, b, f"prefill_t{prefill_chunk}_n{prefill_ncap}",
                model.prefill_block_fn(spec),
                [
                    sds((b, prefill_chunk, d)),
                    sds((b, hkv, prefill_ncap, hd)),
                    sds((b, hkv, prefill_ncap, hd)),
                    sds((b,), I32),
                    *lw,
                ],
                params={"t": prefill_chunk, "ncap": prefill_ncap},
                weight_args=list(LAYER_TENSORS), n_outputs=3,
            )

    return {
        "model": spec.to_json(),
        "defaults": dict(DEFAULTS),
        "ranks": ranks,
        "ncaps": ncaps,
        "batches": batches,
        "prefill": {"chunk": prefill_chunk, "ncap": prefill_ncap},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--presets", default="nano,small,med",
        help="comma-separated subset of: " + ",".join(PRESETS),
    )
    ap.add_argument(
        "--quick", action="store_true",
        help="minimal artifact set for fast iteration (nano, b<=2)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    plan = Plan(out_dir)
    manifest: Dict[str, dict] = {"presets": {}, "version": 1}

    preset_names = [p for p in args.presets.split(",") if p]
    if args.quick:
        preset_names = ["nano"]

    for pname in preset_names:
        spec = PRESETS[pname]
        print(f"[aot] preset {pname}: {spec.n_params()/1e6:.2f}M params", flush=True)

        if pname == "nano":
            kw = dict(
                batches=[1, 2] if args.quick else [1, 2, 4, 8, 16],
                ncaps=[2048] if args.quick else [1024, 2048, 4096, 8192],
                ranks=[4, 8, 16, 32],
                full_ncaps=[2048] if args.quick else [2048, 8192],
                tp_only_batches=[] if args.quick else [16],
                prefill_ncap=2048,
                prefill_chunk=128,
                fused_group=DEFAULTS["group_size"],
            )
        else:
            kw = dict(
                batches=[1, 8],
                ncaps=[2048, 8192],
                ranks=[16],
                full_ncaps=[2048],
                tp_only_batches=[8],
                prefill_ncap=2048,
                prefill_chunk=128,
                fused_group=DEFAULTS["group_size"],
            )

        # Weights + SVD adapters (offline, paper §3.2: no prefill-time SVD).
        weights = __import__(
            "compile.specs", fromlist=["init_weights"]
        ).init_weights(spec, seed=args.seed)
        adapters = calibrate.build_adapters(
            spec, weights, ranks=kw["ranks"],
            n_batches=1 if args.quick else 2,
            batch=2, seq=256, seed=args.seed + 1,
        )
        blob, index = serialize_weights({**weights, **adapters})
        wpath = os.path.join(out_dir, pname, "weights.bin")
        os.makedirs(os.path.dirname(wpath), exist_ok=True)
        with open(wpath, "wb") as f:
            f.write(blob)
        print(f"[aot] {pname}/weights.bin: {len(blob)/2**20:.1f} MiB", flush=True)

        stanza = emit_preset(plan, spec, **kw)
        stanza["weights"] = {"path": f"{pname}/weights.bin", "tensors": index}
        manifest["presets"][pname] = stanza

    manifest["artifacts"] = plan.entries
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"[aot] wrote {len(plan.entries)} artifacts + manifest "
        f"in {time.time()-plan.t0:.0f}s -> {mpath}",
        flush=True,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
