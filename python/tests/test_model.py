"""L2 correctness: decode/prefill blocks, RoPE, predictor approximation."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import NEG_INF
from compile.specs import LAYER_TENSORS, PRESETS, init_weights

SPEC = PRESETS["nano"]
W = init_weights(SPEC, seed=0)
JW = {k: jnp.asarray(v) for k, v in W.items()}


def layer_weights(i):
    return [JW[f"layer{i}.{t}"] for t in LAYER_TENSORS]


# ---------------------------------------------------------------------------
# RoPE properties


def test_rope_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 3, 4, 32)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, 1000, size=(2, 3)), jnp.int32)
    y = model.rope(x, pos, 10000.0)
    assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_rope_zero_position_is_identity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 2, 32)).astype(np.float32))
    pos = jnp.zeros((1,), jnp.int32)
    assert_allclose(np.asarray(model.rope(x, pos, 10000.0)), np.asarray(x), rtol=1e-6)


def test_rope_relative_position_invariance():
    """q·k after RoPE depends only on relative offset (per-pair dims)."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 1, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 32)).astype(np.float32))

    def dot_at(pq, pk):
        qq = model.rope(q, jnp.asarray([pq], jnp.int32), 10000.0)
        kk = model.rope(k, jnp.asarray([pk], jnp.int32), 10000.0)
        return float(jnp.sum(qq * kk))

    assert abs(dot_at(10, 4) - dot_at(106, 100)) < 1e-3
    assert abs(dot_at(50, 0) - dot_at(150, 100)) < 1e-3


# ---------------------------------------------------------------------------
# decode block vs full-attention reference


def test_decode_block_matches_reference_full_attention():
    """decode_block over ALL cache entries == reference oracle step."""
    rng = np.random.default_rng(3)
    b, s_len = 2, 40
    hkv, d = SPEC.n_kv_heads, SPEC.head_dim
    tokens = rng.integers(0, SPEC.vocab, size=(b, s_len))
    x_all, ks, vs = model.reference_prefill(SPEC, JW, jnp.asarray(tokens))

    x0 = jnp.take(JW["emb"], jnp.asarray(rng.integers(0, SPEC.vocab, size=(b,))), axis=0)
    lens = jnp.full((b,), s_len, jnp.int32)
    pos = jnp.full((b,), s_len, jnp.int32)
    want_x, want_k, want_v = model.reference_decode_step(
        SPEC, JW, x0, ks, vs, lens, pos
    )

    # Same step through the exported per-layer decode blocks: the "selected"
    # set is the entire cache (mask all-valid), so results must agree.
    x = x0
    mask = jnp.zeros((b, s_len), jnp.float32)
    f = model.decode_block_fn(SPEC)
    for i in range(SPEC.n_layers):
        x, k_new, v_new = f(x, ks[i], vs[i], mask, pos, *layer_weights(i))
        assert_allclose(np.asarray(k_new), np.asarray(want_k[i]), rtol=1e-4, atol=1e-4)
        assert_allclose(np.asarray(v_new), np.asarray(want_v[i]), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(x), np.asarray(want_x), rtol=1e-3, atol=1e-3)


def test_decode_block_permutation_invariance():
    """Attention over gathered KV must not depend on slot order (the KV
    manager presents selected groups in arbitrary slot order)."""
    rng = np.random.default_rng(4)
    b, p = 1, 32
    hkv, d = SPEC.n_kv_heads, SPEC.head_dim
    x = jnp.asarray(rng.normal(size=(b, SPEC.d_model)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, p, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, p, d)).astype(np.float32))
    mask = jnp.zeros((b, p), jnp.float32)
    pos = jnp.asarray([100], jnp.int32)
    f = model.decode_block_fn(SPEC)
    out1 = f(x, k, v, mask, pos, *layer_weights(0))
    perm = rng.permutation(p)
    out2 = f(x, k[:, :, perm], v[:, :, perm], mask[:, perm], pos, *layer_weights(0))
    assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]), rtol=1e-4, atol=1e-5)


def test_prefill_block_matches_reference_prefill():
    rng = np.random.default_rng(5)
    b, s_len, t = 2, 64, 16
    hkv, d = SPEC.n_kv_heads, SPEC.head_dim
    tokens = rng.integers(0, SPEC.vocab, size=(b, s_len))
    x_want, ks_want, vs_want = model.reference_prefill(SPEC, JW, jnp.asarray(tokens))

    # chunked prefill through prefill_block_fn, chunk size t
    x = jnp.take(JW["emb"], jnp.asarray(tokens), axis=0)
    f = model.prefill_block_fn(SPEC)
    caches_k = [jnp.zeros((b, hkv, s_len, d), jnp.float32) for _ in range(SPEC.n_layers)]
    caches_v = [jnp.zeros((b, hkv, s_len, d), jnp.float32) for _ in range(SPEC.n_layers)]
    x_out = np.zeros((b, s_len, SPEC.d_model), np.float32)
    for c0 in range(0, s_len, t):
        xc = x[:, c0 : c0 + t]
        start = jnp.full((b,), c0, jnp.int32)
        for i in range(SPEC.n_layers):
            xc, k_chunk, v_chunk = f(
                xc, caches_k[i], caches_v[i], start, *layer_weights(i)
            )
            caches_k[i] = caches_k[i].at[:, :, c0 : c0 + t].set(k_chunk)
            caches_v[i] = caches_v[i].at[:, :, c0 : c0 + t].set(v_chunk)
        x_out[:, c0 : c0 + t] = np.asarray(xc)

    for i in range(SPEC.n_layers):
        assert_allclose(np.asarray(caches_k[i]), np.asarray(ks_want[i]), rtol=1e-3, atol=1e-3)
    assert_allclose(x_out, np.asarray(x_want), rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# predictor quality (the paper's core mechanism)


def _prefill_state(b=2, s_len=256, seed=6):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, SPEC.vocab, size=(b, s_len))
    return model.reference_prefill(SPEC, JW, jnp.asarray(tokens)), rng


def test_attention_mass_is_concentrated():
    """Paper §2.3 premise: a small fraction of tokens dominates attention.
    Our init must reproduce that (attn_gain knob)."""
    (x_all, ks, vs), rng = _prefill_state()
    b, s_len = x_all.shape[0], ks[0].shape[2]
    d = SPEC.head_dim
    # last-token query of layer 1 against the full K cache
    i = 1
    h = model.rmsnorm(x_all[:, -1], JW[f"layer{i}.ln1"], SPEC.rms_eps)
    q = (h @ JW[f"layer{i}.wq"]).reshape(b, SPEC.n_q_heads, d)
    q = model.rope(q, jnp.full((b,), s_len - 1, jnp.int32), SPEC.rope_base)
    qg = np.asarray(q).reshape(b, SPEC.n_kv_heads, SPEC.n_rep, d)
    s = np.einsum("bhrd,bhpd->bhrp", qg, np.asarray(ks[i])) / d**0.5
    w = np.exp(s - s.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    w = w.reshape(b, -1, s_len).mean(axis=1)  # avg over heads
    top = np.sort(w, axis=-1)[:, ::-1]
    frac = top[:, : max(1, s_len // 20)].sum(axis=-1)  # top 5%
    # >= ~3x the uniform share (0.05): concentrated but not one-hot — the
    # regime where head-summed score selection works (see DESIGN.md §2).
    assert frac.mean() > 0.12, f"attention too uniform: top5% mass={frac.mean():.3f}"


@pytest.mark.parametrize("rank,min_recall", [(32, 0.35), (16, 0.25), (4, 0.05)])
def test_predictor_recalls_true_top_tokens(rank, min_recall):
    """Low-rank predicted scores must recall a decent share of the true
    top-k attention tokens, degrading with compression (paper Tab. 2)."""
    from compile import calibrate

    (x_all, ks, vs), rng = _prefill_state()
    b, s_len = x_all.shape[0], ks[0].shape[2]
    d = SPEC.head_dim
    layer = 2
    k_flat_cal = calibrate.collect_calibration_k(
        SPEC, W, n_batches=1, batch=2, seq=128, seed=99
    )[layer]
    a = calibrate.svd_adapter(k_flat_cal, rank)

    # true scores: x input of layer `layer` is unavailable from
    # reference_prefill (it returns final x); use the same approximation the
    # runtime uses (x from the *previous* layer ≈ x of this layer) — here we
    # only check selection recall, for which the oracle is the true attention
    # over this layer's K with the approximate q.
    h = model.rmsnorm(x_all[:, -1], JW[f"layer{layer}.ln1"], SPEC.rms_eps)
    q = (h @ JW[f"layer{layer}.wq"]).reshape(b, SPEC.n_q_heads, d)
    q = model.rope(q, jnp.full((b,), s_len - 1, jnp.int32), SPEC.rope_base)
    qn = np.asarray(q)
    k_tok = np.asarray(ks[layer]).transpose(0, 2, 1, 3).reshape(b, s_len, -1)
    true = np.zeros((b, s_len), np.float32)
    for h_i in range(SPEC.n_q_heads):
        g = h_i // SPEC.n_rep
        true += np.einsum(
            "bnd,bd->bn", k_tok[:, :, g * d : (g + 1) * d], qn[:, h_i]
        )
    # predicted via compressed cache
    k_lr = k_tok @ a
    a_heads = a.reshape(SPEC.n_kv_heads, d, rank)
    q_lr = np.einsum(
        "bhrd,hdk->bhrk",
        qn.reshape(b, SPEC.n_kv_heads, SPEC.n_rep, d),
        a_heads,
    ).reshape(b, SPEC.n_q_heads, rank)
    pred = np.einsum("bhr,bnr->bn", q_lr, k_lr)

    k_top = 32
    recall = 0.0
    for bi in range(b):
        t_idx = set(np.argsort(true[bi])[::-1][:k_top].tolist())
        p_idx = set(np.argsort(pred[bi])[::-1][:k_top].tolist())
        recall += len(t_idx & p_idx) / k_top
    recall /= b
    assert recall >= min_recall, f"rank={rank}: recall {recall:.2f} < {min_recall}"


def test_predictor_monotone_in_rank():
    """Higher rank ⇒ better (or equal) approximation of true scores."""
    from compile import calibrate

    (x_all, ks, vs), _ = _prefill_state(seed=8)
    layer, b = 1, x_all.shape[0]
    s_len, d = ks[0].shape[2], SPEC.head_dim
    k_flat_cal = calibrate.collect_calibration_k(
        SPEC, W, n_batches=1, batch=2, seq=128, seed=100
    )[layer]
    errs = []
    k_tok = np.asarray(ks[layer]).transpose(0, 2, 1, 3).reshape(b, s_len, -1)
    for rank in [4, 16, 64, 128]:
        a = calibrate.svd_adapter(k_flat_cal, rank)
        rec = (k_tok @ a) @ a.T
        errs.append(np.linalg.norm(rec - k_tok) / np.linalg.norm(k_tok))
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]
    # Random-init K has a flat spectrum (unlike trained models), so the
    # absolute error at r=64 stays sizeable; full rank must be ~exact.
    assert errs[3] < 0.05


# ---------------------------------------------------------------------------
# logits / embed


def test_embed_then_logits_roundtrip_prefers_same_token():
    """With tied embeddings and no transformer in between, argmax of the
    LM head over an embedded token should often be the token itself.
    Embedding norms are heavy-tailed (persistent heavy hitters, see
    specs.py), which biases the tied-head argmax toward large-norm
    tokens — so assert the roundtrip on the top-norm quartile, where the
    self-alignment dominates."""
    f_e = model.embed_fn(SPEC)
    f_l = model.logits_argmax_fn(SPEC)
    norms = np.linalg.norm(W["emb"], axis=1)
    top = np.argsort(norms)[::-1][: SPEC.vocab // 4][:64].copy()
    tokens = jnp.asarray(top, jnp.int32)
    (x,) = f_e(tokens, JW["emb"])
    tok, _ = f_l(x * 20.0, JW["fln"], JW["emb"])  # scale to sharpen
    match = (np.asarray(tok) == np.asarray(tokens)).mean()
    assert match > 0.8, f"roundtrip match {match:.2f}"
