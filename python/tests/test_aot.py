"""AOT pipeline: manifest/weights round-trip, HLO-text sanity, calibration."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, calibrate, model
from compile.specs import (
    PRESETS,
    deserialize_weights,
    init_weights,
    layer_shapes,
    serialize_weights,
    spec_from_json,
)

SPEC = PRESETS["nano"]


def test_weights_serialize_roundtrip():
    w = init_weights(SPEC, seed=3)
    blob, index = serialize_weights(w)
    back = deserialize_weights(blob, index)
    assert set(back) == set(w)
    for k in w:
        assert_allclose(back[k], w[k])


def test_weight_index_offsets_are_contiguous():
    w = init_weights(SPEC, seed=0)
    blob, index = serialize_weights(w)
    off = 0
    for ent in index:
        assert ent["offset"] == off
        assert ent["nbytes"] == int(np.prod(ent["shape"])) * 4
        off += ent["nbytes"]
    assert off == len(blob)


def test_spec_json_roundtrip():
    d = SPEC.to_json()
    assert spec_from_json(json.loads(json.dumps(d))) == SPEC


def test_init_weights_deterministic():
    a = init_weights(SPEC, seed=7)
    b = init_weights(SPEC, seed=7)
    for k in a:
        assert_allclose(a[k], b[k])
    c = init_weights(SPEC, seed=8)
    assert not np.allclose(a["layer0.wq"], c["layer0.wq"])


def test_layer_shapes_consistent_with_param_count():
    total = sum(int(np.prod(s)) for s in layer_shapes(SPEC).values())
    total *= SPEC.n_layers
    total += SPEC.vocab * SPEC.d_model + SPEC.d_model
    assert total == SPEC.n_params()


def test_to_hlo_text_emits_parseable_hlo():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    text = aot.to_hlo_text(
        fn, [jax.ShapeDtypeStruct((4, 4), jnp.float32)] * 2
    )
    assert "HloModule" in text
    assert "ENTRY" in text
    # per the xla 0.1.6 interchange contract, output must be a tuple
    assert "tuple" in text.lower()


def test_svd_adapter_orthonormal_columns():
    rng = np.random.default_rng(0)
    k_flat = rng.normal(size=(500, 64)).astype(np.float32)
    a = calibrate.svd_adapter(k_flat, 16)
    assert a.shape == (64, 16)
    gram = a.T @ a
    assert_allclose(gram, np.eye(16), atol=1e-4)


def test_svd_adapter_reconstruction_improves_with_rank():
    rng = np.random.default_rng(1)
    # low-rank-ish matrix + noise
    base = rng.normal(size=(400, 8)) @ rng.normal(size=(8, 64))
    k_flat = (base + 0.1 * rng.normal(size=(400, 64))).astype(np.float32)
    errs = [
        calibrate.reconstruction_error(k_flat, calibrate.svd_adapter(k_flat, r))
        for r in (2, 8, 32)
    ]
    assert errs[0] > errs[1] > errs[2]
    assert errs[1] < 0.2  # rank 8 captures the rank-8 structure


def test_collect_calibration_k_shapes():
    w = init_weights(SPEC, seed=0)
    ks = calibrate.collect_calibration_k(
        SPEC, w, n_batches=1, batch=1, seq=32, seed=5
    )
    assert len(ks) == SPEC.n_layers
    for k in ks:
        assert k.shape == (32, SPEC.kv_flat_dim)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_is_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert "nano" in man["presets"]
    for ent in man["artifacts"]:
        path = os.path.join(root, ent["path"])
        assert os.path.exists(path), ent["path"]
        assert ent["n_outputs"] >= 1
        assert len(ent["inputs"]) >= 1
    # weights blob covers every tensor in its index
    for pname, stanza in man["presets"].items():
        wpath = os.path.join(root, stanza["weights"]["path"])
        size = os.path.getsize(wpath)
        for t in stanza["weights"]["tensors"]:
            assert t["offset"] + t["nbytes"] <= size
        names = {t["name"] for t in stanza["weights"]["tensors"]}
        spec = spec_from_json(stanza["model"])
        assert "emb" in names and "fln" in names
        for i in range(spec.n_layers):
            assert f"layer{i}.wq" in names
            for r in stanza["ranks"]:
                assert f"layer{i}.A{r}" in names
