"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (batch, heads, selection width, rank, group size)
and mask/length patterns; assert_allclose against ref.py is the core
correctness signal for the kernels the AOT artifacts embed.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention, prefill, ref, score

SET = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def rnd(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# gathered attention


@SET
@given(
    b=st.integers(1, 4),
    hkv=st.sampled_from([1, 2, 4]),
    n_rep=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    p=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_gathered_attention_matches_ref(b, hkv, n_rep, d, p, seed):
    rng = np.random.default_rng(seed)
    hq = hkv * n_rep
    q = rnd(rng, (b, hq, d))
    k = rnd(rng, (b, hkv, p, d))
    v = rnd(rng, (b, hkv, p, d))
    keep = rng.random((b, p)) < 0.7
    keep[:, 0] = True  # at least one valid slot per row
    mask = jnp.asarray(np.where(keep, 0.0, ref.NEG_INF).astype(np.float32))
    got = attention.gathered_attention(q, k, v, mask)
    want = ref.gathered_attention_ref(q, k, v, mask, 1.0 / d**0.5)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_gathered_attention_masked_slots_have_no_influence():
    rng = np.random.default_rng(0)
    b, hq, hkv, d, p = 2, 8, 4, 16, 24
    q = rnd(rng, (b, hq, d))
    k = rnd(rng, (b, hkv, p, d))
    v = rnd(rng, (b, hkv, p, d))
    mask_np = np.zeros((b, p), np.float32)
    mask_np[:, p // 2 :] = ref.NEG_INF
    out1 = attention.gathered_attention(q, k, v, jnp.asarray(mask_np))
    # Scrambling the masked-out K/V must not change the output.
    k2 = np.asarray(k).copy()
    v2 = np.asarray(v).copy()
    k2[:, :, p // 2 :, :] = rng.normal(size=k2[:, :, p // 2 :, :].shape)
    v2[:, :, p // 2 :, :] = 1e3
    out2 = attention.gathered_attention(
        q, jnp.asarray(k2), jnp.asarray(v2), jnp.asarray(mask_np)
    )
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_gathered_attention_single_valid_slot_returns_its_value():
    rng = np.random.default_rng(1)
    b, hq, hkv, d, p = 1, 4, 2, 8, 16
    q = rnd(rng, (b, hq, d))
    k = rnd(rng, (b, hkv, p, d))
    v = rnd(rng, (b, hkv, p, d))
    mask_np = np.full((b, p), ref.NEG_INF, np.float32)
    mask_np[:, 3] = 0.0
    out = attention.gathered_attention(q, k, v, jnp.asarray(mask_np))
    out = np.asarray(out).reshape(b, hkv, hq // hkv, d)
    for h in range(hkv):
        for r in range(hq // hkv):
            assert_allclose(
                out[0, h, r], np.asarray(v)[0, h, 3], rtol=1e-5, atol=1e-5
            )


def test_gathered_attention_gqa_head_mapping():
    """Query head h must read KV head h // n_rep: make KV heads disjoint."""
    rng = np.random.default_rng(2)
    b, hkv, n_rep, d, p = 1, 4, 2, 8, 8
    hq = hkv * n_rep
    q = rnd(rng, (b, hq, d))
    k = rnd(rng, (b, hkv, p, d))
    # v for kv-head j is constant j
    v = jnp.asarray(
        np.broadcast_to(
            np.arange(hkv, dtype=np.float32)[None, :, None, None], (b, hkv, p, d)
        ).copy()
    )
    mask = jnp.zeros((b, p), jnp.float32)
    out = np.asarray(attention.gathered_attention(q, k, v, mask))
    for h in range(hq):
        assert_allclose(out[0, h], np.full(d, h // n_rep, np.float32), rtol=1e-6)


# ---------------------------------------------------------------------------
# low-rank scores


@SET
@given(
    b=st.integers(1, 4),
    hq=st.sampled_from([4, 8, 16]),
    r=st.sampled_from([4, 8, 16, 32]),
    n=st.sampled_from([32, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_token_scores_matches_ref(b, hq, r, n, seed):
    rng = np.random.default_rng(seed)
    q_lr = rnd(rng, (b, hq, r))
    k_lr = rnd(rng, (b, n, r))
    lens = jnp.asarray(rng.integers(1, n + 1, size=(b,)), jnp.int32)
    got = score.token_scores(q_lr, k_lr, lens)
    want = ref.token_scores_ref(q_lr, k_lr, lens)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@SET
@given(
    b=st.integers(1, 3),
    g=st.sampled_from([1, 2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grouped_scores_matches_ref(b, g, seed):
    rng = np.random.default_rng(seed)
    hq, r, n = 8, 8, 128
    q_lr = rnd(rng, (b, hq, r))
    k_lr = rnd(rng, (b, n, r))
    lens = jnp.asarray(rng.integers(1, n + 1, size=(b,)), jnp.int32)
    got = score.grouped_scores(q_lr, k_lr, lens, g)
    want = ref.grouped_scores_ref(q_lr, k_lr, lens, g)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_token_scores_invalid_rows_are_neg_inf():
    rng = np.random.default_rng(3)
    b, hq, r, n = 2, 4, 8, 32
    q_lr = rnd(rng, (b, hq, r))
    k_lr = rnd(rng, (b, n, r))
    lens = jnp.asarray([5, 20], jnp.int32)
    out = np.asarray(score.token_scores(q_lr, k_lr, lens))
    assert (out[0, 5:] == ref.NEG_INF).all()
    assert (out[1, 20:] == ref.NEG_INF).all()
    assert (out[0, :5] > ref.NEG_INF).all()


def test_grouped_scores_is_max_over_group_members():
    rng = np.random.default_rng(4)
    b, hq, r, n, g = 1, 4, 8, 64, 8
    q_lr = rnd(rng, (b, hq, r))
    k_lr = rnd(rng, (b, n, r))
    lens = jnp.asarray([n], jnp.int32)
    tok = np.asarray(score.token_scores(q_lr, k_lr, lens))
    grp = np.asarray(score.grouped_scores(q_lr, k_lr, lens, g))
    assert_allclose(grp[0], tok[0].reshape(-1, g).max(axis=1), rtol=1e-6)


def test_token_scores_equals_true_lowrank_attention_logits():
    """Eq. (1): head-sum of Q_h A_g K_lr^T == head-sum of (Q A) reconstruction."""
    rng = np.random.default_rng(5)
    b, hkv, n_rep, d, r, n = 1, 2, 2, 16, 8, 32
    hq = hkv * n_rep
    a = rng.normal(size=(hkv * d, r)).astype(np.float32)
    k_flat = rng.normal(size=(n, hkv * d)).astype(np.float32)
    k_lr = k_flat @ a  # [n, r]
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    a_heads = a.reshape(hkv, d, r)
    q_lr = np.einsum(
        "bhrd,hdk->bhrk", q.reshape(b, hkv, n_rep, d), a_heads
    ).reshape(b, hq, r)
    lens = jnp.asarray([n], jnp.int32)
    got = np.asarray(score.token_scores(jnp.asarray(q_lr), jnp.asarray(k_lr[None]), lens))
    # direct: sum_h q_h . (A_g^T k_flat_n) per token
    want = np.zeros((b, n), np.float32)
    for h in range(hq):
        g = h // n_rep
        k_rec = k_lr @ a_heads[g].T  # [n, d] reconstructed head-g keys
        want[0] += k_rec @ q[0, h]
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# prefill attention


@SET
@given(
    b=st.integers(1, 3),
    t=st.sampled_from([1, 4, 8]),
    s_len=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention_matches_ref(b, t, s_len, seed):
    rng = np.random.default_rng(seed)
    hq, hkv, d = 8, 4, 16
    q = rnd(rng, (b, t, hq, d))
    k = rnd(rng, (b, hkv, s_len, d))
    v = rnd(rng, (b, hkv, s_len, d))
    start = jnp.asarray(rng.integers(0, s_len - t + 1, size=(b,)), jnp.int32)
    got = prefill.prefill_attention(q, k, v, start)
    want = ref.prefill_attention_ref(q, k, v, start, 1.0 / d**0.5)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_prefill_attention_is_causal():
    """Future keys (beyond each query's position) must have no influence."""
    rng = np.random.default_rng(6)
    b, t, hq, hkv, d, s_len = 1, 4, 4, 2, 8, 32
    q = rnd(rng, (b, t, hq, d))
    k = rnd(rng, (b, hkv, s_len, d))
    v = rnd(rng, (b, hkv, s_len, d))
    start = jnp.asarray([10], jnp.int32)
    out1 = prefill.prefill_attention(q, k, v, start)
    k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
    k2[:, :, 14:, :] = 99.0  # beyond last query position (10+3)
    v2[:, :, 14:, :] = -99.0
    out2 = prefill.prefill_attention(q, jnp.asarray(k2), jnp.asarray(v2), start)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_prefill_first_token_attends_only_to_itself():
    rng = np.random.default_rng(7)
    b, t, hq, hkv, d, s_len = 1, 2, 2, 1, 8, 16
    q = rnd(rng, (b, t, hq, d))
    k = rnd(rng, (b, hkv, s_len, d))
    v = rnd(rng, (b, hkv, s_len, d))
    start = jnp.asarray([0], jnp.int32)
    out = np.asarray(prefill.prefill_attention(q, k, v, start))
    for h in range(hq):
        assert_allclose(out[0, 0, h], np.asarray(v)[0, 0, 0], rtol=1e-5, atol=1e-5)
